"""The paper's base experiments (Figs 3-5a, Table II) at laptop scale.

Compares all five frameworks over (a) client counts {4,6,8} and (b) server
widths {128,256,512}, writing convergence curves + final accuracies to CSV
— the data behind EXPERIMENTS.md's reproduction claims.

    PYTHONPATH=src python examples/paper_experiments.py [--steps 1500]
"""
import argparse
import csv
import os

import jax
import jax.numpy as jnp

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular

LRS = {"split": 0.05, "vafl": 0.05, "cascaded": 0.05,
       "zoo-vfl": 0.001, "syn-zoo": 0.001}
OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run_cell(n_clients, server_embed, method, steps):
    cfg = PaperMLPConfig(n_features=64, n_classes=10, n_clients=n_clients,
                         client_embed=32, server_embed=server_embed)
    X, y = make_classification(0, 2048, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    vfl = VFLConfig(mu=1e-3, lr_server=LRS[method], lr_client=LRS[method])
    res = async_engine.run(
        async_engine.EngineConfig(method=method, steps=steps, batch_size=64),
        vfl, params, Xp, jnp.asarray(y))
    acc = float(tabular.accuracy(res.params, Xp, jnp.asarray(y)))
    return res.losses, acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1000)
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)

    rows = []
    curves = {}
    for m_clients in (4, 6, 8):
        for method in LRS:
            losses, acc = run_cell(m_clients, 128, method, args.steps)
            rows.append(("clients", m_clients, method, acc))
            curves[f"clients{m_clients}_{method}"] = losses
            print(f"M={m_clients} {method:9s} acc={acc:.3f}", flush=True)
    for width in (128, 256, 512):
        for method in ("vafl", "zoo-vfl", "cascaded"):
            losses, acc = run_cell(4, width, method, args.steps)
            rows.append(("width", width, method, acc))
            curves[f"width{width}_{method}"] = losses
            print(f"W={width} {method:9s} acc={acc:.3f}", flush=True)

    with open(os.path.join(OUT, "paper_table2_accuracy.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["sweep", "value", "method", "train_acc"])
        w.writerows(rows)
    with open(os.path.join(OUT, "paper_fig3_curves.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["cell", "step", "loss"])
        for cell, losses in curves.items():
            for i in range(0, len(losses), 10):
                w.writerow([cell, i, float(losses[i])])
    print("wrote", os.path.join(OUT, "paper_table2_accuracy.csv"))


if __name__ == "__main__":
    main()
