"""End-to-end driver: train a ~100M-parameter split LM with cascaded
hybrid VFL (the distilBERT experiment of paper §VI-D-c at framework scale).

The client holds the token embedding (updated with ZOO, active-row mode);
the server holds the transformer stack (updated with FOO). Training is
constructed through the ``repro.federation`` session API (the
``launch/train.py`` driver wraps ``Federation.build(...).sync_step``),
so any spelling from the method alias table works and ``--dp-epsilon``
plugs a Gaussian DP channel into the loss downlink. Presets:

    ci    :  ~0.4M params,  60 steps  (seconds; used by CI)
    small :  ~20M params,  300 steps  (tens of minutes on 1 CPU core)
    full  : ~100M params,  300 steps  (hours on CPU; the real deal on TPU)

    PYTHONPATH=src python examples/train_lm_cascaded.py --preset small
"""
import argparse
import json

from repro.configs import ARCH_REGISTRY, ModelConfig
from repro.core.methods import METHOD_ALIASES, canonical_method
from repro.core.privacy import GaussianLossChannel
from repro.launch import train as train_mod

PRESETS = {
    "ci": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
               vocab_size=2048, steps=60, batch=8, seq=64),
    "small": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                  d_ff=1536, vocab_size=16384, steps=300, batch=8, seq=128),
    "full": dict(n_layers=10, d_model=640, n_heads=10, n_kv_heads=2,
                 d_ff=2560, vocab_size=32000, steps=300, batch=8, seq=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--method", default="cascaded",
                    choices=sorted(METHOD_ALIASES))
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="per-release ε for the DP loss channel (0 = off)")
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    preset_steps = p.pop("steps")
    steps = args.steps or preset_steps
    batch, seq = p.pop("batch"), p.pop("seq")

    # register a bespoke config so the standard driver can train it
    cfg = ModelConfig(arch_id=f"lm-{args.preset}", family="dense",
                      act="swiglu", norm="rmsnorm", pos="rope", **p)
    ARCH_REGISTRY[cfg.arch_id] = cfg
    n_params = cfg.param_count()
    print(f"[e2e] {cfg.arch_id}: ~{n_params/1e6:.1f}M params, "
          f"{steps} steps, batch {batch}, seq {seq}")

    noise = (GaussianLossChannel(clip=10.0, epsilon=args.dp_epsilon)
             if args.dp_epsilon > 0 else None)
    res = train_mod.train(cfg.arch_id, steps=steps, batch=batch, seq=seq,
                          method=canonical_method(args.method), lr=0.05,
                          active_rows=True, use_reduced=False,
                          log_every=max(steps // 20, 1),
                          checkpoint_path=args.checkpoint, noise=noise)
    res["n_params"] = n_params
    print(json.dumps(res, indent=2))
    assert res["loss_last"] < res["loss_first"]


if __name__ == "__main__":
    main()
