"""Serve small models with batched requests across model families —
SPLIT inference through the Federation session's serve plane: the client
parties embed their token spans (whole spans in one chunked-prefill
upload), the server runs backbone + head with KV/SSM caches through one
compiled decode scan, and every step's wire traffic (embedding up, token
ids down) lands in the session ledger. Covers KV-cache decode (granite
MQA), SSM-state decode (rwkv6) and hybrid decode (zamba2); whisper is
encoder-decoder — its modality frontend cannot cross the VFL wire, so it
exercises the global back-compat path. The granite run also drains the
same request load through the continuous-batching scheduler
(``fed.serve``) to show the churn path end to end.

    PYTHONPATH=src python examples/serve_decode.py
"""
import json

from repro.launch.serve import serve


def main():
    for arch in ("granite-20b", "rwkv6-7b", "zamba2-2.7b"):
        res = serve(arch, batch=4, prompt_len=12, gen_len=12,
                    temperature=0.8, n_clients=2)
        print(json.dumps(res), flush=True)
        assert res["mode"] == "federated"
        assert res["wire_bytes"] > 0 and not res["wire_has_gradients"]
    # continuous batching: 4 requests through 2 slots, admissions
    # mid-flight, per-request exact wire
    res = serve("granite-20b", batch=4, prompt_len=12, gen_len=12,
                temperature=0.8, n_clients=2, continuous=True, max_batch=2)
    print(json.dumps(res), flush=True)
    assert res["mode"] == "continuous" and res["slots"] == 2
    assert res["wire_bytes"] > 0 and not res["wire_has_gradients"]
    # enc-dec fallback: asked to split, served global with a reason
    res = serve("whisper-medium", batch=4, prompt_len=12, gen_len=12,
                temperature=0.8, n_clients=2)
    print(json.dumps(res), flush=True)
    assert res["mode"] == "global" and "fallback" in res


if __name__ == "__main__":
    main()
