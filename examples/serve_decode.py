"""Serve a small model with batched requests across model families —
KV-cache decode (granite MQA), SSM-state decode (rwkv6), hybrid decode
(zamba2) and enc-dec decode (whisper).

    PYTHONPATH=src python examples/serve_decode.py
"""
import json

from repro.launch.serve import serve


def main():
    for arch in ("granite-20b", "rwkv6-7b", "zamba2-2.7b", "whisper-medium"):
        res = serve(arch, batch=4, prompt_len=12, gen_len=12,
                    temperature=0.8)
        print(json.dumps(res), flush=True)


if __name__ == "__main__":
    main()
