"""Direct label-inference attack demo (paper §VI-B, Table I).

Shows WHY the cascade keeps the wire gradient-free: against a FOO server
the curious client (and even a passive eavesdropper) reads labels off the
wire with certainty; against the ZOO wire both collapse to ~chance.

    PYTHONPATH=src python examples/attack_demo.py
"""
import jax

from repro.core import attacks


def main():
    n = 2048
    print(f"{'framework':10s} {'curious client':>15s} {'eavesdropper':>15s}")
    for fw in ("foo", "zoo"):
        r = attacks.run_label_inference(jax.random.key(0), 10, n,
                                        framework=fw)
        print(f"{fw:10s} {r.curious_client_acc:15.3f} "
              f"{r.eavesdropper_acc:15.3f}")
    print("\n(paper Table I: FOO 100/100, ZOO 11.7/10.0 — chance = 10%)")

    fr = attacks.run_feature_inference(jax.random.key(1))
    print("\nfeature inference (§V-B, reconstruction MSE — lower = leak):")
    print(f"  with client-model access : {fr.mse_with_model_access:.3f}")
    print(f"  black-box (our protocol) : {fr.mse_black_box:.3f}")
    print(f"  chance (guess the mean)  : {fr.mse_chance:.3f}")


if __name__ == "__main__":
    main()
