"""Quickstart: cascaded hybrid VFL (ZOO clients + FOO server) in ~40 lines.

Four banks (clients) hold disjoint feature slices of each customer; the
agency (server) holds the labels. Nothing but embeddings and scalar losses
ever crosses the wire.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.privacy import Ledger
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular


def main():
    cfg = PaperMLPConfig(n_features=64, n_classes=10, n_clients=4,
                         client_embed=32, server_embed=128)
    X, y = make_classification(seed=0, n=2048, n_features=cfg.n_features,
                               n_classes=cfg.n_classes)
    x_parts = jnp.asarray(vertical_partition(X, cfg.n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))

    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=800,
                                  batch_size=64),
        vfl, params, x_parts, jnp.asarray(y))

    acc = float(tabular.accuracy(res.params, x_parts, jnp.asarray(y)))
    ledger = Ledger()
    for _ in range(800):
        ledger.log_round("cascaded", 64, cfg.client_embed)
    print(f"final loss        : {res.losses[-25:].mean():.4f}")
    print(f"train accuracy    : {acc:.3f}")
    print(f"wire bytes total  : {ledger.total_bytes:,}")
    print(f"gradients on wire : {ledger.transmits_gradients}")
    assert acc > 0.9 and not ledger.transmits_gradients


if __name__ == "__main__":
    main()
