"""Async engine beyond the paper's tabular MLP, through the one
federation API: ``Federation.build(model_cfg, vfl_cfg, engine_cfg)``.

Four runs over the same vertically partitioned data:
  1. the paper's tabular model, one client per round (baseline protocol)
  2. the SAME protocol driving a SwiGLU-MLP client/server pair — the
     session only sees the ModelAdapter, not the model family
  3. tabular again with block_size=3 — three concurrent client
     activations per round (vmapped), the many-client scaling mode —
     and the client fan-out routed through the fused dual-pass lanes
  4. tabular with the DP loss channel plugged into the Transport:
     calibrated Gaussian noise on every scalar loss crossing the
     downlink, and a finite spent (ε, δ) on the EngineResult.

    PYTHONPATH=src python examples/async_adapters.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core.adapters import mlp_adapter
from repro.core.async_engine import EngineConfig
from repro.data import make_classification, vertical_partition
from repro.federation import Federation, GaussianLossChannel
from repro.models import common, tabular


def main():
    M, f, c = 4, 64, 10
    cfg = PaperMLPConfig(n_features=f, n_classes=c, n_clients=M,
                         client_embed=32, server_embed=128)
    X, y = make_classification(seed=0, n=2048, n_features=f, n_classes=c)
    Xp = jnp.asarray(vertical_partition(X, M))
    y = jnp.asarray(y)
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=4)

    # 1 — paper tabular, one activation per round (session from the
    #     paper's config; the adapter is derived inside build)
    fed = Federation.build(cfg, vfl,
                           EngineConfig(method="cascaded", steps=600,
                                        batch_size=64))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    res = fed.run(params, Xp, y)
    acc = float(tabular.accuracy(res.params, Xp, y))
    print(f"tabular  block=1 : loss {res.losses[-25:].mean():.4f} "
          f"acc {acc:.3f}  mean_delay {res.mean_delay:.1f}")

    # 2 — same protocol, SwiGLU-MLP client/server pair via its adapter
    ad = mlp_adapter(n_clients=M, features=f, client_embed=32, d_ff=64,
                     server_embed=128, n_classes=c)
    fed_m = Federation.build(ad, vfl,
                             EngineConfig(method="cascaded", steps=600,
                                          batch_size=64))
    res_m = fed_m.run(fed_m.init_params(jax.random.key(1)), Xp, y)
    print(f"swiglu   block=1 : loss {res_m.losses[-25:].mean():.4f} "
          f"(first {res_m.losses[:25].mean():.4f})")

    # 3 — block activation + fused dual-pass lanes (stacked ZOO fan-out)
    fed_b = Federation.build(cfg, vfl,
                             EngineConfig(method="cascaded", steps=200,
                                          batch_size=64, block_size=3,
                                          use_lanes=True))
    res_b = fed_b.run(params, Xp, y)
    acc_b = float(tabular.accuracy(res_b.params, Xp, y))
    print(f"tabular  block=3 : loss {res_b.losses[-25:].mean():.4f} "
          f"acc {acc_b:.3f}  mean_delay {res_b.mean_delay:.1f}")

    # 4 — DP loss channel on the Transport's downlink. The ZOO client
    # multiplies (ĥ−h) by φ/μ, so downlink noise is amplified ~φ/μ-fold
    # into its update: under a tight per-release ε the client lr must be
    # tiny — and training STILL converges, because the server's FOO step
    # is local and noise-free (the paper's server-does-the-heavy-lifting
    # claim, surfaced in a DP light).
    import dataclasses
    vfl_dp = dataclasses.replace(vfl, lr_client=1e-7)
    fed_dp = Federation.build(
        cfg, vfl_dp, EngineConfig(method="cascaded", steps=400,
                                  batch_size=64),
        noise=GaussianLossChannel(clip=5.0, epsilon=1.0, delta=1e-5))
    res_dp = fed_dp.run(params, Xp, y)
    print(f"tabular  dp      : loss {res_dp.losses[-25:].mean():.4f} "
          f"spent (eps={res_dp.epsilon:.1f}, delta={res_dp.delta:.1e})  "
          f"grads_on_wire={res_dp.transmits_gradients}")

    assert np.isfinite(res.losses).all() and np.isfinite(res_m.losses).all()
    assert res_b.mean_delay < res.mean_delay  # 3/4 clients fresh per round
    assert np.isfinite(res_dp.epsilon) and not res_dp.transmits_gradients


if __name__ == "__main__":
    main()
