"""Async engine beyond the paper's tabular MLP: model adapters, block
activation, and the fused ZOO fan-out.

Three runs over the same vertically partitioned data:
  1. the paper's tabular model, one client per round (baseline protocol)
  2. the SAME protocol driving a SwiGLU-MLP client/server pair — the
     engine only sees the ModelAdapter, not the model family
  3. tabular again with block_size=3 — three concurrent client
     activations per round (vmapped), the many-client scaling mode —
     and the client fan-out routed through the fused dual-pass lanes.

    PYTHONPATH=src python examples/async_adapters.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.adapters import mlp_adapter, tabular_adapter
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular


def main():
    M, f, c = 4, 64, 10
    cfg = PaperMLPConfig(n_features=f, n_classes=c, n_clients=M,
                         client_embed=32, server_embed=128)
    X, y = make_classification(seed=0, n=2048, n_features=f, n_classes=c)
    Xp = jnp.asarray(vertical_partition(X, M))
    y = jnp.asarray(y)
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=4)

    # 1 — paper tabular, one activation per round
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=600,
                                  batch_size=64),
        vfl, params, Xp, y)
    acc = float(tabular.accuracy(res.params, Xp, y))
    print(f"tabular  block=1 : loss {res.losses[-25:].mean():.4f} "
          f"acc {acc:.3f}  mean_delay {res.mean_delay:.1f}")

    # 2 — same protocol, SwiGLU-MLP client/server pair via the adapter
    ad = mlp_adapter(n_clients=M, features=f, client_embed=32, d_ff=64,
                     server_embed=128, n_classes=c)
    res_m = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=600,
                                  batch_size=64),
        vfl, ad.init_params(jax.random.key(1)), Xp, y, adapter=ad)
    print(f"swiglu   block=1 : loss {res_m.losses[-25:].mean():.4f} "
          f"(first {res_m.losses[:25].mean():.4f})")

    # 3 — block activation + fused dual-pass lanes (stacked ZOO fan-out)
    res_b = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=200,
                                  batch_size=64, block_size=3,
                                  use_lanes=True),
        vfl, params, Xp, y, adapter=tabular_adapter(cfg))
    acc_b = float(tabular.accuracy(res_b.params, Xp, y))
    print(f"tabular  block=3 : loss {res_b.losses[-25:].mean():.4f} "
          f"acc {acc_b:.3f}  mean_delay {res_b.mean_delay:.1f}")

    assert np.isfinite(res.losses).all() and np.isfinite(res_m.losses).all()
    assert res_b.mean_delay < res.mean_delay  # 3/4 clients fresh per round


if __name__ == "__main__":
    main()
