"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per measured cell).

  bench_attack              — Table I   (direct label-inference attack)
  bench_convergence_clients — Fig 3 / Table II-left  (M ∈ {4,6,8})
  bench_server_width        — Fig 5a / Table II-mid  (width ∈ {128,256,512})
  bench_hparam_robustness   — Fig 4    (lr sensitivity: cascaded vs ZOO-VFL)
  bench_large_model         — Fig 5b/c (split LM at laptop scale)
  bench_wire                — §II communication efficiency (bytes/round)
  bench_kernels             — kernel microbench (XLA-path oracle timing)
  bench_zoo_fanout          — stacked vs unrolled ZOO fan-out, q ∈ {1,4,16}
  bench_async_scale         — device-sharded client block, block ∈ {1,4,16}
                              (subprocess: forces 8 virtual host devices)
  bench_lm_async            — reduced transformer server under the async
                              engine via Federation, q ∈ {1,4} + DP point
  bench_serve_throughput    — fused split-serve engine: seed per-token
                              loop vs scan decode vs batched vs continuous
                              batching (emits BENCH_serve.json)
  bench_wire_faults         — population engine over the wire plane:
                              throughput + bytes/round vs drop/latency
                              (emits BENCH_wire.json)
  bench_serve_chaos         — serve-plane failure policy: goodput vs
                              preemption, deadline misses, kill-mid-drain
                              recovery, poison isolation
                              (emits BENCH_chaos.json)
  bench_roofline            — §Roofline terms from the dry-run artifacts

``BENCH_*.json`` artifacts keep a dated history entry per run (see
``benchmarks.history``) instead of being overwritten.

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax
import jax.numpy as jnp

ROWS = []


def row(name: str, us_per_call: float, derived: str):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def _time(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


# ======================================================== Table I ==========

def bench_attack(fast: bool):
    from repro.core import attacks
    n = 512 if fast else 2048
    for fw in ("foo", "zoo"):
        t0 = time.perf_counter()
        r = attacks.run_label_inference(jax.random.key(0), 10, n,
                                        framework=fw)
        us = (time.perf_counter() - t0) / n * 1e6
        row(f"attack_{fw}", us,
            f"curious={r.curious_client_acc:.3f};eaves={r.eavesdropper_acc:.3f}")


# ============================================== Fig 3 / Table II-left ======

def _tabular_setup(n_clients, server_embed=64, n=2048, f=64, c=10):
    from repro.configs.paper_mlp import PaperMLPConfig
    from repro.data import make_classification, vertical_partition
    from repro.models import common, tabular
    cfg = PaperMLPConfig(n_features=f, n_classes=c, n_clients=n_clients,
                         client_embed=32, server_embed=server_embed)
    X, y = make_classification(0, n, f, c)
    Xp = jnp.asarray(vertical_partition(X, n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


# per-method (lr chosen by the paper's style of grid search; ZOO methods
# need the much smaller lr — reproducing the paper's Fig 4 observation)
LRS = {"cascaded": 0.05, "vafl": 0.05, "split": 0.05,
       "zoo-vfl": 0.001, "syn-zoo": 0.001}


def _run_engine(method, params, Xp, y, steps, lr):
    from repro.configs import VFLConfig
    from repro.core import async_engine
    from repro.models import tabular
    vfl = VFLConfig(mu=1e-3, lr_server=lr, lr_client=lr)
    t0 = time.perf_counter()
    res = async_engine.run(
        async_engine.EngineConfig(method=method, steps=steps, batch_size=64),
        vfl, params, Xp, y)
    us = (time.perf_counter() - t0) / steps * 1e6
    acc = float(tabular.accuracy(res.params, Xp, y))
    return us, acc, res


def bench_convergence_clients(fast: bool):
    steps = 300 if fast else 1500
    for m_clients in (4, 6, 8):
        cfg, Xp, y, params = _tabular_setup(m_clients)
        for method in ("split", "vafl", "syn-zoo", "zoo-vfl", "cascaded"):
            us, acc, _ = _run_engine(method, params, Xp, y, steps,
                                     LRS[method])
            row(f"clients{m_clients}_{method}", us, f"train_acc={acc:.3f}")


# ============================================== Fig 5a / Table II-mid ======

def bench_server_width(fast: bool):
    steps = 300 if fast else 1500
    for width in (128, 256, 512):
        cfg, Xp, y, params = _tabular_setup(4, server_embed=width)
        for method in ("vafl", "zoo-vfl", "cascaded"):
            us, acc, _ = _run_engine(method, params, Xp, y, steps,
                                     LRS[method])
            row(f"width{width}_{method}", us, f"train_acc={acc:.3f}")


# ======================================================== Fig 4 ============

def bench_hparam_robustness(fast: bool):
    steps = 300 if fast else 1000
    cfg, Xp, y, params = _tabular_setup(4)
    for method in ("cascaded", "zoo-vfl"):
        accs = []
        for lr in (0.02, 0.01, 0.005, 0.001):
            us, acc, _ = _run_engine(method, params, Xp, y, steps, lr)
            accs.append(acc)
            row(f"lr{lr}_{method}", us, f"train_acc={acc:.3f}")
        row(f"lr_spread_{method}", 0.0,
            f"acc_min={min(accs):.3f};acc_max={max(accs):.3f}")


# ===================================================== Fig 5b/c ============

def bench_large_model(fast: bool):
    """Split-LM analogue of the ResNet/distilBERT experiments: the same
    global model trained with cascaded vs full-ZOO vs (unsafe) split."""
    from repro.launch.train import train
    steps = 100 if fast else 300
    for method, lr in (("split-learning", 0.05), ("cascaded", 0.05),
                       ("zoo-vfl", 0.003)):
        res = train("phi3-mini-3.8b", steps=steps, batch=8, seq=64,
                    method=method, lr=lr, log_every=10 ** 9)
        us = 1e6 / max(res["steps_per_s"], 1e-9)
        row(f"lm_{method}", us,
            f"loss_drop={res['loss_first'] - res['loss_last']:.3f};"
            f"wire_grad={res['wire_has_gradients']}")


# ================================================== wire accounting ========

def bench_wire(fast: bool):
    from repro.core.privacy import Ledger
    for method in ("cascaded", "zoo-vfl", "vafl", "split-learning"):
        led = Ledger()
        led.log_round(method, 64, 128)
        row(f"wire_{method}", 0.0,
            f"bytes={led.total_bytes};grads={led.transmits_gradients}")


# ======================================================== kernels ==========

def bench_kernels(fast: bool):
    from repro.kernels.flash_attention.ref import flash_attention_ref
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    from repro.kernels.zoo_dual_matmul.ref import zoo_dual_matmul_ref
    k = jax.random.key(0)
    q = jax.random.normal(k, (4, 512, 64), jnp.bfloat16)
    us = _time(jax.jit(lambda a: flash_attention_ref(a, a, a)), q)
    flops = 4 * 4 * 512 * 512 * 64
    row("flash_attention_ref", us, f"gflops={flops / us / 1e3:.1f}")

    x = jax.random.normal(k, (2048, 1024), jnp.bfloat16)
    sc = jnp.ones(1024)
    us = _time(jax.jit(lambda a, s: rmsnorm_ref(a, s)), x, sc)
    row("rmsnorm_ref", us, f"gbps={2 * x.size * 2 / us / 1e3:.1f}")

    w = jax.random.normal(k, (1024, 1024), jnp.bfloat16)
    u = jax.random.normal(k, (1024, 1024), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b, c: zoo_dual_matmul_ref(a, b, c, 1e-3)),
               x, w, u)
    row("zoo_dual_matmul_ref", us,
        f"gflops={2 * 2 * 2048 * 1024 * 1024 / us / 1e3:.1f}")

    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    BH, S, P, N = 8, 1024, 64, 32
    xh = jax.random.normal(k, (BH, S, P), jnp.float32)
    a = jnp.full((BH, S), 0.9)
    dt = jnp.ones((BH, S))
    bm = jax.random.normal(k, (BH, S, N), jnp.float32)
    us = _time(jax.jit(lambda *t: ssd_chunk_ref(*t)), xh, a, dt, bm, bm, n=3)
    row("ssd_chunk_ref", us, f"tokens_per_s={BH * S / us * 1e6:.0f}")


# ==================================================== ZOO fan-out ==========

def bench_zoo_fanout(fast: bool):
    from benchmarks.zoo_fanout import bench_zoo_fanout as bench
    bench(fast, row=row)


# ================================================ sharded async block ======

def bench_async_scale(fast: bool):
    """Spawned as a subprocess: the sweep forces 8 virtual host devices
    via XLA_FLAGS, which must be set before jax first initializes — this
    process has already locked the real device topology."""
    import subprocess
    import sys
    cmd = [sys.executable, "-m", "benchmarks.async_scale"]
    if not fast:
        cmd.append("--full")
    proc = subprocess.run(cmd, capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if line.startswith("async_scale"):
            name, us, derived = line.split(",", 2)
            row(name, float(us), derived)
    if proc.returncode:
        row("async_scale_failed", 0.0,
            f"rc={proc.returncode};stderr={proc.stderr.strip()[-200:]}")


# ================================================== LM async engine ========

def bench_lm_async(fast: bool):
    from benchmarks.lm_async import bench_lm_async as bench
    bench(fast, row=row)


# ================================================ serve throughput =========

def bench_serve_throughput(fast: bool):
    from benchmarks.serve_throughput import \
        bench_serve_throughput as bench
    bench(fast, row=row)


# ================================================ wire fault sweep =========

def bench_wire_faults(fast: bool):
    from benchmarks.wire_faults import bench_wire_faults as bench
    bench(fast, row=row)


# ================================================== serve chaos ============

def bench_serve_chaos(fast: bool):
    from benchmarks.serve_chaos import bench_serve_chaos as bench
    bench(fast, row=row)


# ======================================================== roofline =========

def bench_roofline(fast: bool):
    """Re-derive the §Roofline table from the dry-run artifacts."""
    pat = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun", "*baseline.json")
    files = sorted(glob.glob(pat))
    if not files:
        row("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        with open(f) as fh:
            res = json.load(fh)
        if "skipped" in res or res.get("mesh") != "16x16":
            continue
        r = res["roofline"]
        row(f"roofline_{res['arch']}_{res['shape']}",
            r["step_time_s"] * 1e6,
            f"bound={r['bottleneck']};compute_ms={r['compute_s']*1e3:.1f};"
            f"memory_ms={r['memory_s']*1e3:.1f};"
            f"coll_ms={r['collective_s']*1e3:.1f};mfu={r['mfu']:.3f}")


BENCHES = {
    "attack": bench_attack,
    "convergence_clients": bench_convergence_clients,
    "server_width": bench_server_width,
    "hparam_robustness": bench_hparam_robustness,
    "large_model": bench_large_model,
    "wire": bench_wire,
    "kernels": bench_kernels,
    "zoo_fanout": bench_zoo_fanout,
    "async_scale": bench_async_scale,
    "lm_async": bench_lm_async,
    "serve_throughput": bench_serve_throughput,
    "wire_faults": bench_wire_faults,
    "serve_chaos": bench_serve_chaos,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(args.fast)


if __name__ == "__main__":
    main()
