"""LM-scale server under the async engine: a reduced transformer-backbone
config driven with real staleness semantics through the federation
session API (the ROADMAP's "large-model server configs in the async
engine" item, closed by ``adapters.from_model_config``).

Sweeps the ZOO query fan-out q ∈ {1, 4} over the cascaded protocol
(embedding clients / transformer server) and records

  * steady-state per-round wall clock (compile excluded; the runner is
    lru-cached so the timed second ``run`` reuses the executable),
  * the sublinearity of per-round time in q (the fused lanes evaluate
    the clean + q perturbed client forwards in one vmapped pass), and
  * one DP point: the same run with the Gaussian loss channel enabled
    must stay gradient-free and report a finite spent (ε, δ).

Run: PYTHONPATH=src python -m benchmarks.lm_async [--full]
(also registered as ``benchmarks.run --only lm_async``.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import VFLConfig, get_config, reduced
from repro.core.async_engine import EngineConfig
from repro.data import lm_token_batches, vertical_partition
from repro.federation import Federation, GaussianLossChannel

QUERIES = (1, 4)
N_CLIENTS = 4
SEQ = 32


def bench_lm_async(fast: bool = True, row=None):
    """Emit name,us_per_call,derived rows; returns {q: us}."""
    if row is None:
        def row(name, us, derived):
            print(f"{name},{us:.1f},{derived}", flush=True)

    cfg = reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab_size=256)
    steps = 20 if fast else 100
    toks = next(lm_token_batches(0, cfg.vocab_size, 128, SEQ))["tokens"]
    x_parts = jnp.asarray(vertical_partition(toks, N_CLIENTS))
    y = jnp.asarray(toks)

    results = {}
    for q in QUERIES:
        vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=1e-4,
                        zoo_queries=q, active_rows_only=True)
        fed = Federation.build(
            cfg, vfl, EngineConfig(method="cascaded", steps=steps,
                                   batch_size=8, use_lanes=True),
            n_clients=N_CLIENTS, seq_len=SEQ)
        params = fed.init_params(jax.random.key(0))
        t0 = time.perf_counter()
        fed.run(params, x_parts, y)                    # compile + warm
        compile_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = fed.run(params, x_parts, y)
        us = (time.perf_counter() - t0) / steps * 1e6
        results[q] = us
        row(f"lm_async_q{q}", us,
            f"loss_drop={res.losses[:5].mean() - res.losses[-5:].mean():.4f};"
            f"compile_s={compile_s:.2f};max_delay={res.max_delay_seen};"
            f"wire_bytes_per_round={res.wire_bytes // steps};"
            f"wire_grad={res.transmits_gradients}")

    growth = results[QUERIES[-1]] / max(results[QUERIES[0]], 1e-9)
    row("lm_async_q_scaling", 0.0,
        f"round_time_growth_q{QUERIES[0]}->q{QUERIES[-1]}={growth:.2f}x;"
        f"linear_would_be={QUERIES[-1] // QUERIES[0]}x;"
        f"sublinear={growth < QUERIES[-1] / QUERIES[0]}")

    # DP point: noise channel on the loss downlink
    fed_dp = Federation.build(
        cfg, VFLConfig(mu=1e-3, lr_server=0.05, lr_client=1e-4),
        EngineConfig(method="cascaded", steps=steps, batch_size=8),
        n_clients=N_CLIENTS, seq_len=SEQ,
        noise=GaussianLossChannel(clip=10.0, epsilon=0.5, delta=1e-5))
    res_dp = fed_dp.run(fed_dp.init_params(jax.random.key(1)), x_parts, y)
    row("lm_async_dp", 0.0,
        f"eps={res_dp.epsilon:.2f};delta={res_dp.delta:.1e};"
        f"finite={np.isfinite(res_dp.epsilon)};"
        f"wire_grad={res_dp.transmits_gradients}")
    assert np.isfinite(res_dp.epsilon) and not res_dp.transmits_gradients
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false", default=True)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_lm_async(args.fast)


if __name__ == "__main__":
    main()
