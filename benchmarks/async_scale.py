"""Device-sharded async client block: block_size scaling sweep.

Runs the async engine's cascaded protocol at ``block_size ∈ {1, 4, 16}``
twice per point — on the single-device path and on the shard_map path
over a ``("data",)`` mesh of forced virtual host devices — and records

  * steady-state per-round wall clock (compile excluded; the runner is
    lru-cached, so the timed second ``run`` reuses the executable),
  * the sublinearity of per-round time in block_size (activating 16×
    the clients per round must cost well under 16× the wall clock), and
  * exactness: sharded ``block_size=1`` losses must match the existing
    single-device engine bitwise.

This module forces ``--xla_force_host_platform_device_count=8`` BEFORE
importing jax (like repro.launch.dryrun), so it must run in its own
process: ``PYTHONPATH=src python -m benchmarks.async_scale [--full]``
(``benchmarks.run --only async_scale`` spawns exactly that subprocess).
"""
from __future__ import annotations

import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8").strip()

import argparse     # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import VFLConfig                    # noqa: E402
from repro.configs.paper_mlp import PaperMLPConfig     # noqa: E402
from repro.core import async_engine                    # noqa: E402
from repro.data import make_classification, vertical_partition  # noqa: E402
from repro.launch.mesh import make_client_mesh         # noqa: E402
from repro.models import common, tabular               # noqa: E402

BLOCKS = (1, 4, 16)
N_CLIENTS = 16      # divisible by every shard count we sweep


def _setup(n: int = 512, f: int = 64, c: int = 10, server_embed: int = 64):
    cfg = PaperMLPConfig(n_features=f, n_classes=c, n_clients=N_CLIENTS,
                         client_embed=32, server_embed=server_embed)
    X, y = make_classification(0, n, f, c)
    Xp = jnp.asarray(vertical_partition(X, N_CLIENTS))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


def _n_shards(block: int) -> int:
    """Largest shard count ≤ device_count dividing both block and M."""
    d = min(jax.device_count(), block)
    while block % d or N_CLIENTS % d:
        d -= 1
    return d


def bench_async_scale(fast: bool = True, row=None, blocks=BLOCKS):
    """Emit name,us_per_call,derived rows.

    Returns ({(path, block): us}, bitwise_equal_at_b1, growths_by_path)."""
    if row is None:
        def row(name, us, derived):
            print(f"{name},{us:.1f},{derived}", flush=True)

    cfg, Xp, y, params = _setup()
    steps = 30 if fast else 120
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=4)
    results = {}
    losses = {}
    for block in blocks:
        shards = _n_shards(block)
        mesh = make_client_mesh(shards)
        for label, kw in (("single", {}), ("sharded", {"mesh": mesh})):
            ec = async_engine.EngineConfig(method="cascaded", steps=steps,
                                           batch_size=64, block_size=block)
            t0 = time.perf_counter()
            async_engine.run(ec, vfl, params, Xp, y, **kw)  # compile+warm
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = async_engine.run(ec, vfl, params, Xp, y, **kw)
            us = (time.perf_counter() - t0) / steps * 1e6
            results[(label, block)] = us
            losses[(label, block)] = res.losses
            row(f"async_scale_{label}_b{block}", us,
                f"shards={shards if label == 'sharded' else 1};"
                f"compile_s={compile_s:.2f};"
                f"wire_bytes_per_round={res.wire_bytes // steps}")

    exact = bool(np.array_equal(losses[("single", blocks[0])],
                                losses[("sharded", blocks[0])]))
    row("async_scale_equivalence", 0.0,
        f"sharded_b{blocks[0]}_losses_bitwise_match_single={exact}")

    growths = {}
    for label in ("single", "sharded"):
        lo, hi = results[(label, blocks[0])], results[(label, blocks[-1])]
        growths[label] = growth = hi / max(lo, 1e-9)
        row(f"async_scale_{label}_scaling", 0.0,
            f"round_time_growth_b{blocks[0]}->b{blocks[-1]}={growth:.2f}x;"
            f"linear_would_be={blocks[-1] // blocks[0]}x;"
            f"sublinear={growth < blocks[-1] / blocks[0]}")
    return results, exact, growths


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false", default=True)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    print(f"# devices={jax.device_count()}")
    _, exact, growths = bench_async_scale(args.fast)
    # enforce the acceptance criteria so CI fails on a regression, not
    # just prints it
    assert exact, "sharded block=1 losses diverged from single-device"
    linear = BLOCKS[-1] / BLOCKS[0]
    assert growths["sharded"] < linear, (
        f"sharded per-round time grew {growths['sharded']:.2f}x for "
        f"{linear:.0f}x the block — not sublinear")


if __name__ == "__main__":
    main()
