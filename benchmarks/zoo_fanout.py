"""Vectorized vs unrolled ZOO query fan-out (the tentpole speed claim).

For q ∈ {1, 4, 16} and both cascade code paths —
  * ``unrolled`` — the per-query Python-loop oracle (fused_dual=False):
    q separate server passes, trace size and dispatch linear in q
  * ``stacked``  — the vectorized lane path (fused_dual=True): ALL q
    directions drawn as stacked leaves, ONE vmapped server pass
— this records the one-time compile wall clock and the steady-state
per-round wall clock of the cascaded step. The acceptance claim is that
the stacked path's per-round time grows SUBLINEARLY in q (the unrolled
path is the linear baseline, and its compile time grows with q too).

Run: PYTHONPATH=src python -m benchmarks.zoo_fanout [--full]
(also exposed as ``--only zoo_fanout`` in benchmarks.run)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import VFLConfig
from repro.core import cascade
from repro.optim import sgd

QS = (1, 4, 16)


def _toy(vocab: int = 512, d: int = 64, classes: int = 32,
         batch: int = 64, seed: int = 0):
    """Embedding-client / linear-head-server split LM at bench scale."""
    key = jax.random.key(seed)
    params = {
        "embed": {"w": jax.random.normal(key, (vocab, d), jnp.float32) * 0.1},
        "head": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                        (d, classes), jnp.float32) * 0.1},
    }
    x = jax.random.randint(jax.random.fold_in(key, 2), (batch,), 0, vocab)
    y = jax.random.randint(jax.random.fold_in(key, 3), (batch,), 0, classes)

    def loss_fn(p, b):
        h = jnp.take(p["embed"]["w"], b["x"], axis=0)
        logits = h @ p["head"]["w"]
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, b["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold), {}

    return params, {"x": x, "y": y}, loss_fn


def bench_zoo_fanout(fast: bool = True, row=None, qs=QS):
    """Emit name,us_per_call,derived rows; returns {(path, q): us}."""
    if row is None:
        def row(name, us, derived):
            print(f"{name},{us:.1f},{derived}", flush=True)

    params, batch, loss_fn = _toy()
    n_rounds = 20 if fast else 100
    results = {}
    for fused in (False, True):
        label = "stacked" if fused else "unrolled"
        for q in qs:
            vfl = VFLConfig(mu=1e-3, zoo_queries=q, fused_dual=fused)
            opt = sgd(0.01)
            step = jax.jit(cascade.make_cascaded_step(
                loss_fn, ("embed",), vfl, opt))
            opt_state = opt.init(params)
            key = jax.random.key(1)

            t0 = time.perf_counter()
            p, s, out = step(params, opt_state, batch, key)
            jax.block_until_ready(out.loss)
            compile_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            for i in range(n_rounds):
                p, s, out = step(p, s, batch, jax.random.fold_in(key, i))
            jax.block_until_ready(out.loss)
            us = (time.perf_counter() - t0) / n_rounds * 1e6

            results[(label, q)] = us
            row(f"zoo_fanout_{label}_q{q}", us, f"compile_s={compile_s:.2f}")

    for label in ("unrolled", "stacked"):
        lo, hi = results[(label, qs[0])], results[(label, qs[-1])]
        growth = hi / max(lo, 1e-9)
        row(f"zoo_fanout_{label}_scaling", 0.0,
            f"round_time_growth_q{qs[0]}->q{qs[-1]}={growth:.2f}x;"
            f"linear_would_be={qs[-1] / qs[0]:.0f}x;"
            f"sublinear={growth < qs[-1] / qs[0]}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false", default=True)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_zoo_fanout(args.fast)


if __name__ == "__main__":
    main()
