"""Wire-plane fault sweep: population-engine throughput and wire cost
vs. injected drop rate and latency.

Runs the paper's tabular protocol (§VI-A-b MLP, 4 clients) through
``run_population`` over a grid of ``FaultPlan``s — drop ∈ {0, 0.1, 0.2}
× latency ∈ {0, 5}ms — and records, per point:

  * rounds/s (host wall clock) and virtual ms/round (the fault plan's
    deterministic latency accounting),
  * measured serialized bytes per round (the ledger's wire measurement)
    plus the legacy formula cross-check,
  * participation (admitted / activated), drop/straggler counters, and
    whether every scheduled round completed with finite losses.

Two standing invariants land in the emitted JSON for CI to assert:

  * ``no_deadlock_at_20pct_dropout`` — every 20%-drop point executed all
    of its rounds with finite losses (graceful degradation: a dropped
    client misses the round, the server still steps — nothing hangs);
  * ``zero_fault_matches_legacy`` — the drop=0/latency=0 point is
    bitwise-identical to the legacy direct-call engine.

Emits ``BENCH_wire.json`` with one dated ``history`` entry per run
(``benchmarks.history``).

Run: PYTHONPATH=src python -m benchmarks.wire_faults [--full] [--out P]
(also registered as ``benchmarks.run --only wire_faults``.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.history import append_history
from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.adapters import tabular_adapter
from repro.core.async_engine import EngineConfig
from repro.data import make_classification, vertical_partition
from repro.federation import Transport
from repro.wire import FaultPlan

DEFAULT_OUT = "BENCH_wire.json"
DROPS = (0.0, 0.1, 0.2)
LATENCIES_MS = (0.0, 5.0)


def bench_wire_faults(fast: bool = True, row=None, out=DEFAULT_OUT):
    """Sweep the fault grid; returns (and appends to ``out``) the record."""
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    steps = 40 if fast else 200
    X, y = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    y = jnp.asarray(y)
    from repro.models import common, tabular
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    ec = EngineConfig(method="cascaded", steps=steps, batch_size=8)
    adapter, wire = tabular_adapter(cfg), Transport("cascaded")

    legacy = async_engine.run(ec, vfl, params, Xp, y)
    sweep = []
    for drop in DROPS:
        for lat in LATENCIES_MS:
            # max_retries=1 keeps real losses in the trace at these drop
            # rates (the default budget of 3 retries absorbs nearly all)
            plan = FaultPlan(seed=0, drop=drop, latency_ms=lat,
                             jitter_ms=lat / 4, max_retries=1)
            t0 = time.perf_counter()
            res = async_engine.run_population(
                adapter, wire, vfl, ec, params, Xp, y, fault_plan=plan)
            wall = time.perf_counter() - t0
            s = res.stats
            executed = s["rounds_executed"]
            point = {
                "drop": drop, "latency_ms": lat,
                "rounds": executed,
                "completed_all_rounds": executed == steps,
                "finite_losses": bool(np.all(np.isfinite(res.losses))),
                "rounds_per_s": round(executed / max(wall, 1e-9), 2),
                "virtual_ms_per_round": round(s["virtual_ms"]
                                              / max(executed, 1), 3),
                "serialized_bytes_per_round": (res.serialized_bytes
                                               // max(executed, 1)),
                "formula_bytes_per_round": (s["formula_bytes"]
                                            // max(executed, 1)),
                "participation": round(s["participation"], 4),
                "uplink_drops": s["uplink_drops"],
                "downlink_drops": s["downlink_drops"],
                "degraded_rounds": s["degraded_rounds"],
                "retransmit_frames": s["retransmit_frames"],
                "loss_last": float(np.mean(res.losses[-5:])),
                "matches_legacy_bitwise": bool(
                    np.array_equal(legacy.losses, res.losses)),
            }
            sweep.append(point)
            if row is not None:
                row(f"wire_drop{drop}_lat{lat:g}",
                    wall / max(executed, 1) * 1e6,
                    f"participation={point['participation']};"
                    f"bytes_per_round={point['serialized_bytes_per_round']};"
                    f"degraded={point['degraded_rounds']}")

    at20 = [p for p in sweep if p["drop"] == 0.2]
    clean = [p for p in sweep if p["drop"] == 0.0
             and p["latency_ms"] == 0.0]
    results = {
        "config": {"n_clients": cfg.n_clients, "steps": steps,
                   "batch_size": ec.batch_size, "method": "cascaded",
                   "max_retries": 1},
        "sweep": sweep,
        "no_deadlock_at_20pct_dropout": bool(
            at20 and all(p["completed_all_rounds"] and p["finite_losses"]
                         for p in at20)),
        "zero_fault_matches_legacy": bool(
            clean and all(p["matches_legacy_bitwise"] for p in clean)),
    }
    append_history(out, results)
    if row is not None:
        row("wire_faults_invariants", 0.0,
            f"no_deadlock_at_20pct={results['no_deadlock_at_20pct_dropout']};"
            f"zero_fault_bitwise={results['zero_fault_matches_legacy']}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false",
                    default=True)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    res = bench_wire_faults(args.fast, row=None, out=args.out)
    print(json.dumps(res, indent=2))
    assert res["no_deadlock_at_20pct_dropout"], (
        "a 20% dropout run failed to complete — the population engine "
        "must degrade, not hang")


if __name__ == "__main__":
    main()
