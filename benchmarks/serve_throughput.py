"""Serve-plane throughput: the fused split-serve engine vs the seed loop.

Four ways to decode the same request mix at toy size, all through the
split party plane (clients embed, server owns backbone + caches):

* ``single_seed``  — the PR-4 baseline: one request at a time, one
  jitted step per token, Python dispatch + host sync per token.
* ``single_scan``  — one request at a time through the fused engine
  (chunked prefill + one compiled ``lax.scan`` decode, on-device
  sampling, one host transfer).
* ``batched``      — all requests as ONE (B, ·) batch through the fused
  engine: one embedding upload per step amortizes the uplink across the
  whole batch (the communication-efficiency lever of DPZV-style VFL).
* ``continuous``   — the ``ServeScheduler`` (paged caches, block-scan
  stepping, wave admission/retirement) at matched slot width: engine
  overhead vs the static batch, apples to apples.
* ``continuous_churn`` — the same scheduler with half as many slots as
  requests: two admission waves, retirement + re-admission mid-drain
  (the price of actually churning).

Every path is warmed up before timing (compile is reported separately by
the engine and excluded here) and timed best-of-3 — the toy drains are
millisecond-scale, so a single timing is scheduler-jitter-bound and the
mode ratios swing ±50% run to run. The bench verifies the guarantees
the speed must not cost: split decode stays bitwise-equal to global
decode, and per-request wire totals are identical across all paths.

Emits ``BENCH_serve.json`` (tokens/s per mode, uplink bytes per token,
speedups, invariant checks) — the serve-perf trajectory record, one
dated ``history`` entry per run (``benchmarks.history``).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--full] [--out P]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_OUT = "BENCH_serve.json"


def _toy_session(n_clients: int, seq_len: int):
    from repro.configs import get_config, reduced
    from repro.federation import Federation
    cfg = reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab_size=256, remat=False)
    fed = Federation.build(cfg, n_clients=n_clients, seq_len=seq_len)
    return cfg, fed


def _seed_single_decode(fed, params, prompts, gen_len, vocab):
    """The seed (PR 4) serve loop, inlined as the baseline: per-token
    jitted step, Python-dispatched, ``np.asarray`` host sync per token."""
    from repro.federation import serving
    step = serving.make_serve_step(fed.adapter, fed.n_clients, fed.seq_len)
    B, PL = prompts.shape
    caches = serving.zero_caches(fed.adapter, B, PL + gen_len)
    logits = None
    for t in range(PL):
        logits, caches = step(params, prompts[:, t:t + 1], caches, t)
    out = []
    for t in range(PL, PL + gen_len):
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        nxt = jnp.minimum(nxt, vocab - 1).astype(jnp.int32)
        out.append(np.asarray(nxt))            # host sync per token (seed)
        logits, caches = step(params, nxt[:, None], caches, t)
    return np.stack(out, axis=1)


def _global_greedy_decode(cfg, model, gp, toks, gen_len):
    """Global (unsplit) per-token decode — the bitwise oracle."""
    from repro.models.model_api import build_cache_specs
    B, PL = toks.shape
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        build_cache_specs(cfg, B, PL + gen_len),
        is_leaf=lambda x: hasattr(x, "logical"))
    decode = jax.jit(model.decode_fn, donate_argnums=(2,))
    logits = None
    for t in range(PL):
        logits, caches = decode(gp, {"tokens": toks[:, t:t + 1]}, caches, t)
    out = []
    for t in range(PL, PL + gen_len):
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, caches = decode(gp, {"tokens": nxt[:, None]}, caches, t)
    return np.stack(out, axis=1)


def bench_serve_throughput(fast: bool = True, row=None, out=DEFAULT_OUT):
    from repro.models import common
    from repro.models.model_api import build_model

    n_req = 8
    PL, GL = (8, 32) if fast else (16, 128)
    n_clients = 2
    seq_len = PL + GL
    cfg, fed = _toy_session(n_clients, seq_len)
    key = jax.random.key(0)
    model = build_model(cfg, max_seq=seq_len)
    gp = common.materialize(model.param_specs, key)
    params = fed.params_from_global(gp)
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (n_req, PL), 0, cfg.vocab_size))
    total_tokens = n_req * GL

    results = {}
    tokens_per_s = {}
    uplink_per_token = {}

    REPS = 3          # best-of: drains are ms-scale, single timings jitter

    def timed_best(fn):
        best, out = float("inf"), None
        for _ in range(REPS):
            tic = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - tic)
        return best, out

    def record(name, seconds, ledgers, tokens):
        tokens_per_s[name] = tokens / max(seconds, 1e-9)
        up = sum(l.bytes_by_kind().get("embedding", 0) for l in ledgers)
        uplink_per_token[name] = up / tokens
        if row is not None:
            row(f"serve_{name}", seconds / tokens * 1e6,
                f"tok_per_s={tokens_per_s[name]:.1f};"
                f"uplink_B_per_tok={uplink_per_token[name]:.0f}")

    # ------------------------------------------------ seed baseline -----
    from repro.core.privacy import Ledger
    def seed_drain():
        toks, leds = [], []
        for i in range(n_req):
            toks.append(_seed_single_decode(
                fed, params, jnp.asarray(prompts[i:i + 1]), GL,
                cfg.vocab_size))
            leds.append(fed.transport.account_serve(
                batch=1, embed=cfg.d_model, n_steps=PL + GL, n_gen=GL,
                ledger=Ledger()))
        return toks, leds
    _seed_single_decode(fed, params, jnp.asarray(prompts[:1]), GL,
                        cfg.vocab_size)                        # warm-up
    dt, (seed_tokens, seed_ledgers) = timed_best(seed_drain)
    record("single_seed", dt, seed_ledgers, total_tokens)
    seed_tokens = np.concatenate(seed_tokens, axis=0)

    # ------------------------------------- fused engine, one at a time --
    def scan_drain():
        rs = [fed.decode(params, prompts[i:i + 1], gen_len=GL)
              for i in range(n_req)]
        return [r.tokens for r in rs], [r.ledger for r in rs]
    fed.decode(params, prompts[:1], gen_len=GL)                # warm-up
    dt, (scan_tokens, scan_ledgers) = timed_best(scan_drain)
    record("single_scan", dt, scan_ledgers, total_tokens)
    scan_tokens = np.concatenate(scan_tokens, axis=0)

    # ------------------------------------------- fused engine, batched --
    fed.decode(params, prompts, gen_len=GL)                    # warm-up
    dt, rb = timed_best(lambda: fed.decode(params, prompts, gen_len=GL))
    record("batched", dt, [rb.ledger], total_tokens)

    # -------------------------------------------- continuous batching ---
    # two configs: matched slot width (engine overhead vs the static
    # batch, apples to apples) and half-width slots (the churn config —
    # two admission waves, retirement + re-admission mid-drain)
    def run_continuous(mb, gl=GL):
        srv = fed.serve(params, max_batch=mb)
        for i in range(n_req):
            srv.submit(prompts[i], gl)
        return srv, srv.run()
    run_continuous(n_req)                                      # warm-up
    srv, cres = min((run_continuous(n_req) for _ in range(3)),
                    key=lambda sc: sc[0].last_run_s)
    record("continuous", srv.last_run_s,
           [r.ledger for r in cres], total_tokens)
    run_continuous(max(1, n_req // 2))                         # warm-up
    srv_churn, cres_churn = min(
        (run_continuous(max(1, n_req // 2)) for _ in range(3)),
        key=lambda sc: sc[0].last_run_s)
    record("continuous_churn", srv_churn.last_run_s,
           [r.ledger for r in cres_churn], total_tokens)

    # ------------------- hygiene: sentinel over the steady-state loop ---
    # a warmed scheduler's block-stepping between admission and
    # retirement must touch the host ZERO times: no device->host fetch,
    # no retrace. The sentinel measures, the bench asserts — the same
    # instrumentation tests/test_analysis.py pins in CI.
    from repro.analysis import runtime as hygiene
    srv_h = fed.serve(params, max_batch=n_req)
    for i in range(n_req):
        srv_h.submit(prompts[i], GL)
    srv_h.run()                       # warm: compiles the whole pow2 ladder
    for i in range(n_req):
        srv_h.submit(prompts[i], GL)
    srv_h._admit_free_slots()

    def _occupied():
        return [s for s in range(srv_h.max_batch)
                if srv_h._slot_req[s] is not None]
    with hygiene.strict(check=False) as steady:
        while _occupied() and min(srv_h._remaining[s]
                                  for s in _occupied()) > 0:
            srv_h._block_step()
    srv_h._retire_wave()
    transfers_before = srv_h.host_transfers
    # count-mode over a whole warm drain: the only d2h events are the
    # per-wave retirement fetch (mirrored by scheduler.host_transfers)
    # and the per-request key_data read at admission
    for i in range(n_req):
        srv_h.submit(prompts[i], GL)
    with hygiene.strict(check=False) as whole:
        srv_h.run()
    waves = srv_h.host_transfers - transfers_before
    hygiene_ok = (steady.d2h == 0 and steady.compiles == 0
                  and whole.compiles == 0
                  and whole.d2h == waves + n_req)
    assert steady.d2h == 0, steady.d2h_sites
    assert steady.compiles == 0, steady.compiled_names

    # ------------------------- paged memory: short requests, same pool --
    # worst case (above) fills every slot to seq_len; a short-request mix
    # must leave most of the page pool untouched — peak pages tracks the
    # lengths actually in flight, not max_batch x seq_len
    srv_short, _ = run_continuous(max(1, n_req // 2), gl=max(1, GL // 4))

    # --------------------------------------------------- invariants -----
    global_tokens = _global_greedy_decode(cfg, model, gp,
                                          jnp.asarray(prompts), GL)
    split_equals_global = bool(np.array_equal(rb.tokens, global_tokens))
    paths_agree = bool(
        np.array_equal(seed_tokens, scan_tokens)
        and np.array_equal(scan_tokens, rb.tokens)
        and all(np.array_equal(r.tokens, scan_tokens[i])
                for i, r in enumerate(cres))
        and all(np.array_equal(r.tokens, scan_tokens[i])
                for i, r in enumerate(cres_churn)))
    per_req = seed_ledgers[0].total_bytes
    wire_unchanged = bool(
        all(l.total_bytes == per_req for l in scan_ledgers)
        and all(r.ledger.total_bytes == per_req
                for r in list(cres) + list(cres_churn))
        and rb.ledger.total_bytes == n_req * per_req)
    # continuous per-request ledgers are byte-identical Message sequences
    # to the solo (single_scan) ledgers, not just equal totals
    ledgers_exact = bool(all(
        r.ledger.messages == scan_ledgers[i].messages
        for rs in (cres, cres_churn) for i, r in enumerate(rs)))

    results = {
        "config": {"arch": cfg.arch_id, "d_model": cfg.d_model,
                   "vocab": cfg.vocab_size, "n_clients": n_clients,
                   "n_requests": n_req, "prompt_len": PL, "gen_len": GL},
        "tokens_per_s": {k: round(v, 1) for k, v in tokens_per_s.items()},
        "uplink_bytes_per_token": {k: round(v, 1)
                                   for k, v in uplink_per_token.items()},
        "speedup_scan_vs_seed": round(
            tokens_per_s["single_scan"] / tokens_per_s["single_seed"], 2),
        "speedup_batched_vs_seed": round(
            tokens_per_s["batched"] / tokens_per_s["single_seed"], 2),
        "speedup_continuous_vs_seed": round(
            tokens_per_s["continuous"] / tokens_per_s["single_seed"], 2),
        "continuous_vs_batched_ratio": round(
            tokens_per_s["batched"] / tokens_per_s["continuous"], 2),
        "continuous_churn_vs_batched_ratio": round(
            tokens_per_s["batched"] / tokens_per_s["continuous_churn"], 2),
        "paged_cache": {
            "page_size": srv.page_size,
            "pages_per_seq": srv.pages_per_seq,
            "full_len": {
                "slots": srv_churn.max_batch,
                "worst_case_pages": (srv_churn.max_batch
                                     * srv_churn.pages_per_seq),
                "peak_pages": srv_churn.allocator.peak_in_use},
            "short_mix": {
                "slots": srv_short.max_batch,
                "worst_case_pages": (srv_short.max_batch
                                     * srv_short.pages_per_seq),
                "peak_pages": srv_short.allocator.peak_in_use},
            "host_transfers_churn": srv_churn.host_transfers,
            "decode_steps_churn": srv_churn.steps,
        },
        "hygiene": {
            "steady_state_d2h": steady.d2h,
            "steady_state_retraces": steady.compiles,
            "warm_drain_d2h": whole.d2h,
            "warm_drain_retraces": whole.compiles,
            "retirement_waves": waves,
            "d2h_matches_waves_plus_keys": hygiene_ok,
        },
        "split_equals_global": split_equals_global,
        "all_paths_same_tokens": paths_agree,
        "wire_per_request_unchanged": wire_unchanged,
        "continuous_ledgers_byte_identical": ledgers_exact,
    }
    from benchmarks.history import append_history
    append_history(out, results)
    if row is not None:
        row("serve_speedup", 0.0,
            f"batched_vs_seed={results['speedup_batched_vs_seed']:.1f}x;"
            f"split_eq_global={split_equals_global};"
            f"wire_unchanged={wire_unchanged}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false",
                    default=True)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    res = bench_serve_throughput(args.fast, row=None, out=args.out)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
