"""Bench-trajectory persistence for ``BENCH_*.json`` artifacts.

Benchmarks used to overwrite their JSON file on every run, so the perf
trajectory across commits read as empty. Every emitter now goes through
:func:`append_history`, which keeps the file as::

    {"history": [{"commit": <short sha>, "timestamp": <iso utc>,
                  "results": {...}}, ...]}

appending one dated entry per run. A legacy flat-dict file is migrated
in place: its contents become the first history entry (commit
``"pre-history"``) before the new entry is appended, so no measurement
is lost. :func:`latest` is the read side — CI assertions check
``latest(path)`` instead of reaching into the file layout.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess


def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except OSError:  # pragma: no cover - git not installed
        return "unknown"


def load_history(path: str) -> dict:
    """The full ``{"history": [...]}`` document (migrating a legacy flat
    dict to a single ``pre-history`` entry); empty history if no file."""
    if not os.path.exists(path):
        return {"history": []}
    with open(path) as f:
        doc = json.load(f)
    if "history" not in doc:
        doc = {"history": [{"commit": "pre-history", "timestamp": None,
                            "results": doc}]}
    return doc


def append_history(path: str, results: dict) -> dict:
    """Append a dated ``results`` entry to ``path`` and return the doc."""
    doc = load_history(path)
    doc["history"].append({
        "commit": _commit(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "results": results,
    })
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return doc


def latest(path: str) -> dict:
    """The most recent run's results."""
    history = load_history(path)["history"]
    if not history:
        raise FileNotFoundError(f"no bench history at {path!r}")
    return history[-1]["results"]
