"""Serve-plane chaos harness: what failure policy costs, measured.

Four scenarios over the continuous-batching scheduler, all at toy size:

* ``preemption``   — the same request mix through a roomy pool (no
  starvation), a starved pool that WAITS, and a starved pool with
  ``preempt=True``: goodput vs preemption rate, with every result
  checked bitwise against its solo decode (preemption must cost wire
  bytes and wall clock, never correctness).
* ``deadlines``    — a burst behind one slot with per-request step
  deadlines: deadline-miss rate, goodput of the survivors, and the
  wasted-compute bill of the misses (queued expiries burn ZERO tokens —
  infeasibility is detected before admission).
* ``kill_recovery`` — a drain killed mid-flight (bounded ``run`` +
  ``snapshot``), persisted via ``fed.save(serve_state=...)``, restored
  into a FRESH Federation and finished: recovery latency (restore +
  re-install), tokens lost to the kill (must be 0 — the ledger and token
  streams resume bitwise), and the snapshot's byte size.
* ``poison``       — NaN injected into an in-flight request's cache
  pages: the request terminates ``status="poisoned"``, the engine
  survives, and the next tenant of the scrubbed pages decodes bitwise.

Emits ``BENCH_chaos.json`` — one dated ``history`` entry per run
(``benchmarks.history``), the robustness trajectory record the
``serve-chaos-smoke`` CI job asserts over.

    PYTHONPATH=src python -m benchmarks.serve_chaos [--full] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_OUT = "BENCH_chaos.json"


def _toy_session(n_clients: int, seq_len: int):
    from repro.configs import get_config, reduced
    from repro.federation import Federation
    cfg = reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab_size=256, remat=False)
    fed = Federation.build(cfg, n_clients=n_clients, seq_len=seq_len)
    return cfg, fed


def _submit_mix(srv, specs, key, vocab, salt):
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, salt + i), (pl,), 0, vocab))
        k = jax.random.fold_in(key, 10 * salt + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, gl, k))
    return reqs


def _solo_ok(fed, params, reqs, results, temperature):
    """Every "ok" result bitwise-equal to its solo decode?"""
    for (prompt, gl, k), res in zip(reqs, results):
        if res.status != "ok":
            continue
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=temperature, key=k)
        if not np.array_equal(res.tokens, solo.tokens[0]):
            return False
    return True


def bench_serve_chaos(fast: bool = True, row=None, out=DEFAULT_OUT):
    from repro.federation import Federation
    from repro.models import common
    from repro.models.model_api import build_model

    seq_len, n_clients = 32, 2
    cfg, fed = _toy_session(n_clients, seq_len)
    key = jax.random.key(0)
    model = build_model(cfg, max_seq=seq_len)
    gp = common.materialize(model.param_specs, key)
    params = fed.params_from_global(gp)
    temperature = 0.8

    # ---------------------------------------------- preemption sweep -----
    # (4+12 -> 4 pages) + (4+2 -> 2 pages) fills a 6-page pool; the short
    # request's retirement strands the next long head behind starvation
    specs = [(4, 12), (4, 2), (4, 12), (4, 12), (2, 9)]
    total_tokens = sum(gl for _, gl in specs)
    warm = fed.serve(params, max_batch=2, temperature=temperature)
    _submit_mix(warm, specs, key, cfg.vocab_size, salt=50)
    warm.run()                       # absorb compiles outside the timings
    modes = {}
    for name, kw in (
            ("roomy_pool", {}),
            ("starved_wait", {"page_size": 4, "n_pages": 8}),
            ("starved_preempt", {"page_size": 4, "n_pages": 8,
                                 "preempt": True})):
        srv = fed.serve(params, max_batch=2, temperature=temperature, **kw)
        reqs = _submit_mix(srv, specs, key, cfg.vocab_size, salt=50)
        results = srv.run()
        modes[name] = {
            "tokens_per_s": round(total_tokens / max(srv.last_run_s, 1e-9),
                                  1),
            "decode_steps": srv.steps,
            "preemptions": srv.preemptions,
            "preempt_rate": round(srv.preemptions / len(specs), 3),
            "all_ok": all(r.status == "ok" for r in results),
            "bitwise_solo": _solo_ok(fed, params, reqs, results,
                                     temperature),
            "pages_peak": srv.allocator.peak_in_use,
        }
        if row is not None:
            row(f"chaos_{name}", srv.last_run_s / total_tokens * 1e6,
                f"preemptions={srv.preemptions};"
                f"bitwise={modes[name]['bitwise_solo']}")
    # preempt vs wait on the SAME starved pool: what the re-prefill +
    # replay of evicted requests costs relative to just queueing
    preempt_goodput_ratio = round(
        modes["starved_preempt"]["tokens_per_s"]
        / max(modes["starved_wait"]["tokens_per_s"], 1e-9), 3)

    # ------------------------------------------------- deadline burst ----
    srv = fed.serve(params, max_batch=1, temperature=temperature)
    burst = [(4, 6)] * 6
    deadlines = [None, None, 15, 15, 15, 60]
    reqs = []
    for i, (pl, gl) in enumerate(burst):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 60 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 600 + i)
        srv.submit(prompt, gl, key=k, deadline=deadlines[i])
        reqs.append((prompt, gl, k))
    results = srv.run()
    ok = [r for r in results if r.status == "ok"]
    missed = [r for r in results if r.status == "deadline"]
    deadline = {
        "n_requests": len(burst),
        "missed": len(missed),
        "miss_rate": round(len(missed) / len(burst), 3),
        "goodput_tokens": int(sum(r.tokens.size for r in ok)),
        # queued expiries never reached a slot: zero compute burned
        "wasted_tokens": int(sum(r.tokens.size for r in missed)),
        "survivors_bitwise": _solo_ok(fed, params, reqs, results,
                                      temperature),
    }
    assert deadline["missed"] > 0, "deadline scenario never triggered"
    assert deadline["wasted_tokens"] == 0

    # ----------------------------------------------- kill + recovery -----
    churn = [(4, 8), (3, 5), (6, 6), (2, 3)]

    def _drain(bounded=None):
        s = fed.serve(params, max_batch=2, temperature=temperature)
        _submit_mix(s, churn, key, cfg.vocab_size, salt=70)
        s.run(max_steps=bounded)
        return s

    ref = _drain()
    srv = _drain(bounded=6)                  # "killed" with work in flight
    ckpt = tempfile.mkdtemp(prefix="serve_chaos_ck_")
    path = fed.save(ckpt, params, serve_state=srv.snapshot())
    snap_bytes = sum(
        os.path.getsize(os.path.join(dp, f))
        for dp, _, fs in os.walk(os.path.join(path, "serve_plane"))
        for f in fs)
    tic = time.perf_counter()
    fed2, params2, state = Federation.restore(path)
    srv2 = fed2.serve(params2, state=state.serve_state)
    recovery_latency_s = time.perf_counter() - tic     # restore + install
    srv2.run()
    ref_total = sum(r.tokens.size for r in ref.results.values())
    res_total = sum(r.tokens.size for r in srv2.results.values())
    resume_bitwise = (
        set(srv2.results) == set(ref.results)
        and all(np.array_equal(srv2.results[rid].tokens, r.tokens)
                and srv2.results[rid].status == r.status
                for rid, r in ref.results.items()))
    ledger_bitwise = all(
        srv2.results[rid].ledger.messages == r.ledger.messages
        for rid, r in ref.results.items())
    kill_recovery = {
        "killed_at_step": 6,
        "snapshot_bytes": snap_bytes,
        "recovery_latency_s": round(recovery_latency_s, 4),
        "tokens_lost_on_kill": int(ref_total - res_total),
        "resume_bitwise": bool(resume_bitwise),
        "ledger_bitwise": bool(ledger_bitwise),
    }
    assert kill_recovery["tokens_lost_on_kill"] == 0
    if row is not None:
        row("chaos_kill_recovery", recovery_latency_s * 1e6,
            f"tokens_lost={kill_recovery['tokens_lost_on_kill']};"
            f"bitwise={resume_bitwise}")

    # ------------------------------------------------ poison isolation ---
    srv = fed.serve(params, max_batch=2, temperature=temperature)
    prompt = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 80), (4,), 0, cfg.vocab_size))
    srv.submit(prompt, 8, key=jax.random.fold_in(key, 800))
    srv.run(max_steps=2)
    pg = int(srv._slot_pages[0][0])
    srv._caches_st = jax.tree.map(
        lambda st, plan: (st.at[:, pg].set(jnp.nan) if plan.pooled
                          else st),
        srv._caches_st, srv._plans)
    (poisoned_res,) = srv.run()
    k_b = jax.random.fold_in(key, 801)
    prompt_b = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 81), (4,), 0, cfg.vocab_size))
    srv.submit(prompt_b, 6, key=k_b)
    (clean_res,) = srv.run()
    solo_b = fed.decode(params, prompt_b[None], gen_len=6,
                        temperature=temperature, key=k_b)
    poison = {
        "status": poisoned_res.status,
        "engine_survived": clean_res.status == "ok",
        "next_request_bitwise": bool(
            np.array_equal(clean_res.tokens, solo_b.tokens[0])),
        "pages_leaked": srv.allocator.in_use,
    }
    assert poison["status"] == "poisoned"
    assert poison["pages_leaked"] == 0

    results = {
        "config": {"arch": cfg.arch_id, "d_model": cfg.d_model,
                   "vocab": cfg.vocab_size, "n_clients": n_clients,
                   "seq_len": seq_len, "temperature": temperature},
        "preemption": {
            "request_mix": specs,
            "modes": modes,
            "preempt_goodput_vs_wait": preempt_goodput_ratio,
        },
        "deadlines": deadline,
        "kill_recovery": kill_recovery,
        "poison": poison,
    }
    from benchmarks.history import append_history
    append_history(out, results)
    if row is not None:
        row("chaos_summary", 0.0,
            f"preempt_rate={modes['starved_preempt']['preempt_rate']};"
            f"miss_rate={deadline['miss_rate']};"
            f"tokens_lost={kill_recovery['tokens_lost_on_kill']}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="fast", action="store_false",
                    default=True)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    res = bench_serve_chaos(args.fast, row=None, out=args.out)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
