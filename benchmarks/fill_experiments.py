"""Inject generated tables into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
import os

from benchmarks.report import dryrun_table, load, roofline_table, sort_key

ROOT = os.path.join(os.path.dirname(__file__), "..")
MD = os.path.join(ROOT, "EXPERIMENTS.md")


def inject(text: str, marker: str, content: str) -> str:
    return text.replace(f"<!-- {marker} -->", content)


def main():
    rows = sorted(load("baseline"), key=sort_key)
    with open(MD) as f:
        text = f.read()
    text = inject(text, "ROOFLINE_TABLE", roofline_table(rows, "16x16"))
    text = inject(text, "DRYRUN_TABLE", dryrun_table(rows))
    with open(MD, "w") as f:
        f.write(text)
    print("injected", len(rows), "rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
