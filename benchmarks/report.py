"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(variant="baseline"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("variant", "baseline") != variant and "skipped" not in r:
            continue
        rows.append(r)
    return rows


def fmt_ms(s):
    return f"{s*1e3:9.2f}"


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bound | useful-flops | MFU@roofline | HBM GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP: {r['skipped']} | — | — | — |")
            continue
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        hbm = r["memory"]["peak_hbm_estimate_per_dev"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(rf['compute_s'])} | "
            f"{fmt_ms(rf['memory_s'])} | {fmt_ms(rf['collective_s'])} | "
            f"{rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['mfu']:.3f} | {hbm:.1f} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | kind | compile s | HBM GiB/dev | "
           "coll kinds (per-dev bytes, scanned-module) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            continue
        kinds = r["roofline"].get("coll_by_kind", {})
        ks = ";".join(f"{k}={v/2**20:.0f}MiB" for k, v in sorted(kinds.items())
                      if k != "total")
        hbm = r["memory"]["peak_hbm_estimate_per_dev"] / 2**30
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"{r['kind']} | {r['compile_s']} | {hbm:.1f} | {ks} |")
    return "\n".join(out)


def sort_key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"])
            if r["shape"] in SHAPE_ORDER else 9,
            r.get("mesh", "z"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--section", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    rows = sorted(load(args.variant), key=sort_key)
    if args.section == "roofline":
        print(roofline_table(rows, args.mesh))
    else:
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
