"""Phi-3-mini-3.8B — [dense] RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-mini-3.8b",
    family="dense",
    source="arXiv:2404.14219",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    act="swiglu",
    norm="rmsnorm",
    pos="rope",
)
