"""Zamba2-2.7B — [hybrid] Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
The Mamba2 layers form the trunk; a *shared* attention+MLP block (weights
reused) is applied every ``attn_every`` layers, approximating Zamba2's two
alternating shared blocks (see DESIGN.md §8).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    act="swiglu",
    norm="rmsnorm",
    pos="rope",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    n_shared_blocks=1,
)
