"""Configuration dataclasses for the VFL-Cascaded framework.

Every assigned architecture gets a ``ModelConfig`` describing the *global*
model (client embedding/frontend + server backbone).  ``ShapeConfig``
describes one of the four assigned input shapes.  ``VFLConfig`` describes
the party plane (number of clients, optimization method per party, ZOO
hyper-parameters).  ``TrainConfig`` is the top-level launcher config.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------
    arch_id: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""               # citation (arXiv / hf card)

    # transformer trunk -------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "swiglu"            # swiglu | gelu | relu2
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    pos: str = "rope"              # rope | learned | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False

    # attention variants -------------------------------------------------
    causal: bool = True
    window_size: int = 0           # 0 = full attention; >0 = sliding window

    # MoE -----------------------------------------------------------------
    n_experts: int = 0             # 0 = dense MLP
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden size
    n_shared_experts: int = 0
    first_k_dense: int = 0         # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    load_balance_coef: float = 0.01
    moe_groups: int = 16           # dispatch groups per row (= model-axis
                                   # size: local dispatch + all-to-all EP)

    # MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    n_mtp: int = 0                 # multi-token-prediction depth

    # SSM / Mamba2 ---------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # RWKV6 -----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128

    # hybrid (zamba2) ---------------------------------------------------------
    attn_every: int = 0            # shared attention block period; 0 = never
    n_shared_blocks: int = 1

    # encoder-decoder (whisper) --------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0           # precomputed frame count (stub frontend)

    # modality frontend stubs ------------------------------------------------
    n_vision_tokens: int = 0       # VLM: patch-embedding count per sample
    frontend_dim: int = 0          # stub embedding dim fed by input_specs()

    # numerics -----------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save no-batch-dim matmul
                                   # outputs: backward skips re-gathers at
                                   # the cost of saved projections)
    scan_layers: bool = True       # False: unrolled (cost-model probes)
    seq_shard_acts: bool = True    # sequence-parallel residual boundaries
    # §Perf variants (baseline = False; see EXPERIMENTS.md §Perf)
    iota_embed: bool = False       # one-hot-matmul embedding lookup: avoids
                                   # GSPMD's involuntary full remat on the
                                   # vocab-sharded gather
    rs_outputs: bool = False       # constrain attn/mlp outputs to the
                                   # seq-sharded layout so GSPMD emits
                                   # reduce-scatter instead of all-reduce
    mla_absorb: bool = False       # MLA decode scores in latent space
                                   # (never expands the cache to per-head
                                   # k/v — S·H·(nd+vd) -> S·(r+rd) reads)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        # pad so the vocab dim shards over the model axis (16) and lanes (128)
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """True if a 500k-token decode is meaningful & sub-quadratic here."""
        if self.is_encoder_decoder:
            return False               # whisper skip (see DESIGN.md)
        return True                    # ssm/hybrid native; attention via SWA

    @property
    def n_ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Analytic parameter count of the *global* model (approx, counts
        padded vocab). Used for roofline MODEL_FLOPS = 6·N·D."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = 0
        n += self.padded_vocab * d                     # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d                 # lm head
        if self.frontend_dim:
            n += self.frontend_dim * d                 # modality projector
        per_layer = 0
        if self.family == "ssm":                        # rwkv6
            per_layer += 4 * d * d + d * d // 2         # r,k,v,o + gates approx
            per_layer += 2 * d * self.d_ff              # channel mix
        else:
            if self.use_mla:
                per_layer += d * self.q_lora_rank
                per_layer += self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                per_layer += d * self.n_heads * hd          # q
                per_layer += 2 * d * self.n_kv_heads * hd   # k,v
                per_layer += self.n_heads * hd * d          # o
            if self.n_experts:
                ff_mults = 3 if self.act == "swiglu" else 2
                per_layer += d * self.n_experts * self.moe_d_ff * ff_mults
                per_layer += d * self.n_experts             # router
                if self.n_shared_experts:
                    per_layer += d * self.n_shared_experts * self.moe_d_ff * ff_mults
            else:
                ff_mults = 3 if self.act == "swiglu" else 2
                per_layer += d * self.d_ff * ff_mults
        if self.family == "hybrid":                     # mamba2 layers
            d_in = self.ssm_expand * d
            per_layer = 2 * d * d_in + d_in * d + d_in * self.ssm_state * 2  # in/out proj + B,C
        n += per_layer * L
        if self.family == "hybrid" and self.attn_every:
            # shared attention+mlp block(s)
            shared = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            shared += 3 * d * self.d_ff
            n += shared * self.n_shared_blocks
        if self.first_k_dense and self.n_experts:
            ff_mults = 3 if self.act == "swiglu" else 2
            n += self.first_k_dense * (d * self.d_ff * ff_mults - d * self.n_experts * self.moe_d_ff * ff_mults)
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attention
            enc = self.n_encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            cross = L * (4 * d * d)
            n += enc + cross
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE-aware) for MODEL_FLOPS."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        ff_mults = 3 if self.act == "swiglu" else 2
        moe_layers = self.n_layers - self.first_k_dense
        all_experts = moe_layers * self.d_model * self.n_experts * self.moe_d_ff * ff_mults
        active = moe_layers * self.d_model * (self.top_k + self.n_shared_experts) * self.moe_d_ff * ff_mults
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class VFLConfig:
    """Party-plane configuration (the paper's protocol)."""
    n_clients: int = 1
    client_opt: str = "zoo"        # zoo | foo  (paper: zoo)
    server_opt: str = "foo"        # foo | zoo  (paper: foo; zoo-vfl: zoo)
    asynchronous: bool = True
    # ZOO hyper-parameters (paper §III-B, §VI-A)
    mu: float = 1e-3               # smoothing parameter μ
    zoo_dist: str = "sphere"       # sphere (φ=d) | normal (φ=1)
    zoo_queries: int = 1           # q-point averaging (beyond-paper)
    active_rows_only: bool = False # perturb only touched embedding rows
    # async simulation
    max_delay: int = 16            # τ bound (assumption IV.7)
    activation_probs: Optional[Tuple[float, ...]] = None  # p_m; None=uniform
    # learning rates (paper tunes server/client separately)
    lr_server: float = 0.01
    lr_client: float = 0.01
    # §Perf: the clean + q perturbed forwards run as ONE vmapped server
    # pass over stacked lanes (FSDP weight all-gathers happen once instead
    # of 1+q times; compile time constant in q). False selects the unrolled
    # per-query oracle — test-only numerical reference, never production.
    fused_dual: bool = True
    # test-only: route zoo_gradient through the original per-query Python
    # loop instead of the vectorized lane stack (oracle for equality tests)
    zoo_unrolled_oracle: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = dataclasses.field(default_factory=ModelConfig)
    vfl: VFLConfig = dataclasses.field(default_factory=VFLConfig)
    shape: ShapeConfig = dataclasses.field(default_factory=lambda: INPUT_SHAPES["train_4k"])
    optimizer: str = "sgd"         # paper uses vanilla SGD for all frameworks
    momentum: float = 0.0
    weight_decay: float = 0.0      # λ g(w) regularizer of Eq. 1
    lr_schedule: str = "constant"
    warmup_steps: int = 0
    steps: int = 100
    log_every: int = 10
    seed: int = 0
    grad_clip: float = 0.0
    multi_pod: bool = False
    use_pallas: bool = False       # pallas kernels on TPU; XLA path on CPU


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, tiny vocab — runs a real fwd/train step on CPU."""
    small = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=32 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 256),
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.n_experts:
        small.update(n_experts=4, top_k=2, moe_d_ff=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     first_k_dense=min(cfg.first_k_dense, 1),
                     moe_groups=4)
    if cfg.use_mla:
        small.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                     qk_rope_dim=16, v_head_dim=32, n_mtp=min(cfg.n_mtp, 1))
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=min(cfg.ssm_state, 16) or 16,
                     ssm_head_dim=32, ssm_chunk=16, rwkv_head_dim=32,
                     rwkv_chunk=16)
    if cfg.attn_every:
        small.update(attn_every=2)
    if cfg.is_encoder_decoder:
        small.update(n_encoder_layers=2, encoder_seq=16)
    if cfg.n_vision_tokens:
        small.update(n_vision_tokens=4, frontend_dim=64)
    if cfg.frontend_dim and not cfg.n_vision_tokens:
        small.update(frontend_dim=64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
