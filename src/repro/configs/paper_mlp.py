"""The paper's own base experiment model (§VI-A-b).

Clients: single FC layer (feature_slice -> 128, ReLU).
Server: two FC layers (concat(clients) -> embed -> n_classes).
This config drives the tabular VFL experiments (Tables I/II, Figs 3-5a).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperMLPConfig:
    arch_id: str = "paper-mlp"
    n_features: int = 784            # MNIST-like flattened features
    n_classes: int = 10
    n_clients: int = 4
    client_embed: int = 128          # paper default client output size
    server_embed: int = 128          # paper sweeps {128, 256, 512}
    dtype: str = "float32"

    @property
    def features_per_client(self) -> int:
        return self.n_features // self.n_clients


CONFIG = PaperMLPConfig()
