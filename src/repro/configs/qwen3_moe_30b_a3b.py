"""Qwen3-30B-A3B — [moe] 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128e top-8, head_dim=128, qk-norm (Qwen3 family).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                # unused by MoE layers (all layers are MoE)
    vocab_size=151936,
    act="swiglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
)
