"""Config registry: ``get_config(arch_id)`` / ``--arch`` selection."""
from __future__ import annotations

from repro.configs import (
    deepseek_v3_671b,
    granite_20b,
    internlm2_20b,
    internvl2_26b,
    nemotron4_15b,
    paper_mlp,
    phi3_mini_3p8b,
    qwen3_moe_30b_a3b,
    rwkv6_7b,
    whisper_medium,
    zamba2_2p7b,
)

from repro.configs.base import (
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    VFLConfig,
    reduced,
)

ARCH_REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (
        internvl2_26b,
        zamba2_2p7b,
        qwen3_moe_30b_a3b,
        deepseek_v3_671b,
        internlm2_20b,
        granite_20b,
        rwkv6_7b,
        whisper_medium,
        phi3_mini_3p8b,
        nemotron4_15b,
    )
}

PAPER_MLP = paper_mlp.CONFIG


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCH_REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(list_archs())}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {', '.join(sorted(INPUT_SHAPES))}"
        ) from None


__all__ = [
    "ARCH_REGISTRY",
    "INPUT_SHAPES",
    "ModelConfig",
    "PAPER_MLP",
    "ShapeConfig",
    "TrainConfig",
    "VFLConfig",
    "get_config",
    "get_shape",
    "list_archs",
    "reduced",
]
