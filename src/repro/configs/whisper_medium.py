"""Whisper-medium — [audio] encoder-decoder, conv frontend (STUB)
[arXiv:2212.04356].

24L(enc)+24L(dec) d_model=1024 16H d_ff=4096 vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs()`` provides precomputed frame embeddings
(encoder_seq x frontend_dim); the client-side projector maps them to
d_model. long_500k is SKIPPED for this arch (enc-dec; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    pos="learned",
    is_encoder_decoder=True,
    n_encoder_layers=24,
    encoder_seq=1500,
    frontend_dim=1024,       # conv-stub output dim (== d_model for whisper)
)
