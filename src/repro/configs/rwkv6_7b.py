"""RWKV6-7B (Finch) — [ssm] data-dependent decay linear attention
[arXiv:2404.05892].

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.
Time-mix (wkv6 with data-dependent decay w_t) + channel-mix (relu^2).
Natively sub-quadratic: long_500k decode runs on the recurrent state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    act="relu2",             # rwkv channel-mix uses squared relu
    norm="layernorm",
    pos="none",
    rwkv_head_dim=64,
    rwkv_chunk=32,           # fp32-safe chunk for the factored decay form
)
