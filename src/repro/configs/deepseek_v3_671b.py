"""DeepSeek-V3 671B — [moe] MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L d_model=7168 128H d_ff(dense)=18432 per-expert d_ff=2048 vocab=129280.
First 3 layers are dense; the rest are MoE. Attention is Multi-head Latent
Attention (MLA): the KV cache stores only the compressed latent
(kv_lora_rank + qk_rope_dim per token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,              # dense layers (first_k_dense)
    vocab_size=129280,
    act="swiglu",
    norm="rmsnorm",
    pos="rope",
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    first_k_dense=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_mtp=1,
)
