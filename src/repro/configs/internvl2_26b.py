"""InternVL2-26B — [vlm] InternViT + InternLM2 backbone [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The InternViT-6B vision encoder is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (n_vision_tokens x
frontend_dim); the client-side projector maps them into the LM space.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    act="swiglu",
    norm="rmsnorm",
    pos="rope",
    n_vision_tokens=256,
    frontend_dim=3200,      # InternViT-6B hidden size
)
