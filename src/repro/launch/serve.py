"""Serving driver: batched prefill + decode with KV caches / SSM states.

Decoder-only archs serve SPLIT by default now — the ``Federation``
session's serve plane (``fed.decode``) keeps the training party split at
inference: client parties embed their token spans, the server owns
backbone + head + caches, and every step's wire traffic (one embedding
up, token ids down) lands in the Transport's ledger. The pre-session
global path survives as the back-compat shim (``n_clients=0``) and the
fallback for families that cannot cross the VFL wire (encoder-decoder /
VLM need a modality frontend on the wire).

On CPU this serves the reduced configs (the ``serve_decode`` example);
the same step functions are what the dry-run lowers for ``decode_32k`` /
``long_500k`` on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 16 --gen-len 16 [--clients 2]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced
from repro.models import common
from repro.models.model_api import build_cache_specs, build_model


def _zero_caches(cfg, batch: int, seq: int):
    specs = build_cache_specs(cfg, batch, seq)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), specs,
        is_leaf=lambda x: hasattr(x, "logical"))


def _splittable(cfg) -> bool:
    return not (cfg.is_encoder_decoder or cfg.family == "vlm")


def serve(arch: str, *, batch: int = 4, prompt_len: int = 16,
          gen_len: int = 16, use_reduced: bool = True, seed: int = 0,
          temperature: float = 0.0, n_clients: int = 0,
          continuous: bool = False, max_batch: int = 4,
          max_queue: int = None, preempt: bool = False,
          n_pages: int = None, deadline: int = None) -> dict:
    """``n_clients >= 1`` routes through the session's split serve plane
    (falling back to the global path for families that cannot split);
    ``n_clients=0`` is the pre-session global decode, bit-identical to
    the split path on replicated client tables. ``continuous=True``
    serves ``batch`` independent requests through the continuous-batching
    scheduler (``fed.serve``) over ``max_batch`` slots instead of one
    fused batch — with the failure policy exposed: ``max_queue`` bounds
    admission (the driver drains on :class:`QueueFull` and retries),
    ``preempt``/``n_pages`` enable page-pool preemption under memory
    pressure, and ``deadline`` gives every request that many scheduler
    steps to retire (expired requests come back ``status="deadline"``)."""
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg, remat=False)
    if n_clients and _splittable(cfg):
        if continuous:
            return _serve_continuous(arch, cfg, batch=batch,
                                     prompt_len=prompt_len,
                                     gen_len=gen_len, seed=seed,
                                     temperature=temperature,
                                     n_clients=n_clients,
                                     max_batch=max_batch,
                                     max_queue=max_queue, preempt=preempt,
                                     n_pages=n_pages, deadline=deadline)
        return _serve_federated(arch, cfg, batch=batch,
                                prompt_len=prompt_len, gen_len=gen_len,
                                seed=seed, temperature=temperature,
                                n_clients=n_clients)
    res = _serve_global(arch, cfg, batch=batch, prompt_len=prompt_len,
                        gen_len=gen_len, seed=seed, temperature=temperature)
    if n_clients:
        res["fallback"] = (f"{cfg.family}/encdec family needs a modality "
                           "frontend on the wire; served global")
    return res


# ------------------------------------------------- split (session) path ---

def _build_session(cfg, *, n_clients: int, prompt_len: int, gen_len: int,
                   seed: int):
    """(fed, key, params) for a serving run — the party span split is
    rounded up to cover the full served window."""
    from repro.federation import Federation
    max_seq = prompt_len + gen_len
    seq_len = -(-max_seq // n_clients) * n_clients
    fed = Federation.build(cfg, n_clients=n_clients, seq_len=seq_len)
    key = jax.random.key(seed)
    params = common.materialize(fed.model.param_specs, key)
    return fed, key, params


def _serve_federated(arch: str, cfg, *, batch: int, prompt_len: int,
                     gen_len: int, seed: int, temperature: float,
                     n_clients: int) -> dict:
    fed, key, params = _build_session(cfg, n_clients=n_clients,
                                      prompt_len=prompt_len,
                                      gen_len=gen_len, seed=seed)
    toks = jax.random.randint(jax.random.fold_in(key, 1),
                              (batch, prompt_len), 0, cfg.vocab_size)
    res = fed.decode(params, toks, gen_len=gen_len,
                     temperature=temperature, key=key)
    gen = res.tokens
    assert gen.shape == (batch, gen_len)
    assert np.isfinite(np.asarray(res.logits, np.float32)).all()
    return {
        "arch": arch, "batch": batch, "mode": "federated",
        "clients": n_clients,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "prefill_s": round(res.prefill_s, 2),
        "compile_s": round(res.compile_s, 2),
        "decode_tok_per_s": round(batch * gen_len
                                  / max(res.decode_s, 1e-9), 1),
        "wire_bytes": res.wire_bytes,
        "wire_has_gradients": res.transmits_gradients,
        "sample_output": gen[0, :8].tolist(),
    }


# ------------------------------------------- continuous-batching path ---

def _serve_continuous(arch: str, cfg, *, batch: int, prompt_len: int,
                      gen_len: int, seed: int, temperature: float,
                      n_clients: int, max_batch: int,
                      max_queue: int = None, preempt: bool = False,
                      n_pages: int = None, deadline: int = None) -> dict:
    from repro.federation import QueueFull
    fed, key, params = _build_session(cfg, n_clients=n_clients,
                                      prompt_len=prompt_len,
                                      gen_len=gen_len, seed=seed)
    srv = fed.serve(params, max_batch=max_batch, temperature=temperature,
                    max_queue=max_queue, preempt=preempt, n_pages=n_pages)
    # draw every request's prompt in one batched device op and fetch the
    # whole (batch, prompt_len) block with a single transfer — same
    # per-request fold_in streams as drawing them one by one
    prompts = np.asarray(jax.vmap(
        lambda i: jax.random.randint(jax.random.fold_in(key, 1000 + i),
                                     (prompt_len,), 0, cfg.vocab_size))(
                                         jnp.arange(batch)))
    queue_retries = 0
    for i in range(batch):
        while True:
            try:
                srv.submit(prompts[i], gen_len,
                           key=jax.random.fold_in(key, i),
                           deadline=deadline)
                break
            except QueueFull:
                # bounded admission is recoverable by design: drain a
                # block, then offer the request again
                queue_retries += 1
                srv.run(max_steps=1)
    srv.run()
    results = [srv.results[rid] for rid in sorted(srv.results)]
    assert len(results) == batch
    ok = [r for r in results if r.status == "ok"]
    total_tokens = sum(r.tokens.size for r in ok)
    statuses = {}
    for r in results:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    return {
        "arch": arch, "batch": batch, "mode": "continuous",
        "clients": n_clients, "slots": max_batch,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "steps": srv.steps,
        "compile_s": round(srv.compile_s, 2),
        "decode_tok_per_s": round(total_tokens / max(srv.last_run_s, 1e-9),
                                  1),
        "statuses": statuses,
        "preemptions": srv.preemptions,
        "deadline_misses": srv.deadline_misses,
        "queue_retries": queue_retries,
        "wire_bytes": sum(r.wire_bytes for r in results),
        "wire_has_gradients": any(r.transmits_gradients for r in results),
        "sample_output": (ok[0] if ok else results[0]).tokens[:8].tolist(),
    }


# ---------------------------------------------- global back-compat shim ---

def _serve_global(arch: str, cfg, *, batch: int, prompt_len: int,
                  gen_len: int, seed: int, temperature: float) -> dict:
    max_seq = prompt_len + gen_len
    model = build_model(cfg, max_seq=max_seq)
    key = jax.random.key(seed)
    params = common.materialize(model.param_specs, key)

    toks = jax.random.randint(jax.random.fold_in(key, 1),
                              (batch, prompt_len), 0, cfg.vocab_size)
    caches = _zero_caches(cfg, batch, max_seq)
    decode = jax.jit(model.decode_fn, donate_argnums=(2,))

    extra = {}
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        frames = jnp.zeros((batch, cfg.encoder_seq, cfg.frontend_dim),
                           jnp.bfloat16)
        extra["enc_out"] = encdec.encode(cfg, params, frames)

    t0 = time.time()
    # prefill: feed prompt tokens through the decode path one at a time
    # (prefill-as-decode; the batched prefill program is exercised by the
    # prefill_32k dry-run shape)
    logits = None
    for t in range(prompt_len):
        logits, caches = decode(params, {"tokens": toks[:, t:t + 1], **extra},
                                caches, t)
    t_prefill = time.time() - t0

    out_tokens = []
    t0 = time.time()
    for t in range(prompt_len, max_seq):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(
                jax.random.fold_in(key, 100 + t), lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        # tokens stay on device; the host sees ONE (B, gen_len) fetch
        # after the loop instead of gen_len per-token syncs
        out_tokens.append(nxt)
        logits, caches = decode(params, {"tokens": nxt[:, None], **extra},
                                caches, t)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.stack(out_tokens, axis=1))
    assert gen.shape == (batch, gen_len)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return {
        "arch": arch, "batch": batch, "mode": "global",
        "prompt_len": prompt_len, "gen_len": gen_len,
        "prefill_s": round(t_prefill, 2),
        "decode_tok_per_s": round(batch * gen_len / max(t_decode, 1e-9), 1),
        "sample_output": gen[0, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    # 0 = the pre-session global path; >=1 serves split via fed.decode
    ap.add_argument("--clients", type=int, default=2)
    # continuous batching: drain --batch requests through --max-batch slots
    ap.add_argument("--continuous", action="store_true")
    ap.add_argument("--max-batch", type=int, default=4)
    # failure policy (continuous path only): bounded admission, page-pool
    # preemption, and a per-request step deadline
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--preempt", action="store_true")
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--deadline", type=int, default=None)
    args = ap.parse_args()
    print(json.dumps(serve(args.arch, batch=args.batch,
                           prompt_len=args.prompt_len, gen_len=args.gen_len,
                           temperature=args.temperature,
                           use_reduced=args.reduced,
                           n_clients=args.clients,
                           continuous=args.continuous,
                           max_batch=args.max_batch,
                           max_queue=args.max_queue,
                           preempt=args.preempt,
                           n_pages=args.n_pages,
                           deadline=args.deadline), indent=2))


if __name__ == "__main__":
    main()
