import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination with 512 placeholder host devices, and extract the roofline
terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k [--multi-pod] [--method cascaded]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]

The VERY FIRST lines above set XLA_FLAGS before any jax import — jax locks
the device count at first init.  Never import this module from code that
needs the real device topology.
"""

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (INPUT_SHAPES, VFLConfig, get_config,  # noqa: E402
                           get_shape, list_archs)
from repro.core.async_engine import EngineConfig  # noqa: E402
from repro.core.methods import METHOD_ALIASES, canonical_method  # noqa: E402
from repro.federation import Federation  # noqa: E402
from repro.launch import costmodel  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.models import common  # noqa: E402
from repro.models.model_api import (LONG_WINDOW,  # noqa: E402
                                    build_cache_specs, build_input_specs,
                                    build_model)
from repro.optim import sgd  # noqa: E402
from repro.sharding.rules import (ACT_RULES, PARAM_RULES,  # noqa: E402
                                  PARAM_RULES_NO_FSDP)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _specs_shardings(spec_tree, mesh, rules):
    return common.shardings(spec_tree, mesh, rules)


def _replicated(mesh):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return "enc-dec arch: 500k-token decode not meaningful (DESIGN.md)"
    return ""


@dataclasses.dataclass
class Variant:
    """Hillclimb switches (§Perf). Defaults = paper-faithful baseline."""
    name: str = "baseline"
    window_gather: bool = False     # gathered sliding-window decode read
    gather_experts: bool = False    # tiny-batch MoE expert weight gather
    remat: bool = True              # activation checkpointing in train
    zoo_queries: int = 1
    iota_embed: bool = False        # one-hot-matmul embedding lookup
    rs_outputs: bool = False        # reduce-scatter TP output projections
    mla_absorb: bool = False        # latent-space MLA decode
    no_fsdp: bool = False           # TP/EP only: no weight gathers
    fused_dual: bool = False        # one vmapped clean+perturbed pass
    remat_policy: str = "full"      # full | dots
    capacity_factor: float = 0.0    # >0 overrides the MoE capacity factor


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            variant: Variant = Variant(), method: str = "cascaded",
            verbose: bool = True) -> dict:
    method = canonical_method(method)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    window = 0
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        # attention archs need sub-quadratic attention at 500k: SWA variant
        window = LONG_WINDOW
    if variant.remat is False:
        cfg = dataclasses.replace(cfg, remat=False)
    if shape.is_decode:
        cfg = dataclasses.replace(cfg, remat=False)   # no backward pass
    if variant.iota_embed or variant.rs_outputs or variant.mla_absorb:
        cfg = dataclasses.replace(cfg, iota_embed=variant.iota_embed,
                                  rs_outputs=variant.rs_outputs,
                                  mla_absorb=variant.mla_absorb)
    if variant.remat_policy != "full":
        cfg = dataclasses.replace(cfg, remat_policy=variant.remat_policy)
    if variant.capacity_factor:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=variant.capacity_factor)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    model = build_model(cfg, max_seq=shape.seq_len, window=window,
                        window_gather=variant.window_gather,
                        gather_experts=variant.gather_experts)

    param_rules = PARAM_RULES_NO_FSDP if variant.no_fsdp else PARAM_RULES
    params_abs = common.abstract(model.param_specs)
    params_sh = _specs_shardings(model.param_specs, mesh, param_rules)

    data_specs = build_input_specs(cfg, shape)
    data_abs = common.abstract(data_specs)
    data_sh = _specs_shardings(data_specs, mesh, ACT_RULES)

    t0 = time.time()
    backward = shape.kind == "train"

    with mesh:
        if shape.kind == "train":
            vfl = VFLConfig(zoo_queries=variant.zoo_queries,
                            fused_dual=variant.fused_dual)
            opt = sgd(0.01)
            # per-method lowering through the session: the same
            # Federation that drives real training resolves the step
            # factory (cascaded / vafl / split / zoo-vfl), with the
            # variant-built model (window/remat switches) injected
            fed = Federation.build(cfg, vfl, EngineConfig(method=method),
                                   seq_len=shape.seq_len, model=model)
            step = fed.sync_step(opt)
            opt_state_abs = {"step": jax.ShapeDtypeStruct((), jnp.int32)}
            key_abs = jax.eval_shape(lambda: jax.random.key(0))
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, _replicated(mesh), data_sh,
                              _replicated(mesh)),
            ).lower(params_abs, opt_state_abs, data_abs, key_abs)
        elif shape.kind == "prefill":
            lowered = jax.jit(
                model.forward_fn,
                in_shardings=(params_sh, data_sh),
            ).lower(params_abs, data_abs)
        else:  # decode
            cache_specs = build_cache_specs(cfg, shape.global_batch,
                                            shape.seq_len)
            cache_abs = common.abstract(cache_specs)
            cache_sh = _specs_shardings(cache_specs, mesh, ACT_RULES)
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                model.decode_fn,
                in_shardings=(params_sh, data_sh, cache_sh,
                              _replicated(mesh)),
            ).lower(params_abs, data_abs, cache_abs, pos_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw = rl.analyze(compiled, compiled.as_text(), cfg, shape, n_dev,
                     backward=backward)
    # trip-count-corrected costs from unrolled probes (scan bodies are
    # counted once by cost_analysis — see launch/costmodel.py)
    corr = costmodel.corrected_costs(
        cfg, shape, mesh, window=window,
        window_gather=variant.window_gather,
        gather_experts=variant.gather_experts,
        zoo_queries=variant.zoo_queries,
        param_rules=param_rules, fused_dual=variant.fused_dual)
    roof = rl.Roofline(
        flops=corr["flops"], bytes_accessed=corr["bytes"],
        coll_bytes=corr["coll_bytes"], coll_by_kind=raw.coll_by_kind,
        n_devices=n_dev,
        model_flops=rl.model_flops_for(cfg, shape, backward=backward))

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "method": method,
        "variant": variant.name,
        "window": window,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "peak_hbm_estimate_per_dev": (mem.argument_size_in_bytes
                                          + mem.output_size_in_bytes
                                          + mem.temp_size_in_bytes),
        },
        "roofline": roof.as_dict(),
        "roofline_raw_scanned": raw.as_dict(),
        "cost_segments": corr.get("per_segment"),
    }
    if verbose:
        r = result["roofline"]
        hbm_gb = result["memory"]["peak_hbm_estimate_per_dev"] / 2**30
        print(f"[dryrun] {arch:22s} {shape_name:12s} "
              f"{result['mesh']:8s} {variant.name:14s} "
              f"compute={r['compute_s']*1e3:9.3f}ms "
              f"memory={r['memory_s']*1e3:9.3f}ms "
              f"coll={r['collective_s']*1e3:9.3f}ms "
              f"bound={r['bottleneck']:10s} hbm={hbm_gb:6.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return result


def save_result(res: dict, out_dir: str = OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    # the cascaded artifacts keep their historical names (report.py
    # tables key on them); baseline-method sweeps get a method suffix
    suffix = ("" if res.get("method", "cascaded") == "cascaded"
              else f"_{res['method']}")
    name = f"{res['arch']}_{res['shape']}_{res.get('mesh','skip')}" \
           f"_{res.get('variant','baseline')}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(res, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    # train shapes lower the chosen framework's step through the session
    # (every alias spelling accepted, canonicalized at the boundary)
    ap.add_argument("--method", default="cascaded",
                    choices=sorted(METHOD_ALIASES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--window-gather", action="store_true")
    ap.add_argument("--gather-experts", action="store_true")
    ap.add_argument("--iota-embed", action="store_true")
    ap.add_argument("--rs-outputs", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fused-dual", action="store_true")
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--variant-name", default=None)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    any_opt = (args.window_gather or args.gather_experts or args.iota_embed
               or args.rs_outputs or args.mla_absorb or args.no_fsdp
               or args.fused_dual or args.remat_policy != "full"
               or args.capacity_factor)
    variant = Variant(
        name=args.variant_name or ("baseline" if not any_opt else "opt"),
        window_gather=args.window_gather,
        gather_experts=args.gather_experts,
        iota_embed=args.iota_embed,
        rs_outputs=args.rs_outputs,
        mla_absorb=args.mla_absorb,
        no_fsdp=args.no_fsdp,
        fused_dual=args.fused_dual,
        remat_policy=args.remat_policy,
        capacity_factor=args.capacity_factor)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    res = run_one(arch, shape, multi_pod=mp, variant=variant,
                                  method=args.method)
                    save_result(res, args.out)
                    if "skipped" in res:
                        print(f"[dryrun] {arch:22s} {shape:12s} SKIP: "
                              f"{res['skipped']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs lowered + compiled OK")


if __name__ == "__main__":
    main()
