"""End-to-end cascaded VFL training driver.

Trains any assigned architecture with the paper's cascaded hybrid
optimization (ZOO client / FOO server) — or any baseline method — on
synthetic LM data. On CPU this runs the reduced configs (smoke/examples);
on a real cluster the same code path drives the production mesh.

Training is constructed through the ``repro.federation`` session API:
``Federation.build(cfg, vfl, engine_cfg)`` resolves the model plane, the
canonical method name and the wire (ledger + optional DP noise channel),
and this driver just pumps batches through ``fed.sync_step(...)``. The
CLI accepts every spelling in ``repro.core.methods.METHOD_ALIASES`` and
canonicalizes at the boundary — step factories and the ledger only ever
see canonical names.

Checkpointing goes through the session lifecycle: ``--checkpoint`` calls
``fed.save`` (per-party directories + step + optimizer/schedule state +
ledger totals + spent DP budget) and ``--resume PATH`` continues from a
saved session — the restored run re-derives the same batches, per-step
keys and the ORIGINAL schedule horizon from the saved state, so it
matches an uninterrupted run allclose with ledger and (ε, δ) totals
exactly continued (exactly equivalent for step-stationary schedules;
decaying schedules keep their saved total_steps rather than silently
re-stretching, running at the tail lr past the original horizon).

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --reduced --steps 100 --method cascaded [--dp-epsilon 1.0]
    PYTHONPATH=src python -m repro.launch.train --resume ck/ --steps 200 \
        --checkpoint ck2/
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import VFLConfig, get_config, list_archs, reduced
from repro.core.async_engine import EngineConfig, PopulationConfig
from repro.core.methods import METHOD_ALIASES, canonical_method
from repro.core.privacy import GaussianLossChannel
from repro.data import lm_token_batches, vertical_partition
from repro.federation import Federation, SessionState
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import common
from repro.optim import make_schedule, sgd
from repro.sharding.rules import PARAM_RULES
from repro.wire import FaultPlan


def train(arch: str = "", *, steps: int = 100, batch: int = 8,
          seq: int = 128, method: str = "cascaded", lr: float = 0.01,
          mu: float = 1e-3, lr_client: float = 0.0,
          use_reduced: bool = True, seed: int = 0,
          log_every: int = 10, zoo_queries: int = 1,
          active_rows: bool = False, production_mesh: bool = False,
          checkpoint_path: str = "", schedule: str = "constant",
          noise: Optional[GaussianLossChannel] = None,
          resume: str = "") -> dict:
    start = 0
    state = SessionState()
    sched_total = steps
    if resume:
        # the saved session is the source of truth for everything that
        # must match the original run (model/vfl/engine/noise configs and
        # the driver knobs stashed in the metadata); ``steps`` stays a
        # TOTAL step count, so resume at step k with steps=2k runs k more
        fed, params, state = Federation.restore(resume)
        meta = _driver_metadata(resume, state.metadata)
        arch, method = meta["arch"], fed.transport.method
        batch, seq, seed = meta["batch"], meta["seq"], meta["seed"]
        lr, schedule = meta["lr"], meta["schedule"]
        # rebuild the EXACT schedule the saved run trained under — a
        # decaying schedule must not silently re-stretch to the new total
        # (resume-equivalence to an uninterrupted run is exact for
        # step-stationary schedules; decaying ones continue the original
        # horizon and run at their tail value past it)
        sched_total = meta.get("schedule_total_steps", steps)
        zoo_queries = fed.vfl.zoo_queries
        cfg = fed.model_cfg
        noise = fed.transport.noise
        start = state.step
        if steps <= start:
            raise ValueError(
                f"--steps {steps} is a total step count; the resumed "
                f"session is already at step {start}")
    else:
        cfg = get_config(arch)
        if use_reduced:
            cfg = reduced(cfg)
        method = canonical_method(method)
        vfl = VFLConfig(mu=mu, lr_server=lr, lr_client=lr_client or lr,
                        zoo_queries=zoo_queries, active_rows_only=active_rows)
        fed = Federation.build(cfg, vfl,
                               EngineConfig(method=method, steps=steps,
                                            batch_size=batch),
                               seq_len=seq, noise=noise)
        if not lr_client:
            lr_client = _normalized_lr_client(fed, lr)
            fed.vfl = dataclasses.replace(vfl, lr_client=lr_client)

    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    model = fed.model
    opt = sgd(make_schedule(schedule, lr, total_steps=sched_total))
    step_fn = fed.sync_step(opt)

    key = jax.random.key(seed)
    shardings = common.shardings(model.param_specs, mesh, PARAM_RULES)
    if not resume:
        params = common.materialize(model.param_specs, key)
    params = jax.device_put(params, shardings)
    opt_state = (state.opt_state if state.opt_state is not None
                 else opt.init(params))

    # deterministic batch stream: a resumed run skips the first ``start``
    # draws, so step i consumes the exact batch the uninterrupted run did
    data = itertools.islice(
        lm_token_batches(seed + 1, cfg.vocab_size, batch, seq),
        start, steps)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    losses, t0 = [], time.time()
    with mesh:
        for i, nb in enumerate(data, start=start):
            b = {k: jnp.asarray(v) for k, v in nb.items()}
            if cfg.family == "vlm":
                b["patch_embeds"] = jnp.zeros(
                    (batch, cfg.n_vision_tokens, cfg.frontend_dim),
                    jnp.bfloat16)
            if cfg.is_encoder_decoder:
                b["frames"] = jnp.zeros(
                    (batch, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16)
            params, opt_state, out = jit_step(
                params, opt_state, b, jax.random.fold_in(key, i))
            losses.append(float(out.loss))
            if i % log_every == 0:
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"|g_c|={float(out.grad_client_norm):.3e} "
                      f"|g_s|={float(out.grad_server_norm):.3e}", flush=True)

    wall = time.time() - t0
    n_new = steps - start
    # the Transport owns the wire: one ledger call covers this segment
    # (one activated client party — the embedding owner — per sync round),
    # EXTENDING the restored ledger so lifetime totals continue exactly
    ledger = fed.transport.account(batch=batch, embed=cfg.d_model,
                                   zoo_queries=zoo_queries, n_rounds=n_new,
                                   ledger=state.ledger)
    dp_releases = state.dp_releases
    if noise is not None:
        dp_releases += fed.transport.releases(n_rounds=n_new,
                                              zoo_queries=zoo_queries)
    result = {
        "arch": arch, "method": method, "steps": steps,
        "loss_first": losses[0], "loss_last": float(np.mean(losses[-5:])),
        "wall_s": round(wall, 1),
        "steps_per_s": round(n_new / wall, 2),
        "wire_bytes_per_round": ledger.total_bytes // max(steps, 1),
        "wire_has_gradients": ledger.transmits_gradients,
    }
    if resume:
        result["resumed_from"], result["start_step"] = resume, start
    if noise is not None:
        eps, delta = fed.transport.privacy_spent(dp_releases)
        result["dp_epsilon"], result["dp_delta"] = eps, delta
    if checkpoint_path:
        fed.save(checkpoint_path, params, step=steps, opt_state=opt_state,
                 ledger=ledger, dp_releases=dp_releases,
                 metadata={"arch": arch, "batch": batch, "seq": seq,
                           "seed": seed, "lr": lr, "schedule": schedule,
                           "schedule_total_steps": sched_total})
        result["checkpoint"] = checkpoint_path
    return result


def _normalized_lr_client(fed: Federation, lr: float) -> float:
    """Per-party lr (paper §VI-A-d tunes them separately): the sphere
    two-point estimator's norm scales ~√d·|∇|, so normalize the client lr
    by √d_client to keep update magnitudes FOO-comparable."""
    from repro.core.partition import split_params
    model = fed.model
    client_spec, _ = split_params(model.param_specs, model.client_keys)
    d_client = sum(int(np.prod(s.shape))
                   for s in jax.tree.leaves(
                       client_spec,
                       is_leaf=lambda x: hasattr(x, "logical")))
    return lr / max(np.sqrt(d_client), 1.0)


def train_population(arch: str = "", *, steps: int = 60, batch: int = 8,
                     seq: int = 32, method: str = "cascaded",
                     n_clients: int = 4, rows: int = 128, lr: float = 0.05,
                     mu: float = 1e-3, lr_client: float = 0.0,
                     use_reduced: bool = True, seed: int = 0,
                     zoo_queries: int = 1, fault_drop: float = 0.0,
                     fault_latency_ms: float = 0.0,
                     fault_jitter_ms: float = 0.0, fault_seed: int = 0,
                     admission_ms: Optional[float] = None,
                     staleness_bound: Optional[int] = None,
                     until: int = 0, checkpoint_path: str = "",
                     noise: Optional[GaussianLossChannel] = None,
                     resume: str = "") -> dict:
    """The population engine over the wire plane (``fed.run_population``).

    Unlike the sync driver, the round horizon is FIXED at first build
    (``--steps`` = total rounds T; the activation schedule and fault
    stream are drawn over T once). ``--until k`` stops after round k and
    — with ``--checkpoint`` — saves the full async-plane state, so a
    later ``--resume`` continues the SAME horizon bitwise; ``--steps``
    is ignored on resume.
    """
    if resume:
        fed, params, state = Federation.restore(resume)
        meta = state.metadata
        if state.async_state is None or meta.get("engine") != "population":
            raise ValueError(
                f"checkpoint {resume!r} has no async plane — it was not "
                "written by the population driver")
        arch, rows, seq = meta["arch"], meta["rows"], meta["seq"]
        seed, n_clients = meta["seed"], fed.n_clients
        cfg = fed.model_cfg
        # the saved run's fault stream and admission policy, NOT the CLI's
        # — resume-equivalence requires replaying the identical plan
        fault = (FaultPlan(**meta["fault_plan"]) if meta.get("fault_plan")
                 else FaultPlan.none())
        population = (PopulationConfig(**meta["population"])
                      if meta.get("population") else None)
        noise = fed.transport.noise
    else:
        cfg = get_config(arch)
        if use_reduced:
            cfg = reduced(cfg)
        method = canonical_method(method)
        vfl = VFLConfig(mu=mu, lr_server=lr, lr_client=lr_client,
                        zoo_queries=zoo_queries)
        fed = Federation.build(cfg, vfl,
                               EngineConfig(method=method, steps=steps,
                                            batch_size=batch, seed=seed),
                               n_clients=n_clients, seq_len=seq,
                               noise=noise)
        if not lr_client:
            fed.vfl = dataclasses.replace(
                vfl, lr_client=_normalized_lr_client(fed, lr))
        params = fed.init_params(jax.random.key(seed))
        state = SessionState()
        fault = FaultPlan(seed=fault_seed, drop=fault_drop,
                          latency_ms=fault_latency_ms,
                          jitter_ms=fault_jitter_ms)
        population = (PopulationConfig(admission_ms=admission_ms,
                                       staleness_bound=staleness_bound)
                      if (admission_ms or staleness_bound) else None)

    horizon = fed.engine.steps
    stop_at = min(until, horizon) if until else horizon
    # deterministic dataset: the resumed run regenerates the exact rows
    # the original drew, so every round samples identical batches
    toks = next(lm_token_batches(seed + 1, cfg.vocab_size, rows,
                                 seq))["tokens"]
    x_parts = jnp.asarray(vertical_partition(toks, n_clients))
    y = jnp.asarray(toks)

    t0 = time.time()
    res = fed.run_population(
        params, x_parts, y, fault_plan=fault, population=population,
        state=state.async_state, ledger=state.ledger,
        dp_releases=state.dp_releases,
        until=stop_at if stop_at < horizon else None)
    wall = time.time() - t0

    stats = res.stats
    executed = stats["rounds_executed"]
    result = {
        "arch": arch, "method": fed.transport.method,
        "engine": "population", "clients": n_clients,
        "rounds": int(res.state.step), "horizon": horizon,
        "loss_first": float(res.losses[0]),
        "loss_last": float(np.mean(res.losses[-5:])),
        "wall_s": round(wall, 1),
        "rounds_per_s": round(executed / max(wall, 1e-9), 2),
        "virtual_ms": stats["virtual_ms"],
        "participation": stats["participation"],
        "max_delay_seen": int(res.max_delay_seen),
        # the §V wire, measured (serialized frames) vs the formula
        "serialized_bytes": int(res.serialized_bytes),
        "formula_bytes": int(stats["formula_bytes"]),
        "control_bytes": int(res.control_bytes),
        "wire_has_gradients": res.transmits_gradients,
        "faults": {
            "drop": fault.drop, "latency_ms": fault.latency_ms,
            "jitter_ms": fault.jitter_ms,
            "uplink_drops": stats["uplink_drops"],
            "downlink_drops": stats["downlink_drops"],
            "stragglers": stats["stragglers"],
            "forced": stats["forced"],
            "degraded_rounds": stats["degraded_rounds"],
        },
    }
    if resume:
        result["resumed_from"] = resume
        result["start_step"] = int(state.async_state.step)
    if noise is not None:
        result["dp_epsilon"], result["dp_delta"] = res.epsilon, res.delta
    if checkpoint_path:
        fed.save(checkpoint_path, res.params, step=res.state.step,
                 ledger=res.ledger, dp_releases=res.dp_releases,
                 async_state=res.state,
                 metadata={"engine": "population", "arch": arch,
                           "rows": rows, "seq": seq, "seed": seed,
                           "fault_plan": {
                               "seed": fault.seed, "drop": fault.drop,
                               "latency_ms": fault.latency_ms,
                               "jitter_ms": fault.jitter_ms},
                           "population": (
                               None if population is None else
                               {"admission_ms": population.admission_ms,
                                "staleness_bound":
                                    population.staleness_bound})})
        result["checkpoint"] = checkpoint_path
    return result


def _driver_metadata(path: str, meta: dict) -> dict:
    """Validate the driver knobs ``fed.save`` stashed in the session."""
    missing = {"arch", "batch", "seq", "seed", "lr", "schedule"} - set(meta)
    if missing:
        raise ValueError(
            f"checkpoint {path!r} was not written by the train driver "
            f"(metadata missing {sorted(missing)})")
    return meta


def build_parser() -> argparse.ArgumentParser:
    """CLI (factored out so tests can assert the alias surface)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b",
                    choices=list_archs())
    # every spelling in the shared alias table is accepted; only the
    # canonical name travels past this boundary
    ap.add_argument("--method", default="cascaded",
                    choices=sorted(METHOD_ALIASES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--zoo-queries", type=int, default=1)
    ap.add_argument("--active-rows", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--checkpoint", default="")
    # continue a saved session; --steps then means TOTAL steps (the run
    # does steps - saved_step more). Model/method/data knobs come from
    # the checkpoint, not the CLI.
    ap.add_argument("--resume", default="")
    ap.add_argument("--schedule", default="constant")
    ap.add_argument("--seed", type=int, default=0)
    # DP loss channel (0 = off): clip + per-release (ε, δ) target
    ap.add_argument("--dp-epsilon", type=float, default=0.0)
    ap.add_argument("--dp-delta", type=float, default=1e-5)
    ap.add_argument("--dp-clip", type=float, default=10.0)
    # --- population engine (the wire plane) ---------------------------
    # sync: jitted lockstep driver (default). population: N client
    # parties behind repro.wire endpoints with fault injection and a
    # durable async plane (--until k + --checkpoint, then --resume).
    ap.add_argument("--engine", choices=("sync", "population"),
                    default="sync")
    ap.add_argument("--clients", type=int, default=4,
                    help="population: number of client parties")
    ap.add_argument("--rows", type=int, default=128,
                    help="population: dataset rows each round samples")
    ap.add_argument("--until", type=int, default=0,
                    help="population: stop after this round (0 = run the "
                         "full --steps horizon); pair with --checkpoint")
    ap.add_argument("--fault-drop", type=float, default=0.0)
    ap.add_argument("--fault-latency-ms", type=float, default=0.0)
    ap.add_argument("--fault-jitter-ms", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--admission-ms", type=float, default=0.0,
                    help="population: straggler budget in virtual ms")
    ap.add_argument("--staleness-bound", type=int, default=0,
                    help="population: force-activate clients staler than "
                         "this many rounds")
    return ap


def main():
    args = build_parser().parse_args()
    noise = (GaussianLossChannel(clip=args.dp_clip, epsilon=args.dp_epsilon,
                                 delta=args.dp_delta)
             if args.dp_epsilon > 0 else None)
    if args.engine == "population":
        res = train_population(
            args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
            method=canonical_method(args.method), n_clients=args.clients,
            rows=args.rows, lr=args.lr, mu=args.mu, seed=args.seed,
            use_reduced=args.reduced, zoo_queries=args.zoo_queries,
            fault_drop=args.fault_drop,
            fault_latency_ms=args.fault_latency_ms,
            fault_jitter_ms=args.fault_jitter_ms,
            fault_seed=args.fault_seed,
            admission_ms=args.admission_ms or None,
            staleness_bound=args.staleness_bound or None,
            until=args.until, checkpoint_path=args.checkpoint,
            noise=noise, resume=args.resume)
    else:
        res = train(args.arch, steps=args.steps, batch=args.batch,
                    seq=args.seq, method=canonical_method(args.method),
                    lr=args.lr, mu=args.mu, use_reduced=args.reduced,
                    seed=args.seed, zoo_queries=args.zoo_queries,
                    active_rows=args.active_rows,
                    production_mesh=args.production_mesh,
                    checkpoint_path=args.checkpoint,
                    schedule=args.schedule, noise=noise,
                    resume=args.resume)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
