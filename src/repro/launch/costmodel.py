"""Trip-count-corrected roofline costs via unrolled probe lowering.

XLA's ``cost_analysis()`` counts a while-loop (lax.scan) body ONCE, so a
scanned 61-layer model under-reports flops/bytes/collectives by ~L×. The
fix: lower small UNROLLED probe programs (scan_layers=False) at the full
global batch/mesh, with 1 vs 2 instances of each repeated segment, and
solve the linear model

    cost(counts) = base + Σ_seg slope_seg · counts[seg]

per metric (flops, bytes, collective bytes). The full-size scanned program
is still compiled by the dry-run as the lowering/memory proof; this module
only supplies the corrected cost terms.

Segments per family:
  dense/vlm/ssm : layers
  moe           : moe layers (+ leading dense layers for deepseek)
  hybrid        : super-blocks (attn_every mambas + shared attn)
  enc-dec       : encoder layers, decoder layers
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, VFLConfig
from repro.core.cascade import make_cascaded_step
from repro.models import common
from repro.models.model_api import (build_cache_specs,
                                    build_input_specs, build_model)
from repro.optim import sgd
from repro.sharding.rules import ACT_RULES, PARAM_RULES
from repro.utils.hlo import collective_bytes


def _segment_counts(cfg: ModelConfig) -> Dict[str, int]:
    if cfg.is_encoder_decoder:
        return {"enc": cfg.n_encoder_layers, "dec": cfg.n_layers}
    if cfg.family == "hybrid":
        return {"super": cfg.n_layers // cfg.attn_every}
    if cfg.n_experts and cfg.first_k_dense:
        return {"dense": cfg.first_k_dense,
                "moe": cfg.n_layers - cfg.first_k_dense}
    return {"layers": cfg.n_layers}


def _probe_cfg(cfg: ModelConfig, counts: Dict[str, int]) -> ModelConfig:
    kw = dict(scan_layers=False)
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=counts["enc"], n_layers=counts["dec"])
    elif cfg.family == "hybrid":
        kw.update(n_layers=counts["super"] * cfg.attn_every)
    elif cfg.n_experts and cfg.first_k_dense:
        kw.update(first_k_dense=counts["dense"],
                  n_layers=counts["dense"] + counts["moe"])
    else:
        kw.update(n_layers=counts["layers"])
    return dataclasses.replace(cfg, **kw)


def _probe_points(cfg: ModelConfig) -> List[Dict[str, int]]:
    segs = sorted(_segment_counts(cfg))
    pts = [{s: 1 for s in segs}]
    for s in segs:
        p = {t: 1 for t in segs}
        p[s] = 2
        pts.append(p)
    return pts


def _measure(cfg: ModelConfig, shape: ShapeConfig, mesh, *, window: int,
             window_gather: bool, gather_experts: bool,
             zoo_queries: int, param_rules=None,
             fused_dual: bool = False) -> Tuple[float, float, float]:
    """Lower+compile one probe; return per-device (flops, bytes, coll_bytes)."""
    model = build_model(cfg, max_seq=shape.seq_len, window=window,
                        window_gather=window_gather,
                        gather_experts=gather_experts)
    p_abs = common.abstract(model.param_specs)
    p_sh = common.shardings(model.param_specs, mesh,
                            param_rules or PARAM_RULES)
    d_specs = build_input_specs(cfg, shape)
    d_abs = common.abstract(d_specs)
    d_sh = common.shardings(d_specs, mesh, ACT_RULES)
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    with mesh:
        if shape.kind == "train":
            step = make_cascaded_step(
                model.loss_fn, model.client_keys,
                VFLConfig(zoo_queries=zoo_queries, fused_dual=fused_dual),
                sgd(0.01), vocab=cfg.padded_vocab)
            opt_abs = {"step": jax.ShapeDtypeStruct((), jnp.int32)}
            key_abs = jax.eval_shape(lambda: jax.random.key(0))
            compiled = jax.jit(step, in_shardings=(p_sh, rep, d_sh, rep)) \
                .lower(p_abs, opt_abs, d_abs, key_abs).compile()
        elif shape.kind == "prefill":
            compiled = jax.jit(model.forward_fn, in_shardings=(p_sh, d_sh)) \
                .lower(p_abs, d_abs).compile()
        else:
            c_specs = build_cache_specs(cfg, shape.global_batch, shape.seq_len)
            c_abs = common.abstract(c_specs)
            c_sh = common.shardings(c_specs, mesh, ACT_RULES)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            compiled = jax.jit(model.decode_fn,
                               in_shardings=(p_sh, d_sh, c_sh, rep)) \
                .lower(p_abs, d_abs, c_abs, pos).compile()

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll.get("total", 0)))


def corrected_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    window: int = 0, window_gather: bool = False,
                    gather_experts: bool = False, zoo_queries: int = 1,
                    param_rules=None, fused_dual: bool = False
                    ) -> Dict[str, float]:
    """Probe, solve, extrapolate. Returns per-device
    {flops, bytes, coll_bytes} for the FULL layer counts."""
    if shape.is_decode:
        cfg = dataclasses.replace(cfg, remat=False)
    segs = sorted(_segment_counts(cfg))
    pts = _probe_points(cfg)
    rows, ys = [], []
    for pt in pts:
        pcfg = _probe_cfg(cfg, pt)
        m = _measure(pcfg, shape, mesh, window=window,
                     window_gather=window_gather,
                     gather_experts=gather_experts, zoo_queries=zoo_queries,
                     param_rules=param_rules, fused_dual=fused_dual)
        rows.append([1.0] + [float(pt[s]) for s in segs])
        ys.append(m)
    A = np.array(rows)                      # (n_probes, 1+n_segs)
    Y = np.array(ys)                        # (n_probes, 3)
    coef, *_ = np.linalg.lstsq(A, Y, rcond=None)
    full = np.array([1.0] + [float(_segment_counts(cfg)[s]) for s in segs])
    flops, nbytes, coll = full @ coef
    return {"flops": max(float(flops), 0.0),
            "bytes": max(float(nbytes), 0.0),
            "coll_bytes": max(float(coll), 0.0),
            "segments": {s: _segment_counts(cfg)[s] for s in segs},
            "per_segment": {s: {"flops": float(coef[1 + i, 0]),
                                "bytes": float(coef[1 + i, 1]),
                                "coll_bytes": float(coef[1 + i, 2])}
                            for i, s in enumerate(segs)}}
