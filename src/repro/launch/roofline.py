"""Roofline term derivation from compiled dry-run artifacts.

TPU v5e targets (per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s per link

``compiled.cost_analysis()`` describes the per-device SPMD module, so all
three terms are computed per-device:

    compute_s    = HLO_flops_per_dev / PEAK_FLOPS
    memory_s     = HLO_bytes_per_dev / HBM_BW
    collective_s = collective_bytes_per_dev / ICI_BW

collective bytes are parsed from the compiled HLO text
(``repro.utils.hlo``) since cost_analysis does not report them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.utils.hlo import collective_bytes

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# The CPU backend used for the dry-run legalizes bf16 -> f32 before
# partitioning, so every large tensor's bytes (HBM traffic and collective
# operands) are reported at 2x their TPU size. All large tensors in our
# models are bf16 (fp32 appears only in norm scales / scalars), so we apply
# a uniform 0.5 correction to byte counts. Raw numbers are preserved in the
# dry-run JSONs under roofline_raw_scanned.
BF16_LEGALIZATION_CORRECTION = 0.5


@dataclasses.dataclass
class Roofline:
    flops: float                   # per-device HLO flops
    bytes_accessed: float          # per-device HLO bytes
    coll_bytes: float              # per-device collective bytes
    coll_by_kind: Dict[str, int]
    n_devices: int
    model_flops: float             # analytic 6·N·D (or 2·N·D inference)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return (self.bytes_accessed * BF16_LEGALIZATION_CORRECTION) / HBM_BW

    @property
    def collective_s(self) -> float:
        return (self.coll_bytes * BF16_LEGALIZATION_CORRECTION) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound on step latency (no overlap assumed worst
        term dominates; perfect overlap = max of the three)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops — catches remat/dispatch waste."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilisation at the roofline bound."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.n_devices * PEAK_FLOPS)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_by_kind": dict(self.coll_by_kind),
            "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape, *, backward: bool) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens
    (inference); decode processes 1 token per sequence."""
    n_active = cfg.active_param_count()
    if shape.is_decode:
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if backward else 2.0
    return mult * n_active * tokens


def analyze(compiled, lowered_text: Optional[str], cfg, shape, n_devices: int,
            *, backward: bool) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):                # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    text = lowered_text or compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        flops=flops, bytes_accessed=nbytes,
        coll_bytes=float(coll.get("total", 0)), coll_by_kind=coll,
        n_devices=n_devices,
        model_flops=model_flops_for(cfg, shape, backward=backward))
