"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Single-device mesh for CPU smoke runs (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
