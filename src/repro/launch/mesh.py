"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the real single device.

``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer JAX releases; :func:`_compat_make_mesh` feature-detects
them and falls back to the plain ``make_mesh`` signature so the same code
runs on the pinned JAX.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def _compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """make_mesh with Auto axis types where supported, plain mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Single-device mesh for CPU smoke runs (axes exist, size 1)."""
    return _compat_make_mesh((1, 1), ("data", "model"))


def make_client_mesh(n_shards: Optional[int] = None) -> jax.sharding.Mesh:
    """1-D ``("data",)`` mesh over the first ``n_shards`` local devices.

    This is the axis the async engine shard_maps the activated client
    block over (the ``"clients"`` logical rows of the embedding table
    partition along it). ``n_shards=None`` takes every visible device;
    tests/benches pass an explicit divisor of the block size so the same
    code runs on 1 real CPU device and on
    ``--xla_force_host_platform_device_count=8`` virtual meshes."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"n_shards={n_shards} out of range for {len(devices)} devices")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))
