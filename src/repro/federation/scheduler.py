"""Continuous batching for the split serve plane, on paged caches.

The sglang-style serving loop, with the VFL party split kept intact: a
:class:`ServeScheduler` owns ``max_batch`` fixed SLOTS whose
sequence-indexed cache state lives in a shared page pool
(:mod:`repro.federation.paging`) addressed through per-slot block
tables, admits queued requests into free slots mid-flight, and drives
the whole churning mix with compiled MULTI-STEP decode blocks.

The first scheduler revision lost 6.6× to the static batched path by
doing host work per token: a Python dispatch per step, a per-active-slot
ledger call per token, and a blocking device→host fetch inside
``_retire``. This revision keeps the host out of the loop:

* **block stepping** — ``remaining`` lives on device and derives the
  active mask, so a compiled ``lax.scan`` block of K steps needs no host
  intervention. K is the largest power of two that no active request
  outlives (``K <= min(remaining)``), so a block never overshoots a
  retirement, the compiled-block set is bounded by ``log2(seq_len)``
  programs, and an occupied slot is never stepped while logically done.
* **wave retirement** — after a block, every slot whose host-mirrored
  ``remaining`` hit zero retires together: ONE batched device→host fetch
  per wave (``host_transfers`` counts them — O(requests), not O(steps)).
* **deferred accounting** — prefill wire traffic is logged at admission
  (``n_steps=prompt_len, n_gen=0``) and generation at retirement
  (``n_steps=gen_len, n_gen=gen_len``). ``Transport.account_serve``
  appends ``serve_messages(b, e, with_token=False) * (n_steps - n_gen)``
  then ``serve_messages(b, e) * n_gen``, so admission + retirement
  produce exactly ``up×prompt_len`` then ``(up+token)×gen_len`` — the
  byte-identical Message list a solo ``fed.decode`` logs in its single
  ``account_serve(n_steps=prompt_len+gen_len, n_gen=gen_len)`` call, and
  what the per-step ``account_serve_step`` metering used to build one
  token at a time.
* **wave admission** — the queue's head run of equal-length prompts is
  admitted as ONE wave: one batched chunk-prefill and one compiled
  install scatter cover the whole wave (width-1 waves reuse a persistent
  dense ``(1, seq_len)`` buffer — only the small recurrent state leaves
  are re-zeroed; stale KV rows beyond the prompt are masked exactly).
  Admission issues only async dispatches — no host sync, and admission
  is page-gated FIFO: an undersized pool makes requests wait for pages,
  never reorder.

Sampling uses the same ``fold_in(request_key, 100 + t)`` stream as the
solo path, so a request's tokens do not depend on what shared the batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tags
from repro.core.adapters import ModelAdapter
from repro.core.privacy import Ledger
from repro.federation import paging, serving


@dataclasses.dataclass
class ServeRequest:
    """A queued generation request (one sequence; batch=1 on the wire)."""
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    gen_len: int
    key: jax.Array                  # typed PRNG key — solo-compatible stream
    ledger: Ledger = dataclasses.field(default_factory=Ledger)


@dataclasses.dataclass
class RequestResult:
    """One drained request: its tokens and its exact wire ledger."""
    rid: int
    tokens: np.ndarray              # (gen_len,) sampled token ids
    ledger: Ledger
    prompt_len: int
    admitted_at: int                # scheduler step index at admission
    finished_at: int                # scheduler step index at retirement

    @property
    def wire_bytes(self) -> int:
        return self.ledger.total_bytes

    @property
    def transmits_gradients(self) -> bool:
        return self.ledger.transmits_gradients


@functools.lru_cache(maxsize=64)
def make_paged_decode_block(adapter: ModelAdapter, n_clients: int,
                            seq_len: int, temperature: float,
                            vocab_size: int, page_size: int,
                            n_slots: int, n_steps: int):
    """A compiled block of ``n_steps`` continuous-batching decode steps.

    Per step every slot samples from its carried logits on its own key
    stream, the owning client embeds the token, and the server runs ONE
    batched paged decode over all slots (``server_decode_paged``). The
    active mask derives on device from ``remaining > 0``, so the host
    never touches the loop; a slot that hits zero simply freezes (its
    uplink embedding is zeroed, its recurrent state held, its KV row
    routed to the trash page).

    Inactive slots still pay the backbone FLOPs for their batch row:
    under a batched (or vmapped) step XLA lowers per-row ``cond`` to
    ``select`` — both branches run — and a dense matmul has no ragged
    batch. The engine bounds that waste structurally instead: the block
    length never exceeds the smallest active ``remaining`` (an occupied
    slot is never stepped past its retirement) and the host loop stops
    the moment no slot is occupied, so idle rows only ride along while
    the queue is empty and other slots still stream. True row skipping
    needs slot compaction across bucketed batch sizes (a recompile per
    occupancy) or ragged kernels — a TPU-pass item (see ROADMAP).
    """
    serving._require_serve_plane(adapter)
    if adapter.server_decode_paged is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no server_decode_paged hook; "
            "the paged continuous scheduler needs it")
    span = seq_len // n_clients

    def block(params, tables, keydata_st, logits_st, caches_st, t_st,
              gen_pos_st, rem_st, gen_buf_st):
        sl = jnp.arange(n_slots)

        @tags.wire("up", accounted_by="Transport.account_serve",
                   kind="embedding",
                   reason="continuous-batching decode step: each active "
                          "slot's client embeds its sampled token and the "
                          "embedding crosses to server_decode_paged; the "
                          "traffic is metered deferred — prompt uploads at "
                          "admission, generation at retirement (see module "
                          "docstring)")
        def body(carry, _):
            logits, caches, t, gen_pos, rem, gen_buf = carry
            active = (rem > 0).astype(jnp.int32)
            nxt = jax.vmap(
                lambda lg, kd, tt: serving.sample_token(
                    lg, jax.random.wrap_key_data(kd), tt, temperature,
                    vocab_size))(logits, keydata_st, t)        # (n, 1)
            nxt = nxt[:, 0]
            idx = jnp.clip(gen_pos, 0, gen_buf.shape[1] - 1)
            gen_buf = gen_buf.at[sl, idx].set(
                jnp.where(active > 0, nxt, gen_buf[sl, idx]))

            m = jnp.where(active > 0, t, 0) // span

            def embed_one(tok, mi):
                client_m = jax.tree.map(lambda a: a[mi], params["clients"])
                return adapter.client_embed(client_m, tok[None, None])

            e = jax.vmap(embed_one)(nxt, m)[:, 0]              # (n, 1, d)
            e = e * (active > 0).astype(e.dtype)[:, None, None]
            lg, caches = adapter.server_decode_paged(
                params["server"], e, caches, tables, t, active, page_size)
            return (lg[:, None], caches, t + active, gen_pos + active,
                    rem - active, gen_buf), None

        carry, _ = jax.lax.scan(
            body, (logits_st, caches_st, t_st, gen_pos_st, rem_st,
                   gen_buf_st), None, length=n_steps)
        return carry

    return jax.jit(block, donate_argnums=(3, 4, 5, 6, 7, 8))


@functools.lru_cache(maxsize=32)
def make_install_prog(adapter: ModelAdapter, seq_len: int):
    """The slot-install scatter: move a wave of freshly prefilled
    requests from the dense prefill buffer into their allocated pages
    (pooled leaves) / their slot rows (state leaves), and set the wave's
    logits, clocks, remaining counters and key streams in one compiled
    call. One program per (prompt_len, wave_width) shape pair; shared
    across scheduler instances (lru on the frozen adapter)."""
    plans = paging.leaf_plans(adapter.cache_specs(1, seq_len))

    def install(caches_st, logits_st, t_st, gen_pos_st, rem_st,
                keydata_st, dense_caches, logits, rows, slots, t0s,
                rem0s, keydata_w):
        def one(st, dense, plan):
            if plan.pooled:
                # pooled leaves are (layers, B, S, *tail) densely: scatter
                # each wave row's first prompt_len positions to its pages
                n_pages, pg = st.shape[1], st.shape[2]
                flat = st.reshape((st.shape[0], n_pages * pg)
                                  + st.shape[3:])
                vals = dense[:, :, :rows.shape[1]]
                flat = flat.at[:, rows].set(vals.astype(st.dtype))
                return flat.reshape(st.shape)
            idx = (slice(None),) * plan.batch_axis + (slots,)
            return st.at[idx].set(dense.astype(st.dtype))

        caches_st = jax.tree.map(one, caches_st, dense_caches, plans)
        return (caches_st, logits_st.at[slots].set(logits[:, None]),
                t_st.at[slots].set(t0s),
                gen_pos_st.at[slots].set(jnp.zeros_like(t0s)),
                rem_st.at[slots].set(rem0s),
                keydata_st.at[slots].set(keydata_w))

    return jax.jit(install, donate_argnums=(0, 1, 2, 3, 4, 5))


class ServeScheduler:
    """Continuous-batching engine over the split serve plane.

    ``submit()`` queues requests; ``run()`` drains the queue through the
    fixed slots and returns :class:`RequestResult` per request (rid
    order). Construct via :meth:`repro.federation.Federation.serve`.

    ``page_size`` must divide ``seq_len`` (default: the largest divisor
    <= 8); ``n_pages`` sizes the shared pool (default: worst case,
    ``max_batch`` full-length sequences + the two reserved pages). A
    smaller pool admission-gates requests on free pages instead of free
    slots — peak cache memory then tracks the lengths actually in
    flight, not ``max_batch × seq_len``.
    """

    def __init__(self, adapter: ModelAdapter, transport, *, params,
                 n_clients: int, seq_len: int, embed_dim: int,
                 vocab_size: int, max_batch: int = 4,
                 temperature: float = 0.0,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None):
        serving._require_serve_plane(adapter)
        if adapter.server_decode_paged is None:
            raise ValueError(
                f"adapter {adapter.name!r} has no server_decode_paged "
                "hook; build the session from a ModelConfig to serve")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.adapter = adapter
        self.transport = transport
        self.params = params
        self.n_clients = n_clients
        self.seq_len = seq_len
        self.span = seq_len // n_clients
        self.embed_dim = embed_dim
        self.vocab_size = vocab_size
        self.max_batch = max_batch
        self.temperature = float(temperature)

        self.page_size = (paging.default_page_size(seq_len)
                          if page_size is None else int(page_size))
        if self.page_size < 1 or seq_len % self.page_size:
            raise ValueError(
                f"page_size={self.page_size} must divide seq_len={seq_len}")
        self.pages_per_seq = seq_len // self.page_size
        self.n_pages = (max_batch * self.pages_per_seq + paging.N_RESERVED
                        if n_pages is None else int(n_pages))
        self.allocator = paging.PageAllocator(self.n_pages)

        self._queue: List[ServeRequest] = []
        self._next_rid = 0
        self._slot_req: List[Optional[ServeRequest]] = [None] * max_batch
        self._slot_pages: List[Optional[np.ndarray]] = [None] * max_batch
        self._remaining = np.zeros(max_batch, np.int64)   # host mirror
        self._admitted_at = np.zeros(max_batch, np.int64)
        self._tables = np.full((max_batch, self.pages_per_seq),
                               paging.ZERO_PAGE, np.int32)
        self._tables_dev = None     # device mirror, rebuilt on mutation
        self._results: Dict[int, RequestResult] = {}

        # device-side slot state. Sequence cache leaves live in the shared
        # page pool; recurrent state leaves are slot-stacked. (Logits
        # dtype is model-dependent — built lazily from the first prefill.)
        dense_specs = adapter.cache_specs(1, seq_len)
        self._plans = paging.leaf_plans(dense_specs)
        paged_specs = paging.paged_specs(
            dense_specs, n_slots=max_batch, n_pages=self.n_pages,
            page_size=self.page_size)
        self._caches_st = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), paged_specs,
            is_leaf=lambda x: hasattr(x, "logical"))
        self._logits_st = None      # (slots, 1, 1, vocab)
        self._t_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_pos_st = jnp.zeros(max_batch, jnp.int32)
        self._rem_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_buf_st = jnp.zeros((max_batch, seq_len), jnp.int32)
        kd = jax.random.key_data(jax.random.key(0))
        self._keydata_st = jnp.zeros((max_batch,) + kd.shape, kd.dtype)

        # persistent dense (1, seq_len) prefill buffer — only its small
        # recurrent-state leaves are re-zeroed per admission
        self._prefill_caches = None
        # hot-loop executables keyed on the block length — the
        # steady-state path never rebuilds an AOT cache key per block
        self._block_progs: Dict[int, object] = {}

        # perf counters (the throughput bench reads these)
        self.steps = 0
        self.compile_s = 0.0
        self.generated_tokens = 0
        self.last_run_s = 0.0
        self.host_transfers = 0     # device->host fetches (one per wave)

    # ------------------------------------------------------- queueing ----
    def submit(self, prompt, gen_len: int, *, seed: Optional[int] = None,
               key=None) -> int:
        """Queue one request; returns its rid. ``key`` (or ``seed``) is
        the request's sampling stream — the SAME key given to a solo
        ``fed.decode`` yields the same tokens. Without either, each
        request gets its own stream (folded from its rid), so concurrent
        sampled requests are never correlated."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1 or gen_len < 1:
            raise ValueError(
                f"need a non-empty prompt and gen_len >= 1, got "
                f"prompt_len={prompt.size}, gen_len={gen_len}")
        if prompt.size + gen_len > self.seq_len:
            raise ValueError(
                f"prompt_len + gen_len = {prompt.size + gen_len} exceeds "
                f"the session seq_len {self.seq_len}")
        need = paging.pages_needed(prompt.size + gen_len, self.page_size)
        if need > self.allocator.capacity:
            raise ValueError(
                f"request needs {need} pages but the pool holds "
                f"{self.allocator.capacity} (n_pages={self.n_pages}, "
                f"page_size={self.page_size})")
        rid = self._next_rid
        if key is None and seed is None:
            key = jax.random.fold_in(jax.random.key(0), rid)
        elif key is None:
            key = jax.random.key(seed)
        self._next_rid += 1
        self._queue.append(ServeRequest(rid=rid, prompt=prompt,
                                        gen_len=gen_len, key=key))
        return rid

    # ------------------------------------------------------ admission ----
    def _prefill_wave(self, reqs: List[ServeRequest]):
        """Chunk-prefill a wave of equal-length prompts as ONE batch.

        A width-1 wave reuses the persistent dense buffer (recurrent
        state leaves re-zeroed; stale KV rows from the previous tenant
        sit beyond the causal mask of every prefill query position and
        contribute exactly 0.0 — bitwise-identical to a fresh zero
        buffer). Wider waves prefill through one (w, prompt_len) batch
        into transient zero caches: w prompts pay ONE dispatch chain
        instead of w. Batched rows staying bitwise-equal to a B=1
        prefill is an empirical backend property, not an XLA guarantee —
        exactly the same status as the decode scan matching the eager
        loop or split matching global — and it is pinned by
        tests/test_serving_engine.py (wave admission at sampling
        temperature, where low-bit drift is visible)."""
        w = len(reqs)
        prompt_len = reqs[0].prompt.size
        if w == 1:
            if self._prefill_caches is None:
                self._prefill_caches = serving.zero_caches(
                    self.adapter, 1, self.seq_len)
            else:
                self._prefill_caches = jax.tree.map(
                    lambda a, plan: a if plan.pooled else jnp.zeros_like(a),
                    self._prefill_caches, self._plans)
            caches = self._prefill_caches
        else:
            caches = serving.zero_caches(self.adapter, w, self.seq_len)
        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        logits = None
        if self.adapter.server_prefill is not None:
            chunk_fn = serving.make_prefill_chunk(self.adapter,
                                                  self.n_clients,
                                                  self.seq_len)
            for t0, t1, m in serving.prefill_plan(prompt_len, self.span):
                prog, dt = serving.compiled_with_timing(
                    chunk_fn, self.params, toks[:, t0:t1], caches, t0, m)
                self.compile_s += dt
                logits, caches = prog(self.params, toks[:, t0:t1], caches,
                                      t0, m)
        else:
            step = serving.make_serve_step(self.adapter, self.n_clients,
                                           self.seq_len)
            prog, dt = serving.compiled_with_timing(
                step, self.params, toks[:, :1], caches, 0)
            self.compile_s += dt
            for t in range(prompt_len):
                logits, caches = prog(self.params, toks[:, t:t + 1],
                                      caches, t)
        if w == 1:
            self._prefill_caches = caches
        return logits, caches

    def _admit_wave(self, slots: List[int], reqs: List[ServeRequest]):
        """Prefill a wave of requests, allocate their pages, and install
        all their slot state with ONE compiled scatter — async dispatches
        only, no host sync. Prefill wire traffic is logged here per
        request: prompt_len embedding uploads, no downlink."""
        w = len(reqs)
        prompt_len = reqs[0].prompt.size
        pages = [self.allocator.alloc(paging.pages_needed(
            r.prompt.size + r.gen_len, self.page_size)) for r in reqs]

        logits, caches = self._prefill_wave(reqs)
        if self._logits_st is None:
            self._logits_st = jnp.zeros(
                (self.max_batch, 1) + logits.shape[1:], logits.dtype)

        rows = jnp.asarray(np.stack([
            paging.install_rows(p, prompt_len, self.page_size)
            for p in pages]))
        kd = np.stack([np.asarray(jax.random.key_data(r.key))
                       for r in reqs])
        fn = make_install_prog(self.adapter, self.seq_len)
        args = (self._caches_st, self._logits_st, self._t_st,
                self._gen_pos_st, self._rem_st, self._keydata_st,
                caches, logits, rows, np.asarray(slots, np.int32),
                np.full(w, prompt_len, np.int32),
                np.asarray([r.gen_len for r in reqs], np.int32), kd)
        prog, dt = serving.compiled_with_timing(fn, *args)
        self.compile_s += dt
        (self._caches_st, self._logits_st, self._t_st, self._gen_pos_st,
         self._rem_st, self._keydata_st) = prog(*args)

        for slot, req, page_ids in zip(slots, reqs, pages):
            self._tables[slot, :] = paging.ZERO_PAGE
            self._tables[slot, :len(page_ids)] = page_ids
            self._tables_dev = None
            self._slot_pages[slot] = page_ids
            self._slot_req[slot] = req
            self._remaining[slot] = req.gen_len
            self._admitted_at[slot] = self.steps
            self.transport.account_serve(batch=1, embed=self.embed_dim,
                                         n_steps=req.prompt.size, n_gen=0,
                                         ledger=req.ledger)

    def _admit_free_slots(self):
        """FIFO wave admission: take the queue's head run of equal-length
        prompts that fits the free slots AND the page pool, prefill it as
        one batch and install it with one compiled scatter. The queue is
        never reordered — if the head doesn't fit, nothing jumps it."""
        while self._queue:
            free = [s for s in range(self.max_batch)
                    if self._slot_req[s] is None]
            if not free:
                return
            avail = self.allocator.available
            pl = self._queue[0].prompt.size
            wave = []
            for req in self._queue:
                need = paging.pages_needed(req.prompt.size + req.gen_len,
                                           self.page_size)
                if (len(wave) == len(free) or req.prompt.size != pl
                        or need > avail):
                    break
                wave.append(req)
                avail -= need
            if not wave:
                # page-gated: wait for a retirement wave to free pages
                return
            del self._queue[:len(wave)]
            self._admit_wave(free[:len(wave)], wave)

    # ----------------------------------------------------- the engine ----
    def _block_len(self) -> int:
        occ = [s for s, r in enumerate(self._slot_req) if r is not None]
        m = int(min(self._remaining[s] for s in occ))
        return 1 << (max(m, 1).bit_length() - 1)    # pow2 floor <= min rem

    def _device_tables(self):
        """Device mirror of the block tables, uploaded once per mutation
        (admission / retirement) instead of once per block — the first
        scheduler revision re-uploaded an identical table every block."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
        return self._tables_dev

    @tags.hot_loop
    def _block_step(self):
        """Run one compiled K-step decode block over all slots — one
        dispatch, zero host syncs."""
        n_occ = self.active
        if n_occ == 0:
            return
        k = self._block_len()
        prog = self._block_progs.get(k)
        tables = self._device_tables()
        args = (self.params, tables, self._keydata_st, self._logits_st,
                self._caches_st, self._t_st, self._gen_pos_st,
                self._rem_st, self._gen_buf_st)
        if prog is None:
            block_fn = make_paged_decode_block(
                self.adapter, self.n_clients, self.seq_len,
                self.temperature, self.vocab_size, self.page_size,
                self.max_batch, k)
            prog, dt = serving.compiled_with_timing(block_fn, *args)
            self.compile_s += dt
            self._block_progs[k] = prog
        (self._logits_st, self._caches_st, self._t_st, self._gen_pos_st,
         self._rem_st, self._gen_buf_st) = prog(*args)
        self.steps += k
        self.generated_tokens += k * n_occ
        for slot, req in enumerate(self._slot_req):
            if req is not None:
                self._remaining[slot] -= k

    @tags.host_boundary("once-per-wave retirement fetch: one batched "
                        "device->host transfer covers every slot that "
                        "finished in the last block — O(requests) syncs, "
                        "not O(steps)")
    def _retire_wave(self):
        """Retire every slot that finished in the last block: ONE
        batched device→host fetch for all of them, generation wire
        accounted in one deferred call per request (byte-identical to
        the per-step metering it replaces — see the module docstring)."""
        done = [s for s, r in enumerate(self._slot_req)
                if r is not None and self._remaining[s] <= 0]
        if not done:
            return
        toks_all = np.asarray(self._gen_buf_st[jnp.asarray(
            np.array(done, np.int32))])
        self.host_transfers += 1
        for row, slot in enumerate(done):
            req = self._slot_req[slot]
            self.transport.account_serve(batch=1, embed=self.embed_dim,
                                         n_steps=req.gen_len,
                                         n_gen=req.gen_len,
                                         ledger=req.ledger)
            self._results[req.rid] = RequestResult(
                rid=req.rid, tokens=toks_all[row, :req.gen_len],
                ledger=req.ledger, prompt_len=req.prompt.size,
                admitted_at=int(self._admitted_at[slot]),
                finished_at=self.steps)
            self.allocator.free_(self._slot_pages[slot])
            self._slot_pages[slot] = None
            self._tables[slot, :] = paging.ZERO_PAGE
            self._tables_dev = None
            self._slot_req[slot] = None

    # ----------------------------------------------------------- drive ----
    @property
    def active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def run(self) -> List[RequestResult]:
        """Drain the queue: admit into free slots (and free pages) as
        they open up mid-flight, run compiled decode blocks until every
        submitted request is done. Returns THIS drain's results in rid
        order (requests drained by an earlier ``run()`` stay retrievable
        via ``results``); wall-clock minus compile is exposed as
        ``last_run_s``."""
        draining = sorted([r.rid for r in self._queue]
                          + [r.rid for r in self._slot_req if r is not None])
        tic = time.perf_counter()
        compile0 = self.compile_s
        while self._queue or self.active:
            self._admit_free_slots()
            self._block_step()
            self._retire_wave()
        jax.block_until_ready(self._gen_buf_st)
        self.last_run_s = (time.perf_counter() - tic
                           - (self.compile_s - compile0))
        return [self._results[rid] for rid in draining]

    @property
    def results(self) -> Dict[int, RequestResult]:
        """Every request this scheduler has ever drained, by rid."""
        return dict(self._results)
