"""Continuous batching for the split serve plane, on paged caches.

The sglang-style serving loop, with the VFL party split kept intact: a
:class:`ServeScheduler` owns ``max_batch`` fixed SLOTS whose
sequence-indexed cache state lives in a shared page pool
(:mod:`repro.federation.paging`) addressed through per-slot block
tables, admits queued requests into free slots mid-flight, and drives
the whole churning mix with compiled MULTI-STEP decode blocks.

The first scheduler revision lost 6.6× to the static batched path by
doing host work per token: a Python dispatch per step, a per-active-slot
ledger call per token, and a blocking device→host fetch inside
``_retire``. This revision keeps the host out of the loop:

* **block stepping** — ``remaining`` lives on device and derives the
  active mask, so a compiled ``lax.scan`` block of K steps needs no host
  intervention. K is the largest power of two that no active request
  outlives (``K <= min(remaining)``), so a block never overshoots a
  retirement, the compiled-block set is bounded by ``log2(seq_len)``
  programs, and an occupied slot is never stepped while logically done.
* **wave retirement** — after a block, every slot whose host-mirrored
  ``remaining`` hit zero retires together: ONE batched device→host fetch
  per wave (``host_transfers`` counts them — O(requests), not O(steps)).
* **deferred accounting** — prefill wire traffic is logged at admission
  (``n_steps=prompt_len, n_gen=0``) and generation at retirement
  (``n_steps=gen_len, n_gen=gen_len``). ``Transport.account_serve``
  appends ``serve_messages(b, e, with_token=False) * (n_steps - n_gen)``
  then ``serve_messages(b, e) * n_gen``, so admission + retirement
  produce exactly ``up×prompt_len`` then ``(up+token)×gen_len`` — the
  byte-identical Message list a solo ``fed.decode`` logs in its single
  ``account_serve(n_steps=prompt_len+gen_len, n_gen=gen_len)`` call, and
  what the per-step ``account_serve_step`` metering used to build one
  token at a time.
* **wave admission** — the queue's head run of equal-length prompts is
  admitted as ONE wave: one batched chunk-prefill and one compiled
  install scatter cover the whole wave (width-1 waves reuse a persistent
  dense ``(1, seq_len)`` buffer — only the small recurrent state leaves
  are re-zeroed; stale KV rows beyond the prompt are masked exactly).
  Admission issues only async dispatches — no host sync, and admission
  is page-gated FIFO: an undersized pool makes requests wait for pages,
  never reorder.

Sampling uses the same ``fold_in(request_key, 100 + t)`` stream as the
solo path, so a request's tokens do not depend on what shared the batch.

**Failure policy** (the robustness layer):

* **bounded queue** — ``max_queue`` turns unbounded FIFO growth into
  typed backpressure: ``submit`` past the bound raises
  :class:`QueueFull` instead of silently deepening the backlog.
* **deadlines** — ``submit(deadline=D)`` gives the request D scheduler
  steps to RETIRE. An admitted request always meets its deadline (every
  block steps every occupied slot), so misses happen in the queue: the
  admission loop expires any queued request that can no longer finish in
  time (``status="deadline"``, partial tokens, ledger metering exactly
  what ran).
* **cancellation** — :meth:`cancel` removes a queued request or evicts
  an in-flight one between blocks (``status="cancelled"``); its ledger
  meters exactly the steps it ran — admission's prompt uploads plus one
  generation entry per token actually produced, byte-identical to a solo
  decode truncated at the same length.
* **preemption** — when the queue's head cannot get pages while a slot
  is free, the scheduler may evict a victim (fewest tokens remaining
  wins; only slots that progressed since admission are eligible, which
  makes the policy livelock-free) and re-queue it. On re-admission the
  victim re-prefills its prompt, REPLAYS its already-generated tokens
  through the per-token serve step, and resumes at the same absolute
  position ``t`` — the sampling stream is ``fold_in(key, 100 + t)``, so
  the resumed tokens are BITWISE what the unpreempted run would have
  produced (pinned by tests next to the continuous==solo guarantee).
  Preemption overhead is metered honestly: the evicted tenancy's
  generation entries at eviction, the full re-prefill (prompt +
  generated-so-far uploads) at re-admission.
* **poison isolation** — a request whose logits go non-finite fails with
  ``status="poisoned"`` at its next host-fetch point (retirement or
  eviction), never the engine: its pages are scrubbed to zero before
  reuse, because NaN — unlike the usual stale bytes — survives the
  causal mask (``0·NaN = NaN``) and would leak into the page's next
  tenant.
* **durability** — :meth:`snapshot` captures the whole serve plane
  (queue, slot tables, page-pool free list order, gen buffers,
  per-request ledgers, RNG key streams) as a :class:`SchedulerState`
  that saves through ``fed.save(serve_state=...)``; a scheduler restored
  mid-drain (``run(max_steps=...)`` then kill) continues bitwise — same
  token streams, byte-identical per-request ledgers — mirroring the
  async training plane's ``AsyncPlaneState`` contract.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tags
from repro.checkpoint.io import load_tree, save_checkpoint
from repro.core.adapters import ModelAdapter
from repro.core.privacy import Ledger, Message
from repro.federation import paging, serving


class QueueFull(RuntimeError):
    """Typed backpressure: the admission queue is at ``max_queue`` — shed
    load upstream instead of queueing unboundedly."""


@dataclasses.dataclass
class ServeRequest:
    """A queued generation request (one sequence; batch=1 on the wire)."""
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    gen_len: int
    key: jax.Array                  # typed PRNG key — solo-compatible stream
    ledger: Ledger = dataclasses.field(default_factory=Ledger)
    deadline: Optional[int] = None  # absolute scheduler step to retire by
    # tokens generated before a preemption (replayed at re-admission)
    generated: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    preemptions: int = 0
    first_admitted: int = -1        # -1 = never admitted


@dataclasses.dataclass
class RequestResult:
    """One drained request: its tokens and its exact wire ledger.

    ``status`` is ``"ok"`` for a full retirement; ``"cancelled"`` /
    ``"deadline"`` / ``"poisoned"`` results carry the tokens generated up
    to the failure and a ledger metering exactly the steps that ran."""
    rid: int
    tokens: np.ndarray              # (gen_len,) sampled token ids
    ledger: Ledger
    prompt_len: int
    admitted_at: int                # scheduler step index at admission
    finished_at: int                # scheduler step index at retirement
    status: str = "ok"
    preemptions: int = 0

    @property
    def wire_bytes(self) -> int:
        return self.ledger.total_bytes

    @property
    def transmits_gradients(self) -> bool:
        return self.ledger.transmits_gradients


# -------------------------------------------------- ledger (de)serialize --
# SchedulerState needs per-request ledgers BYTE-identical across a
# save/restore, including message ORDER — Ledger.to_counts aggregates
# (fine for totals, lossy for interleavings), so the serve plane keeps
# its own exact row codec.

def _ledger_rows(led: Ledger) -> List[list]:
    return [[m.sender, m.kind, list(m.shape), m.dtype, m.wired]
            for m in led.messages]


def _ledger_from_rows(rows: List[list]) -> Ledger:
    led = Ledger()
    led.messages.extend(
        Message(sender, kind, tuple(shape), dtype,
                wired=None if wired is None else int(wired))
        for sender, kind, shape, dtype, wired in rows)
    return led


@dataclasses.dataclass
class SchedulerState:
    """A complete serve-plane snapshot: every device buffer (page pool,
    slot state, gen buffers, RNG key data), the host bookkeeping (queue,
    slot tables, allocator free-list ORDER, per-request ledgers, result
    set, counters) and the constructor config. ``fed.save(serve_state=)``
    persists it; ``fed.serve(params, state=...)`` resumes it bitwise."""
    flat: Dict[str, np.ndarray]     # array leaves, keystr-addressed
    meta: dict                      # JSON-able bookkeeping + config

    def save(self, path: str) -> str:
        save_checkpoint(path, self.flat, metadata=self.meta)
        return path

    @classmethod
    def load(cls, path: str) -> "SchedulerState":
        tree, _, meta = load_tree(path)
        return cls(flat={k: np.asarray(v) for k, v in tree.items()},
                   meta=meta)


def _leafkey(group: str, path: Any) -> str:
    # "x" prefix keeps load_tree's dict-only key grammar happy (keystr
    # output starts with "[")
    return f"x['{group}']" + jax.tree_util.keystr(path)


@functools.lru_cache(maxsize=64)
def make_paged_decode_block(adapter: ModelAdapter, n_clients: int,
                            seq_len: int, temperature: float,
                            vocab_size: int, page_size: int,
                            n_slots: int, n_steps: int):
    """A compiled block of ``n_steps`` continuous-batching decode steps.

    Per step every slot samples from its carried logits on its own key
    stream, the owning client embeds the token, and the server runs ONE
    batched paged decode over all slots (``server_decode_paged``). The
    active mask derives on device from ``remaining > 0``, so the host
    never touches the loop; a slot that hits zero simply freezes (its
    uplink embedding is zeroed, its recurrent state held, its KV row
    routed to the trash page).

    Inactive slots still pay the backbone FLOPs for their batch row:
    under a batched (or vmapped) step XLA lowers per-row ``cond`` to
    ``select`` — both branches run — and a dense matmul has no ragged
    batch. The engine bounds that waste structurally instead: the block
    length never exceeds the smallest active ``remaining`` (an occupied
    slot is never stepped past its retirement) and the host loop stops
    the moment no slot is occupied, so idle rows only ride along while
    the queue is empty and other slots still stream. True row skipping
    needs slot compaction across bucketed batch sizes (a recompile per
    occupancy) or ragged kernels — a TPU-pass item (see ROADMAP).
    """
    serving._require_serve_plane(adapter)
    if adapter.server_decode_paged is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no server_decode_paged hook; "
            "the paged continuous scheduler needs it")
    span = seq_len // n_clients

    def block(params, tables, keydata_st, logits_st, caches_st, t_st,
              gen_pos_st, rem_st, gen_buf_st):
        sl = jnp.arange(n_slots)

        @tags.wire("up", accounted_by="Transport.account_serve",
                   kind="embedding",
                   reason="continuous-batching decode step: each active "
                          "slot's client embeds its sampled token and the "
                          "embedding crosses to server_decode_paged; the "
                          "traffic is metered deferred — prompt uploads at "
                          "admission, generation at retirement (see module "
                          "docstring)")
        def body(carry, _):
            logits, caches, t, gen_pos, rem, gen_buf = carry
            active = (rem > 0).astype(jnp.int32)
            nxt = jax.vmap(
                lambda lg, kd, tt: serving.sample_token(
                    lg, jax.random.wrap_key_data(kd), tt, temperature,
                    vocab_size))(logits, keydata_st, t)        # (n, 1)
            nxt = nxt[:, 0]
            idx = jnp.clip(gen_pos, 0, gen_buf.shape[1] - 1)
            gen_buf = gen_buf.at[sl, idx].set(
                jnp.where(active > 0, nxt, gen_buf[sl, idx]))

            m = jnp.where(active > 0, t, 0) // span

            def embed_one(tok, mi):
                client_m = jax.tree.map(lambda a: a[mi], params["clients"])
                return adapter.client_embed(client_m, tok[None, None])

            e = jax.vmap(embed_one)(nxt, m)[:, 0]              # (n, 1, d)
            e = e * (active > 0).astype(e.dtype)[:, None, None]
            lg, caches = adapter.server_decode_paged(
                params["server"], e, caches, tables, t, active, page_size)
            return (lg[:, None], caches, t + active, gen_pos + active,
                    rem - active, gen_buf), None

        carry, _ = jax.lax.scan(
            body, (logits_st, caches_st, t_st, gen_pos_st, rem_st,
                   gen_buf_st), None, length=n_steps)
        return carry

    return jax.jit(block, donate_argnums=(3, 4, 5, 6, 7, 8))


@functools.lru_cache(maxsize=32)
def make_install_prog(adapter: ModelAdapter, seq_len: int):
    """The slot-install scatter: move a wave of freshly prefilled
    requests from the dense prefill buffer into their allocated pages
    (pooled leaves) / their slot rows (state leaves), and set the wave's
    logits, clocks, remaining counters, gen buffers and key streams in
    one compiled call. One program per (prompt_len, wave_width) shape
    pair; shared across scheduler instances (lru on the frozen adapter).

    ``gen_rows``/``gen_pos0s`` seed the generation buffer — zeros for a
    fresh request, the already-generated prefix (with its length as the
    write cursor) for a preempted request being resumed."""
    plans = paging.leaf_plans(adapter.cache_specs(1, seq_len))

    def install(caches_st, logits_st, t_st, gen_pos_st, rem_st,
                keydata_st, gen_buf_st, dense_caches, logits, rows, slots,
                t0s, rem0s, keydata_w, gen_rows, gen_pos0s):
        def one(st, dense, plan):
            if plan.pooled:
                # pooled leaves are (layers, B, S, *tail) densely: scatter
                # each wave row's first prompt_len positions to its pages
                n_pages, pg = st.shape[1], st.shape[2]
                flat = st.reshape((st.shape[0], n_pages * pg)
                                  + st.shape[3:])
                vals = dense[:, :, :rows.shape[1]]
                flat = flat.at[:, rows].set(vals.astype(st.dtype))
                return flat.reshape(st.shape)
            idx = (slice(None),) * plan.batch_axis + (slots,)
            return st.at[idx].set(dense.astype(st.dtype))

        caches_st = jax.tree.map(one, caches_st, dense_caches, plans)
        return (caches_st, logits_st.at[slots].set(logits[:, None]),
                t_st.at[slots].set(t0s),
                gen_pos_st.at[slots].set(gen_pos0s),
                rem_st.at[slots].set(rem0s),
                keydata_st.at[slots].set(keydata_w),
                gen_buf_st.at[slots].set(gen_rows))

    return jax.jit(install, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


class ServeScheduler:
    """Continuous-batching engine over the split serve plane.

    ``submit()`` queues requests; ``run()`` drains the queue through the
    fixed slots and returns :class:`RequestResult` per request (rid
    order). Construct via :meth:`repro.federation.Federation.serve`.

    ``page_size`` must divide ``seq_len`` (default: the largest divisor
    <= 8); ``n_pages`` sizes the shared pool (default: worst case,
    ``max_batch`` full-length sequences + the two reserved pages). A
    smaller pool admission-gates requests on free pages instead of free
    slots — peak cache memory then tracks the lengths actually in
    flight, not ``max_batch × seq_len``. With ``preempt=True`` a
    page-starved queue head may instead evict the in-flight request with
    the fewest tokens remaining (bitwise-exact resume; see the module
    docstring). ``max_queue`` bounds the admission queue (``submit``
    raises :class:`QueueFull` past it).
    """

    def __init__(self, adapter: ModelAdapter, transport, *, params,
                 n_clients: int, seq_len: int, embed_dim: int,
                 vocab_size: int, max_batch: int = 4,
                 temperature: float = 0.0,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 preempt: bool = False):
        serving._require_serve_plane(adapter)
        if adapter.server_decode_paged is None:
            raise ValueError(
                f"adapter {adapter.name!r} has no server_decode_paged "
                "hook; build the session from a ModelConfig to serve")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.adapter = adapter
        self.transport = transport
        self.params = params
        self.n_clients = n_clients
        self.seq_len = seq_len
        self.span = seq_len // n_clients
        self.embed_dim = embed_dim
        self.vocab_size = vocab_size
        self.max_batch = max_batch
        self.temperature = float(temperature)
        self.max_queue = max_queue
        self.preempt = bool(preempt)

        self.page_size = (paging.default_page_size(seq_len)
                          if page_size is None else int(page_size))
        if self.page_size < 1 or seq_len % self.page_size:
            raise ValueError(
                f"page_size={self.page_size} must divide seq_len={seq_len}")
        self.pages_per_seq = seq_len // self.page_size
        self.n_pages = (max_batch * self.pages_per_seq + paging.N_RESERVED
                        if n_pages is None else int(n_pages))
        self.allocator = paging.PageAllocator(self.n_pages)

        self._queue: List[ServeRequest] = []
        self._next_rid = 0
        self._slot_req: List[Optional[ServeRequest]] = [None] * max_batch
        self._slot_pages: List[Optional[np.ndarray]] = [None] * max_batch
        self._remaining = np.zeros(max_batch, np.int64)   # host mirror
        self._admitted_at = np.zeros(max_batch, np.int64)
        self._tables = np.full((max_batch, self.pages_per_seq),
                               paging.ZERO_PAGE, np.int32)
        self._tables_dev = None     # device mirror, rebuilt on mutation
        self._results: Dict[int, RequestResult] = {}

        # device-side slot state. Sequence cache leaves live in the shared
        # page pool; recurrent state leaves are slot-stacked. (Logits
        # dtype is model-dependent — built lazily from the first prefill.)
        dense_specs = adapter.cache_specs(1, seq_len)
        self._plans = paging.leaf_plans(dense_specs)
        paged_specs = paging.paged_specs(
            dense_specs, n_slots=max_batch, n_pages=self.n_pages,
            page_size=self.page_size)
        self._caches_st = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), paged_specs,
            is_leaf=lambda x: hasattr(x, "logical"))
        self._logits_st = None      # (slots, 1, 1, vocab)
        self._t_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_pos_st = jnp.zeros(max_batch, jnp.int32)
        self._rem_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_buf_st = jnp.zeros((max_batch, seq_len), jnp.int32)
        kd = jax.random.key_data(jax.random.key(0))
        self._keydata_st = jnp.zeros((max_batch,) + kd.shape, kd.dtype)

        # persistent dense (1, seq_len) prefill buffer — only its small
        # recurrent-state leaves are re-zeroed per admission
        self._prefill_caches = None
        # hot-loop executables keyed on the block length — the
        # steady-state path never rebuilds an AOT cache key per block
        self._block_progs: Dict[int, object] = {}

        # perf + failure counters (the throughput/chaos benches read these)
        self.steps = 0
        self.compile_s = 0.0
        self.generated_tokens = 0
        self.last_run_s = 0.0
        self.host_transfers = 0     # device->host fetches (one per wave)
        self.preemptions = 0
        self.deadline_misses = 0
        self.poisoned = 0

    # ------------------------------------------------------- queueing ----
    def submit(self, prompt, gen_len: int, *, seed: Optional[int] = None,
               key=None, deadline: Optional[int] = None) -> int:
        """Queue one request; returns its rid. ``key`` (or ``seed``) is
        the request's sampling stream — the SAME key given to a solo
        ``fed.decode`` yields the same tokens. Without either, each
        request gets its own stream (folded from its rid), so concurrent
        sampled requests are never correlated. ``deadline`` gives the
        request that many SCHEDULER STEPS (from now) to retire; raises
        :class:`QueueFull` when the admission queue is at ``max_queue``."""
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            raise QueueFull(
                f"admission queue full ({len(self._queue)}/"
                f"{self.max_queue}); retry after a drain")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1 or gen_len < 1:
            raise ValueError(
                f"need a non-empty prompt and gen_len >= 1, got "
                f"prompt_len={prompt.size}, gen_len={gen_len}")
        if prompt.size + gen_len > self.seq_len:
            raise ValueError(
                f"prompt_len + gen_len = {prompt.size + gen_len} exceeds "
                f"the session seq_len {self.seq_len}")
        need = paging.pages_needed(prompt.size + gen_len, self.page_size)
        if need > self.allocator.capacity:
            raise ValueError(
                f"request needs {need} pages but the pool holds "
                f"{self.allocator.capacity} (n_pages={self.n_pages}, "
                f"page_size={self.page_size})")
        if deadline is not None and deadline < 1:
            raise ValueError(f"deadline must be >= 1 steps, got {deadline}")
        rid = self._next_rid
        if key is None and seed is None:
            key = jax.random.fold_in(jax.random.key(0), rid)
        elif key is None:
            key = jax.random.key(seed)
        self._next_rid += 1
        self._queue.append(ServeRequest(
            rid=rid, prompt=prompt, gen_len=gen_len, key=key,
            deadline=None if deadline is None else self.steps + deadline))
        return rid

    def cancel(self, rid: int) -> Optional[RequestResult]:
        """Explicitly cancel a request. Queued: removed outright.
        In-flight: evicted between blocks — its tokens so far come back
        and its ledger meters exactly the steps it ran. Returns the
        terminal ``status="cancelled"`` result, or None if ``rid`` is
        unknown or already finished."""
        if rid in self._results:
            return None
        for i, req in enumerate(self._queue):
            if req.rid == rid:
                self._queue.pop(i)
                return self._fail_request(req, "cancelled")
        for slot, req in enumerate(self._slot_req):
            if req is not None and req.rid == rid:
                return self._evict_slot(slot, "cancelled")
        return None

    # ------------------------------------------------------ admission ----
    def _prefill_wave(self, reqs: List[ServeRequest]):
        """Chunk-prefill a wave of equal-length prompts as ONE batch.

        A width-1 wave reuses the persistent dense buffer (recurrent
        state leaves re-zeroed; stale KV rows from the previous tenant
        sit beyond the causal mask of every prefill query position and
        contribute exactly 0.0 — bitwise-identical to a fresh zero
        buffer). Wider waves prefill through one (w, prompt_len) batch
        into transient zero caches: w prompts pay ONE dispatch chain
        instead of w. Batched rows staying bitwise-equal to a B=1
        prefill is an empirical backend property, not an XLA guarantee —
        exactly the same status as the decode scan matching the eager
        loop or split matching global — and it is pinned by
        tests/test_serving_engine.py (wave admission at sampling
        temperature, where low-bit drift is visible)."""
        w = len(reqs)
        prompt_len = reqs[0].prompt.size
        if w == 1:
            if self._prefill_caches is None:
                self._prefill_caches = serving.zero_caches(
                    self.adapter, 1, self.seq_len)
            else:
                self._prefill_caches = jax.tree.map(
                    lambda a, plan: a if plan.pooled else jnp.zeros_like(a),
                    self._prefill_caches, self._plans)
            caches = self._prefill_caches
        else:
            caches = serving.zero_caches(self.adapter, w, self.seq_len)
        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        logits = None
        if self.adapter.server_prefill is not None:
            chunk_fn = serving.make_prefill_chunk(self.adapter,
                                                  self.n_clients,
                                                  self.seq_len)
            for t0, t1, m in serving.prefill_plan(prompt_len, self.span):
                prog, dt = serving.compiled_with_timing(
                    chunk_fn, self.params, toks[:, t0:t1], caches, t0, m)
                self.compile_s += dt
                logits, caches = prog(self.params, toks[:, t0:t1], caches,
                                      t0, m)
        else:
            step = serving.make_serve_step(self.adapter, self.n_clients,
                                           self.seq_len)
            prog, dt = serving.compiled_with_timing(
                step, self.params, toks[:, :1], caches, 0)
            self.compile_s += dt
            for t in range(prompt_len):
                logits, caches = prog(self.params, toks[:, t:t + 1],
                                      caches, t)
        if w == 1:
            self._prefill_caches = caches
        return logits, caches

    @tags.host_boundary("preemption-resume replay: feeds the victim's "
                        "already-fetched host tokens back one position at "
                        "a time — host->device uploads on a cold path, "
                        "never the steady-state decode loop")
    def _replay_generated(self, req: ServeRequest, logits, caches):
        """Re-derive a preempted request's device state: feed its
        already-generated tokens through the per-token serve step, one
        position at a time — the exact computation the solo decode loop
        runs, so the carried logits and cache rows come back bitwise and
        the resumed stream continues where the evicted one stopped."""
        step = serving.make_serve_step(self.adapter, self.n_clients,
                                       self.seq_len)
        pl = req.prompt.size
        tok0 = np.asarray([[req.generated[0]]], np.int32)
        prog, dt = serving.compiled_with_timing(
            step, self.params, tok0, caches, pl)
        self.compile_s += dt
        for i, tok in enumerate(np.asarray(req.generated, np.int32)):
            logits, caches = prog(self.params,
                                  np.asarray([[tok]], np.int32),
                                  caches, pl + i)
        return logits, caches

    def _admit_wave(self, slots: List[int], reqs: List[ServeRequest]):
        """Prefill a wave of requests, allocate their pages, and install
        all their slot state with ONE compiled scatter — async dispatches
        only, no host sync. Prefill wire traffic is logged here per
        request: one embedding upload per prefilled position (prompt
        only for fresh requests; prompt + replayed tokens for a resumed
        one), no downlink."""
        w = len(reqs)
        prompt_len = reqs[0].prompt.size
        gens = [int(r.generated.size) for r in reqs]
        eff_len = prompt_len + gens[0]      # uniform: wave is width-1 when
        assert all(g == gens[0] for g in gens)  # any prefix is non-empty
        pages = [self.allocator.alloc(paging.pages_needed(
            r.prompt.size + r.gen_len, self.page_size)) for r in reqs]

        logits, caches = self._prefill_wave(reqs)
        if gens[0]:
            logits, caches = self._replay_generated(reqs[0], logits, caches)
            if w == 1:
                self._prefill_caches = caches
        if self._logits_st is None:
            self._logits_st = jnp.zeros(
                (self.max_batch, 1) + logits.shape[1:], logits.dtype)

        rows = jnp.asarray(np.stack([
            paging.install_rows(p, eff_len, self.page_size)
            for p in pages]))
        kd = np.stack([np.asarray(jax.random.key_data(r.key))
                       for r in reqs])
        gen_rows = np.zeros((w, self.seq_len), np.int32)
        for i, r in enumerate(reqs):
            gen_rows[i, :r.generated.size] = r.generated
        fn = make_install_prog(self.adapter, self.seq_len)
        args = (self._caches_st, self._logits_st, self._t_st,
                self._gen_pos_st, self._rem_st, self._keydata_st,
                self._gen_buf_st, caches, logits, rows,
                np.asarray(slots, np.int32),
                np.full(w, eff_len, np.int32),
                np.asarray([r.gen_len - g
                            for r, g in zip(reqs, gens)], np.int32),
                kd, gen_rows, np.asarray(gens, np.int32))
        prog, dt = serving.compiled_with_timing(fn, *args)
        self.compile_s += dt
        (self._caches_st, self._logits_st, self._t_st, self._gen_pos_st,
         self._rem_st, self._keydata_st, self._gen_buf_st) = prog(*args)

        for slot, req, page_ids in zip(slots, reqs, pages):
            self._tables[slot, :] = paging.ZERO_PAGE
            self._tables[slot, :len(page_ids)] = page_ids
            self._tables_dev = None
            self._slot_pages[slot] = page_ids
            self._slot_req[slot] = req
            self._remaining[slot] = req.gen_len - req.generated.size
            self._admitted_at[slot] = self.steps
            if req.first_admitted < 0:
                req.first_admitted = self.steps
            self.transport.account_serve(
                batch=1, embed=self.embed_dim,
                n_steps=req.prompt.size + req.generated.size, n_gen=0,
                ledger=req.ledger)

    def _expire_queue(self):
        """Fail queued requests that can no longer meet their deadline
        (an admitted request always retires in exactly ``remaining``
        scheduler steps, so feasibility is checkable at admission)."""
        i = 0
        while i < len(self._queue):
            req = self._queue[i]
            needed = req.gen_len - req.generated.size
            if (req.deadline is not None
                    and self.steps + needed > req.deadline):
                self._queue.pop(i)
                self.deadline_misses += 1
                self._fail_request(req, "deadline")
            else:
                i += 1

    def _pick_victim(self) -> Optional[int]:
        """Preemption victim: the occupied slot with the FEWEST tokens
        remaining, among slots that produced at least one token since
        (re-)admission — requiring progress makes preemption ping-pong
        terminate (total remaining strictly decreases between evictions
        of the same pair)."""
        best, best_rem = None, None
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            ran = (req.gen_len - req.generated.size) - self._remaining[slot]
            if ran <= 0:
                continue
            if best_rem is None or self._remaining[slot] < best_rem:
                best, best_rem = slot, self._remaining[slot]
        return best

    def _admit_free_slots(self):
        """FIFO wave admission: take the queue's head run of equal-length
        prompts that fits the free slots AND the page pool, prefill it as
        one batch and install it with one compiled scatter. The queue is
        never reordered — if the head doesn't fit, nothing jumps it.
        With ``preempt=True`` a page-starved head may evict a victim
        (see :meth:`_pick_victim`) instead of waiting."""
        while self._queue:
            self._expire_queue()
            if not self._queue:
                return
            free = [s for s in range(self.max_batch)
                    if self._slot_req[s] is None]
            if not free:
                return
            avail = self.allocator.available
            pl = self._queue[0].prompt.size
            g0 = int(self._queue[0].generated.size)
            wave = []
            for req in self._queue:
                need = paging.pages_needed(req.prompt.size + req.gen_len,
                                           self.page_size)
                if (len(wave) == len(free) or req.prompt.size != pl
                        or need > avail
                        or int(req.generated.size) != g0
                        or (g0 and wave)):
                    break
                wave.append(req)
                avail -= need
            if not wave:
                # page-gated. Either preempt a victim to unblock the
                # head, or wait for a retirement wave to free pages.
                if self.preempt:
                    victim = self._pick_victim()
                    if victim is not None:
                        self._preempt_slot(victim)
                        continue
                return
            del self._queue[:len(wave)]
            self._admit_wave(free[:len(wave)], wave)

    # ----------------------------------------------------- the engine ----
    def _block_len(self, budget: Optional[int] = None) -> int:
        occ = [s for s, r in enumerate(self._slot_req) if r is not None]
        m = int(min(self._remaining[s] for s in occ))
        if budget is not None:
            m = min(m, max(int(budget), 1))
        return 1 << (max(m, 1).bit_length() - 1)    # pow2 floor <= min rem

    def _device_tables(self):
        """Device mirror of the block tables, uploaded once per mutation
        (admission / retirement) instead of once per block — the first
        scheduler revision re-uploaded an identical table every block."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
        return self._tables_dev

    @tags.hot_loop
    def _block_step(self, budget: Optional[int] = None):
        """Run one compiled K-step decode block over all slots — one
        dispatch, zero host syncs."""
        n_occ = self.active
        if n_occ == 0:
            return
        k = self._block_len(budget)
        prog = self._block_progs.get(k)
        tables = self._device_tables()
        args = (self.params, tables, self._keydata_st, self._logits_st,
                self._caches_st, self._t_st, self._gen_pos_st,
                self._rem_st, self._gen_buf_st)
        if prog is None:
            block_fn = make_paged_decode_block(
                self.adapter, self.n_clients, self.seq_len,
                self.temperature, self.vocab_size, self.page_size,
                self.max_batch, k)
            prog, dt = serving.compiled_with_timing(block_fn, *args)
            self.compile_s += dt
            self._block_progs[k] = prog
        (self._logits_st, self._caches_st, self._t_st, self._gen_pos_st,
         self._rem_st, self._gen_buf_st) = prog(*args)
        self.steps += k
        self.generated_tokens += k * n_occ
        for slot, req in enumerate(self._slot_req):
            if req is not None:
                self._remaining[slot] -= k

    # ---------------------------------------------------- slot teardown --
    @tags.host_boundary("eviction fetch: one device->host transfer pulls "
                        "the slot's generated-so-far tokens and its "
                        "logits-health flag — preempt/cancel/poison paths "
                        "only, never the hot loop")
    def _fetch_slot(self, slot: int):
        """(tokens generated so far, logits finite?) for one slot."""
        req = self._slot_req[slot]
        total = (req.gen_len - req.generated.size) - self._remaining[slot]
        total += req.generated.size
        toks = np.asarray(self._gen_buf_st[slot])[:int(total)]
        finite = True
        if self._logits_st is not None:
            finite = bool(np.isfinite(np.asarray(
                self._logits_st[slot], np.float32)).all())
        self.host_transfers += 1
        return toks.astype(np.int32), finite

    def _scrub_pages(self, page_ids) -> None:
        """Zero a poisoned request's pages (and the trash page) in every
        pooled leaf before they can be reallocated. Ordinary stale bytes
        sit behind the causal mask and contribute exactly 0.0; NaN does
        not (0·NaN = NaN), so poison must not outlive its tenancy."""
        pages = jnp.asarray(np.concatenate(
            [np.asarray(page_ids, np.int32),
             np.asarray([paging.TRASH_PAGE], np.int32)]))
        self._caches_st = jax.tree.map(
            lambda st, plan: (st.at[:, pages].set(jnp.zeros(
                (), st.dtype)) if plan.pooled else st),
            self._caches_st, self._plans)

    def _release_slot(self, slot: int, *, scrub: bool) -> None:
        """Return a slot's pages to the pool and deactivate its device
        row (``rem=0`` — otherwise the freed slot would keep decoding
        and scribble on the ZERO page via its reset table)."""
        if scrub:
            self._scrub_pages(self._slot_pages[slot])
        self.allocator.free_(self._slot_pages[slot])
        self._slot_pages[slot] = None
        self._tables[slot, :] = paging.ZERO_PAGE
        self._tables_dev = None
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._rem_st = self._rem_st.at[slot].set(0)

    def _fail_request(self, req: ServeRequest, status: str
                      ) -> RequestResult:
        res = RequestResult(
            rid=req.rid, tokens=np.asarray(req.generated, np.int32),
            ledger=req.ledger, prompt_len=int(req.prompt.size),
            admitted_at=int(req.first_admitted), finished_at=self.steps,
            status=status, preemptions=req.preemptions)
        self._results[req.rid] = res
        return res

    def _evict_slot(self, slot: int, status: str) -> RequestResult:
        """Terminally evict an in-flight request (cancel / poison): meter
        the generation steps that actually ran, free (and if poisoned,
        scrub) its pages, record the partial result."""
        req = self._slot_req[slot]
        toks, finite = self._fetch_slot(slot)
        ran = len(toks) - req.generated.size
        if ran > 0:
            self.transport.account_serve(batch=1, embed=self.embed_dim,
                                         n_steps=ran, n_gen=ran,
                                         ledger=req.ledger)
        if not finite:
            status = "poisoned"
            self.poisoned += 1
        self._release_slot(slot, scrub=not finite)
        req.generated = toks
        return self._fail_request(req, status)

    def _preempt_slot(self, slot: int) -> None:
        """Evict a victim to free pages for the queue's head: fetch its
        tokens so far, meter the evicted tenancy, and re-queue it (tail)
        to re-prefill + replay later. A poisoned victim fails here
        instead of being resumed (replaying NaN state is pointless)."""
        req = self._slot_req[slot]
        toks, finite = self._fetch_slot(slot)
        ran = len(toks) - req.generated.size
        if ran > 0:
            self.transport.account_serve(batch=1, embed=self.embed_dim,
                                         n_steps=ran, n_gen=ran,
                                         ledger=req.ledger)
        if not finite:
            self.poisoned += 1
            self._release_slot(slot, scrub=True)
            req.generated = toks
            self._fail_request(req, "poisoned")
            return
        self._release_slot(slot, scrub=False)
        req.generated = toks
        req.preemptions += 1
        self.preemptions += 1
        self._queue.append(req)

    @tags.host_boundary("once-per-wave retirement fetch: one batched "
                        "device->host transfer covers every slot that "
                        "finished in the last block — O(requests) syncs, "
                        "not O(steps)")
    def _retire_wave(self):
        """Retire every slot that finished in the last block: ONE
        batched device→host fetch for all of them, generation wire
        accounted in one deferred call per request (byte-identical to
        the per-step metering it replaces — see the module docstring).
        The same fetch carries each slot's logits-health flag: a
        non-finite slot fails as ``status="poisoned"`` and its pages are
        scrubbed before reuse."""
        done = [s for s, r in enumerate(self._slot_req)
                if r is not None and self._remaining[s] <= 0]
        if not done:
            return
        done_idx = jnp.asarray(np.array(done, np.int32))
        toks_all = np.asarray(self._gen_buf_st[done_idx])
        fin_all = np.isfinite(np.asarray(
            self._logits_st[done_idx], np.float32)).reshape(
                len(done), -1).all(axis=1)
        self.host_transfers += 1
        for row, slot in enumerate(done):
            req = self._slot_req[slot]
            ran = req.gen_len - req.generated.size
            self.transport.account_serve(batch=1, embed=self.embed_dim,
                                         n_steps=ran, n_gen=ran,
                                         ledger=req.ledger)
            finite = bool(fin_all[row])
            if not finite:
                self.poisoned += 1
            self._results[req.rid] = RequestResult(
                rid=req.rid, tokens=toks_all[row, :req.gen_len],
                ledger=req.ledger, prompt_len=req.prompt.size,
                admitted_at=int(self._admitted_at[slot]),
                finished_at=self.steps,
                status="ok" if finite else "poisoned",
                preemptions=req.preemptions)
            self._release_slot(slot, scrub=not finite)

    # ----------------------------------------------------------- drive ----
    @property
    def active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def run(self, max_steps: Optional[int] = None) -> List[RequestResult]:
        """Drain the queue: admit into free slots (and free pages) as
        they open up mid-flight, run compiled decode blocks until every
        submitted request is done. Returns the requests that reached a
        terminal state DURING this call, in rid order (earlier drains
        stay retrievable via ``results``); wall-clock minus compile is
        exposed as ``last_run_s``.

        ``max_steps`` bounds the scheduler steps executed this call
        (blocks are shortened to land exactly on the bound) and returns
        with work still in flight — the partial-drain hook that
        :meth:`snapshot`, :meth:`cancel` and kill/resume tests interleave
        with."""
        before = set(self._results)
        tic = time.perf_counter()
        compile0 = self.compile_s
        start = self.steps
        while self._queue or self.active:
            budget = (None if max_steps is None
                      else max_steps - (self.steps - start))
            if budget is not None and budget <= 0:
                break
            self._admit_free_slots()
            self._block_step(budget)
            self._retire_wave()
        jax.block_until_ready(self._gen_buf_st)
        self.last_run_s = (time.perf_counter() - tic
                           - (self.compile_s - compile0))
        return [self._results[rid]
                for rid in sorted(set(self._results) - before)]

    @property
    def results(self) -> Dict[int, RequestResult]:
        """Every request this scheduler has ever drained, by rid."""
        return dict(self._results)

    # ------------------------------------------------------ durability ----
    def _req_meta(self, req: ServeRequest, *, remaining: int,
                  admitted_at: int) -> dict:
        return {
            "rid": req.rid, "prompt": np.asarray(req.prompt).tolist(),
            "gen_len": int(req.gen_len),
            "key_data": np.asarray(
                jax.random.key_data(req.key)).tolist(),
            "deadline": req.deadline,
            "generated": np.asarray(req.generated).tolist(),
            "preemptions": int(req.preemptions),
            "first_admitted": int(req.first_admitted),
            "ledger": _ledger_rows(req.ledger),
            "remaining": int(remaining),
            "admitted_at": int(admitted_at),
        }

    @staticmethod
    def _req_from_meta(d: dict) -> ServeRequest:
        kd = jnp.asarray(np.asarray(d["key_data"], np.uint32))
        return ServeRequest(
            rid=int(d["rid"]),
            prompt=np.asarray(d["prompt"], np.int32),
            gen_len=int(d["gen_len"]),
            key=jax.random.wrap_key_data(kd),
            ledger=_ledger_from_rows(d["ledger"]),
            deadline=d["deadline"],
            generated=np.asarray(d["generated"], np.int32),
            preemptions=int(d["preemptions"]),
            first_admitted=int(d["first_admitted"]))

    @tags.host_boundary("snapshot fetch: pulls the whole serve-plane "
                        "device state (page pool, slot rows, gen buffers, "
                        "key streams) to host for a durable checkpoint — "
                        "a stop-the-world operation, never the hot loop")
    def snapshot(self) -> SchedulerState:
        """Capture the complete serve plane between blocks. The snapshot
        is self-contained: restored via ``fed.serve(params, state=...)``
        the scheduler continues the drain with bitwise-identical token
        streams and byte-identical per-request ledgers."""
        jax.block_until_ready(self._gen_buf_st)
        flat: Dict[str, np.ndarray] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._caches_st)[0]:
            flat[_leafkey("caches", path)] = np.asarray(leaf)
        slot_arrays = {
            "t": self._t_st, "gen_pos": self._gen_pos_st,
            "rem": self._rem_st, "gen_buf": self._gen_buf_st,
            "keydata": self._keydata_st, "tables": self._tables,
        }
        if self._logits_st is not None:
            slot_arrays["logits"] = self._logits_st
        for name, arr in slot_arrays.items():
            flat[f"slot_{name}"] = np.asarray(arr)
        meta = {
            "config": {
                "max_batch": self.max_batch, "seq_len": self.seq_len,
                "n_clients": self.n_clients, "embed_dim": self.embed_dim,
                "vocab_size": self.vocab_size,
                "temperature": self.temperature,
                "page_size": self.page_size, "n_pages": self.n_pages,
                "max_queue": self.max_queue, "preempt": self.preempt,
                "has_logits": self._logits_st is not None,
            },
            "allocator": self.allocator.snapshot(),
            "slots": [None if req is None else self._req_meta(
                req, remaining=int(self._remaining[s]),
                admitted_at=int(self._admitted_at[s]))
                for s, req in enumerate(self._slot_req)],
            "slot_pages": [None if p is None else
                           np.asarray(p).tolist()
                           for p in self._slot_pages],
            "queue": [self._req_meta(r, remaining=0, admitted_at=-1)
                      for r in self._queue],
            "results": [{
                "rid": r.rid, "tokens": np.asarray(r.tokens).tolist(),
                "ledger": _ledger_rows(r.ledger),
                "prompt_len": int(r.prompt_len),
                "admitted_at": int(r.admitted_at),
                "finished_at": int(r.finished_at), "status": r.status,
                "preemptions": int(r.preemptions),
            } for r in self._results.values()],
            "counters": {
                "steps": self.steps, "next_rid": self._next_rid,
                "generated_tokens": self.generated_tokens,
                "host_transfers": self.host_transfers,
                "preemptions": self.preemptions,
                "deadline_misses": self.deadline_misses,
                "poisoned": self.poisoned,
            },
        }
        return SchedulerState(flat=flat, meta=meta)

    @tags.host_boundary("checkpoint restore: rehydrates host-side queue/"
                        "slot/result metadata and uploads the pooled "
                        "caches once — runs before the first decode "
                        "block, never inside it")
    def _load_state(self, state: SchedulerState) -> None:
        cfg = state.meta["config"]
        for k in ("max_batch", "seq_len", "n_clients", "page_size",
                  "n_pages"):
            if int(cfg[k]) != int(getattr(self, k)):
                raise ValueError(
                    f"serve state was captured with {k}={cfg[k]}, this "
                    f"scheduler has {getattr(self, k)} — construct via "
                    "fed.serve(params, state=...) so the config matches")
        leaves, treedef = jax.tree_util.tree_flatten_with_path(
            self._caches_st)
        self._caches_st = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(state.flat[_leafkey("caches", p)],
                                  dtype=leaf.dtype)
                      for p, leaf in leaves])
        self._t_st = jnp.asarray(state.flat["slot_t"])
        self._gen_pos_st = jnp.asarray(state.flat["slot_gen_pos"])
        self._rem_st = jnp.asarray(state.flat["slot_rem"])
        self._gen_buf_st = jnp.asarray(state.flat["slot_gen_buf"])
        self._keydata_st = jnp.asarray(state.flat["slot_keydata"])
        # copy: the snapshot array may be a read-only npz view (or alias
        # a live scheduler's table), and _tables is mutated in place
        self._tables = np.array(state.flat["slot_tables"], np.int32)
        self._tables_dev = None
        if cfg["has_logits"]:
            self._logits_st = jnp.asarray(state.flat["slot_logits"])
        self.allocator = paging.PageAllocator.restore(
            state.meta["allocator"])
        self._slot_req = [None if d is None else self._req_from_meta(d)
                          for d in state.meta["slots"]]
        self._slot_pages = [None if p is None else
                            np.asarray(p, np.int32)
                            for p in state.meta["slot_pages"]]
        self._remaining = np.zeros(self.max_batch, np.int64)
        self._admitted_at = np.zeros(self.max_batch, np.int64)
        for s, d in enumerate(state.meta["slots"]):
            if d is not None:
                self._remaining[s] = int(d["remaining"])
                self._admitted_at[s] = int(d["admitted_at"])
        self._queue = [self._req_from_meta(d)
                       for d in state.meta["queue"]]
        self._results = {}
        for d in state.meta["results"]:
            self._results[int(d["rid"])] = RequestResult(
                rid=int(d["rid"]),
                tokens=np.asarray(d["tokens"], np.int32),
                ledger=_ledger_from_rows(d["ledger"]),
                prompt_len=int(d["prompt_len"]),
                admitted_at=int(d["admitted_at"]),
                finished_at=int(d["finished_at"]),
                status=d["status"], preemptions=int(d["preemptions"]))
        c = state.meta["counters"]
        self.steps = int(c["steps"])
        self._next_rid = int(c["next_rid"])
        self.generated_tokens = int(c["generated_tokens"])
        self.host_transfers = int(c["host_transfers"])
        self.preemptions = int(c["preemptions"])
        self.deadline_misses = int(c["deadline_misses"])
        self.poisoned = int(c["poisoned"])
