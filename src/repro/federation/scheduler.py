"""Continuous batching for the split serve plane.

The sglang-style serving loop, with the VFL party split kept intact: a
:class:`ServeScheduler` owns ``max_batch`` fixed SLOTS over slot-indexed
caches (one leading slot axis over ``cache_specs(1, seq_len)``), admits
queued requests into free slots mid-flight, and drives the whole churning
mix with ONE compiled step — the B=1 split serve step vmapped over slots
with per-slot positions, per-slot sampling keys and an active mask, so
admissions and retirements never retrace.

Per admission the new request's prompt is chunk-prefilled into its slot
(span-aligned ``client_embed`` uploads through ``server_prefill``); per
decode step every active slot samples on device into a per-slot
generation buffer (the host fetches a request's tokens ONCE, at
retirement) and the scheduler logs exactly that slot's wire messages —
so each request's ledger total is identical to a solo ``fed.decode`` of
the same request, however the batch around it churned.

Sampling uses the same ``fold_in(request_key, 100 + t)`` stream as the
solo path, so a request's tokens do not depend on what shared the batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import ModelAdapter
from repro.core.privacy import Ledger
from repro.federation import serving


@dataclasses.dataclass
class ServeRequest:
    """A queued generation request (one sequence; batch=1 on the wire)."""
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    gen_len: int
    key: jax.Array                  # typed PRNG key — solo-compatible stream
    ledger: Ledger = dataclasses.field(default_factory=Ledger)


@dataclasses.dataclass
class RequestResult:
    """One drained request: its tokens and its exact wire ledger."""
    rid: int
    tokens: np.ndarray              # (gen_len,) sampled token ids
    ledger: Ledger
    prompt_len: int
    admitted_at: int                # scheduler step index at admission
    finished_at: int                # scheduler step index at retirement

    @property
    def wire_bytes(self) -> int:
        return self.ledger.total_bytes

    @property
    def transmits_gradients(self) -> bool:
        return self.ledger.transmits_gradients


@functools.lru_cache(maxsize=16)
def make_slot_decode_step(adapter: ModelAdapter, n_clients: int,
                          seq_len: int, temperature: float,
                          vocab_size: int):
    """One continuous-batching decode step, compiled once per slot count.

    The B=1 serve step (sample → owning client embeds → server decodes)
    vmapped over the slot axis: per-slot position ``t``, per-slot key and
    an ``active`` mask (inactive slots compute padding at position 0 and
    keep their counters; their caches are rebuilt from zeros at the next
    admission). The sampled token lands in the slot's on-device
    generation buffer at ``gen_pos`` — no host transfer inside the loop.
    """
    serving._require_serve_plane(adapter)
    span = seq_len // n_clients

    def slot_body(params, logits, caches, t, gen_pos, key_data, active,
                  gen_buf):
        key = jax.random.wrap_key_data(key_data)
        nxt = serving.sample_token(logits, key, t, temperature,
                                   vocab_size)                     # (1,)
        idx = jnp.clip(gen_pos, 0, gen_buf.shape[0] - 1)
        gen_buf = gen_buf.at[idx].set(
            jnp.where(active > 0, nxt[0], gen_buf[idx]))
        ts = jnp.where(active > 0, t, 0)
        m = ts // span
        client_m = jax.tree.map(lambda a: a[m], params["clients"])
        e = adapter.client_embed(client_m, nxt[:, None])
        logits, caches = adapter.server_decode(params["server"], e, caches,
                                               ts)
        return logits, caches, t + active, gen_pos + active, gen_buf

    batched = jax.vmap(slot_body, in_axes=(None, 0, 0, 0, 0, 0, 0, 0))
    return jax.jit(batched, donate_argnums=(1, 2, 3, 4, 7))


@functools.lru_cache(maxsize=16)
def make_slot_write(adapter: ModelAdapter):
    """Jitted slot-state writer: installs a freshly prefilled slot (its
    caches + decode-seed logits) into the stacked slot state."""

    def write(caches_st, logits_st, slot_caches, slot_logits, i):
        caches_st = jax.tree.map(lambda a, b: a.at[i].set(b), caches_st,
                                 slot_caches)
        return caches_st, logits_st.at[i].set(slot_logits)

    return jax.jit(write, donate_argnums=(0, 1))


class ServeScheduler:
    """Continuous-batching engine over the split serve plane.

    ``submit()`` queues requests; ``run()`` drains the queue through the
    fixed slots and returns :class:`RequestResult` per request (rid
    order). Construct via :meth:`repro.federation.Federation.serve`.
    """

    def __init__(self, adapter: ModelAdapter, transport, *, params,
                 n_clients: int, seq_len: int, embed_dim: int,
                 vocab_size: int, max_batch: int = 4,
                 temperature: float = 0.0):
        serving._require_serve_plane(adapter)
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.adapter = adapter
        self.transport = transport
        self.params = params
        self.n_clients = n_clients
        self.seq_len = seq_len
        self.span = seq_len // n_clients
        self.embed_dim = embed_dim
        self.vocab_size = vocab_size
        self.max_batch = max_batch
        self.temperature = float(temperature)

        self._queue: List[ServeRequest] = []
        self._next_rid = 0
        self._slot_req: List[Optional[ServeRequest]] = [None] * max_batch
        self._remaining = np.zeros(max_batch, np.int64)
        self._admitted_at = np.zeros(max_batch, np.int64)
        self._results: Dict[int, RequestResult] = {}

        # device-side slot state (logits dtype is model-dependent; built
        # lazily from the first prefill)
        self._caches_st = None      # leading (max_batch,) slot axis
        self._logits_st = None      # (slots, 1, 1, vocab)
        self._t_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_pos_st = jnp.zeros(max_batch, jnp.int32)
        self._active_st = jnp.zeros(max_batch, jnp.int32)
        self._gen_buf_st = jnp.zeros((max_batch, seq_len), jnp.int32)
        kd = jax.random.key_data(jax.random.key(0))
        self._keydata_st = jnp.zeros((max_batch,) + kd.shape, kd.dtype)

        # the hot-loop executable, resolved once: slot shapes are fixed by
        # construction (admissions/retirements never retrace), so _step
        # must not pay a per-token cache-key rebuild over the param tree
        self._step_prog = None

        # perf counters (the throughput bench reads these)
        self.steps = 0
        self.compile_s = 0.0
        self.generated_tokens = 0
        self.last_run_s = 0.0

    # ------------------------------------------------------- queueing ----
    def submit(self, prompt, gen_len: int, *, seed: Optional[int] = None,
               key=None) -> int:
        """Queue one request; returns its rid. ``key`` (or ``seed``) is
        the request's sampling stream — the SAME key given to a solo
        ``fed.decode`` yields the same tokens. Without either, each
        request gets its own stream (folded from its rid), so concurrent
        sampled requests are never correlated."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1 or gen_len < 1:
            raise ValueError(
                f"need a non-empty prompt and gen_len >= 1, got "
                f"prompt_len={prompt.size}, gen_len={gen_len}")
        if prompt.size + gen_len > self.seq_len:
            raise ValueError(
                f"prompt_len + gen_len = {prompt.size + gen_len} exceeds "
                f"the session seq_len {self.seq_len}")
        rid = self._next_rid
        if key is None and seed is None:
            key = jax.random.fold_in(jax.random.key(0), rid)
        elif key is None:
            key = jax.random.key(seed)
        self._next_rid += 1
        self._queue.append(ServeRequest(rid=rid, prompt=prompt,
                                        gen_len=gen_len, key=key))
        return rid

    # ------------------------------------------------------ admission ----
    def _admit(self, slot: int, req: ServeRequest):
        """Chunk-prefill the request's prompt into the slot (fresh zero
        caches) and install the slot state. Prefill wire traffic is
        logged at admission: prompt_len embedding uploads, no downlink."""
        B1 = 1
        prompt_len = req.prompt.size
        caches = serving.zero_caches(self.adapter, B1, self.seq_len)
        toks = jnp.asarray(req.prompt[None], jnp.int32)
        if self.adapter.server_prefill is not None:
            chunk_fn = serving.make_prefill_chunk(self.adapter,
                                                  self.n_clients,
                                                  self.seq_len)
            logits = None
            for t0, t1, m in serving.prefill_plan(prompt_len, self.span):
                prog, dt = serving.compiled_with_timing(
                    chunk_fn, self.params, toks[:, t0:t1], caches, t0, m)
                self.compile_s += dt
                logits, caches = prog(self.params, toks[:, t0:t1], caches,
                                      t0, m)
        else:
            step = serving.make_serve_step(self.adapter, self.n_clients,
                                           self.seq_len)
            prog, dt = serving.compiled_with_timing(
                step, self.params, toks[:, :1], caches, 0)
            self.compile_s += dt
            logits = None
            for t in range(prompt_len):
                logits, caches = prog(self.params, toks[:, t:t + 1],
                                      caches, t)

        if self._caches_st is None:
            # first admission fixes the stacked dtypes/shapes
            self._caches_st = jax.tree.map(
                lambda a: jnp.zeros((self.max_batch,) + a.shape, a.dtype),
                caches)
            self._logits_st = jnp.zeros(
                (self.max_batch,) + logits.shape, logits.dtype)
        write = make_slot_write(self.adapter)
        prog, dt = serving.compiled_with_timing(
            write, self._caches_st, self._logits_st, caches, logits, slot)
        self.compile_s += dt
        self._caches_st, self._logits_st = prog(
            self._caches_st, self._logits_st, caches, logits, slot)

        self._t_st = self._t_st.at[slot].set(prompt_len)
        self._gen_pos_st = self._gen_pos_st.at[slot].set(0)
        self._active_st = self._active_st.at[slot].set(1)
        self._keydata_st = self._keydata_st.at[slot].set(
            jax.random.key_data(req.key))
        self._slot_req[slot] = req
        self._remaining[slot] = req.gen_len
        self._admitted_at[slot] = self.steps
        self.transport.account_serve(batch=B1, embed=self.embed_dim,
                                     n_steps=prompt_len, n_gen=0,
                                     ledger=req.ledger)

    def _admit_free_slots(self):
        for slot in range(self.max_batch):
            if self._slot_req[slot] is None and self._queue:
                self._admit(slot, self._queue.pop(0))

    # ----------------------------------------------------- the engine ----
    def _step(self):
        """One continuous-batching step: every active slot samples its
        next token and advances one position — one compiled dispatch for
        the whole mix, per-slot wire metering on the host."""
        if self._step_prog is None:
            step_fn = make_slot_decode_step(self.adapter, self.n_clients,
                                            self.seq_len, self.temperature,
                                            self.vocab_size)
            self._step_prog, dt = serving.compiled_with_timing(
                step_fn, self.params, self._logits_st, self._caches_st,
                self._t_st, self._gen_pos_st, self._keydata_st,
                self._active_st, self._gen_buf_st)
            self.compile_s += dt
        (self._logits_st, self._caches_st, self._t_st, self._gen_pos_st,
         self._gen_buf_st) = self._step_prog(
            self.params, self._logits_st, self._caches_st, self._t_st,
            self._gen_pos_st, self._keydata_st, self._active_st,
            self._gen_buf_st)
        self.steps += 1
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            self.transport.account_serve_step(
                batch=1, embed=self.embed_dim, ledger=req.ledger)
            self.generated_tokens += 1
            self._remaining[slot] -= 1
            if self._remaining[slot] <= 0:
                self._retire(slot)

    def _retire(self, slot: int):
        """The request's tokens leave the device HERE — one transfer per
        request, at retirement."""
        req = self._slot_req[slot]
        toks = np.asarray(self._gen_buf_st[slot, :req.gen_len])
        self._results[req.rid] = RequestResult(
            rid=req.rid, tokens=toks, ledger=req.ledger,
            prompt_len=req.prompt.size,
            admitted_at=int(self._admitted_at[slot]),
            finished_at=self.steps)
        self._slot_req[slot] = None
        self._active_st = self._active_st.at[slot].set(0)

    # ----------------------------------------------------------- drive ----
    @property
    def active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def run(self) -> List[RequestResult]:
        """Drain the queue: admit into free slots as they open up
        mid-flight, step the batch until every submitted request is done.
        Returns THIS drain's results in rid order (requests drained by an
        earlier ``run()`` stay retrievable via ``results``); wall-clock
        minus compile is exposed as ``last_run_s``."""
        draining = sorted([r.rid for r in self._queue]
                          + [r.rid for r in self._slot_req if r is not None])
        tic = time.perf_counter()
        compile0 = self.compile_s
        while self._queue or self.active:
            self._admit_free_slots()
            self._step()
        jax.block_until_ready(self._gen_buf_st)
        self.last_run_s = (time.perf_counter() - tic
                           - (self.compile_s - compile0))
        return [self._results[rid] for rid in draining]

    @property
    def results(self) -> Dict[int, RequestResult]:
        """Every request this scheduler has ever drained, by rid."""
        return dict(self._results)
