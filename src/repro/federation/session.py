"""The ``Federation`` session: one party-scoped lifecycle API.

``Federation.build(model_cfg, vfl_cfg, engine_cfg)`` resolves the three
orthogonal choices every entry point used to wire by hand —

* the MODEL plane: a :class:`repro.core.adapters.ModelAdapter` (given
  directly, derived from a ``PaperMLPConfig``, or derived from any
  registered LM-scale ``ModelConfig`` via ``adapters.from_model_config``),
* the WIRE: a :class:`repro.federation.Transport` (canonical method name,
  ledger ownership, optional DP noise channel on the loss downlink),
* the EXECUTION substrate: the device-sharded client mesh, picked from
  ``engine_cfg.mesh_shards`` instead of a loose ``mesh=`` kwarg —

and the whole lifecycle runs off the same session object:

* TRAIN — :meth:`run` (asynchronous engine: staleness semantics, one
  jitted ``lax.scan``) and :meth:`sync_step` (jitted cascade/baseline
  step factories the ``launch/train.py`` driver pumps batches through);
* CHECKPOINT/RESUME — :meth:`save` writes one directory per PARTY
  (``fed.parties``: the server's directory contains zero client leaves
  and vice versa) plus the session state (step, optimizer state, wire
  ledger totals, spent DP budget); :meth:`restore` rebuilds the session
  and state so a resumed run continues allclose to an uninterrupted one
  with ledger and (ε, δ) totals exactly continued;
* SERVE — :meth:`serve_step` / :meth:`decode` run split inference with
  the SAME party split as training (clients embed their token spans,
  the server owns backbone + head + caches), routed through the
  ``Transport`` so serve-time wire traffic lands in the ledger.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.checkpoint.io import atomic_write, load_tree, save_checkpoint
from repro.configs.base import ModelConfig, VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine, cascade
from repro.core.adapters import (ModelAdapter, from_model_config,
                                 lm_engine_params, tabular_adapter)
from repro.core.methods import canonical_method
from repro.core.partition import merge_params, split_params
from repro.core.privacy import GaussianLossChannel, Ledger
from repro.federation import serving
from repro.federation.parties import (ClientParty, Parties, ServerParty,
                                      is_engine_layout)
from repro.federation.transport import Transport
from repro.launch.mesh import make_client_mesh
from repro.models import model_api
from repro.sharding.rules import PARAM_RULES, resolve_spec

ModelLike = Union[ModelAdapter, ModelConfig, PaperMLPConfig]

SESSION_MANIFEST = "session.json"
CHECKPOINT_VERSION = 1


@dataclasses.dataclass
class SessionState:
    """The non-parameter state a checkpoint carries: everything a resumed
    run needs to continue EXACTLY (not just approximately) — the step
    clock, the optimizer/schedule state, the Transport ledger totals, and
    the DP accountant's release count."""
    step: int = 0
    opt_state: Optional[Any] = None
    ledger: Ledger = dataclasses.field(default_factory=Ledger)
    dp_releases: int = 0
    # the population engine's full mutable state (embedding table, delay
    # counters, activity clock, fault counters) — set when the checkpoint
    # was taken mid-``run_population``, so the resumed wire run replays
    # the remaining rounds bitwise (see async_engine.AsyncPlaneState)
    async_state: Optional[async_engine.AsyncPlaneState] = None
    # the serve plane's full mutable state (admission queue, slot/block
    # tables, page-pool free list, gen buffers, per-request ledgers, RNG
    # streams) — set when the checkpoint was taken mid-drain, so
    # ``fed.serve(params, state=...)`` resumes the drain bitwise (a
    # ``scheduler.SchedulerState``; typed Any to keep the scheduler
    # import lazy)
    serve_state: Optional[Any] = None
    # the free-form metadata the saver passed to ``fed.save`` (driver
    # knobs like batch/seed/schedule live here, not in the session)
    metadata: dict = dataclasses.field(default_factory=dict)

    def dp_spent(self, transport: Transport) -> Tuple[float, float]:
        return transport.privacy_spent(self.dp_releases)


@dataclasses.dataclass
class Federation:
    """A built training session; construct via :meth:`build`."""
    vfl: VFLConfig
    engine: async_engine.EngineConfig
    transport: Transport
    mesh: Optional[Mesh] = None
    # set for ModelConfig-built sessions (the sync-driver plane)
    model_cfg: Optional[ModelConfig] = None
    n_clients: int = 2
    seq_len: int = 32
    _adapter: Optional[ModelAdapter] = None
    _model: Optional[model_api.Model] = None

    # ----------------------------------------------------------- build ----
    @classmethod
    def build(cls, model_cfg: ModelLike,
              vfl_cfg: Optional[VFLConfig] = None,
              engine_cfg: Optional[async_engine.EngineConfig] = None, *,
              noise: Optional[GaussianLossChannel] = None,
              transport: Optional[Transport] = None,
              mesh: Optional[Mesh] = None,
              n_clients: int = 2, seq_len: int = 32,
              model: Optional[model_api.Model] = None) -> "Federation":
        """One constructor for every entry point.

        ``model_cfg`` may be a ready :class:`ModelAdapter`, the paper's
        ``PaperMLPConfig`` (tabular protocol), or any ``ModelConfig`` from
        the arch registry (clients own the embedding, server owns the
        backbone; ``n_clients``/``seq_len`` size the vertical token
        split). ``noise`` plugs a DP channel into the transport's loss
        downlink. ``mesh`` is normally derived from
        ``engine_cfg.mesh_shards``; passing an explicit ``Mesh`` is the
        back-compat escape hatch ``async_engine.run`` uses. ``model``
        injects a pre-built :class:`model_api.Model` for a ModelConfig
        session (the dry-run's hook for window/remat/decode variants the
        default ``build_model`` call would not select).
        """
        vfl = vfl_cfg if vfl_cfg is not None else VFLConfig()
        engine = (engine_cfg if engine_cfg is not None
                  else async_engine.EngineConfig())
        if transport is None:
            transport = Transport(engine.method, noise=noise)
        elif noise is not None:
            raise ValueError("pass noise= or a full transport=, not both")
        if canonical_method(engine.method) != transport.method:
            raise ValueError(
                f"engine_cfg.method {engine.method!r} and transport method "
                f"{transport.method!r} disagree")
        if mesh is not None and engine.mesh_shards:
            raise ValueError(
                f"both an explicit mesh= and engine_cfg.mesh_shards="
                f"{engine.mesh_shards} were given; set one (mesh_shards is "
                "the session-native spelling)")
        if mesh is None and engine.mesh_shards:
            mesh = make_client_mesh(engine.mesh_shards)

        adapter = cfg = None
        if isinstance(model_cfg, ModelAdapter):
            adapter = model_cfg
        elif isinstance(model_cfg, PaperMLPConfig):
            adapter = tabular_adapter(model_cfg)
            n_clients = model_cfg.n_clients
        elif isinstance(model_cfg, ModelConfig):
            cfg = model_cfg
        else:
            raise TypeError(
                f"model_cfg must be a ModelAdapter, PaperMLPConfig or "
                f"ModelConfig, got {type(model_cfg).__name__}")
        if model is not None and cfg is None:
            raise ValueError("model= injection needs a ModelConfig session")
        return cls(vfl=vfl, engine=engine, transport=transport, mesh=mesh,
                   model_cfg=cfg, n_clients=n_clients,
                   seq_len=seq_len, _adapter=adapter, _model=model)

    # ------------------------------------------------------- model plane --
    @property
    def adapter(self) -> ModelAdapter:
        """The session's ModelAdapter (derived lazily for ModelConfig
        sessions — families without an async bridge, e.g. encoder-decoder,
        can still drive the sync path). ``vfl.active_rows_only`` gates the
        active-row ZOO mask, matching the sync plane's semantics; the
        derivation is re-resolved per access (``from_model_config`` is
        lru-cached) so a ``fed.vfl`` update never serves a stale mask."""
        if self._adapter is not None:
            return self._adapter
        return from_model_config(
            self.model_cfg, n_clients=self.n_clients, seq_len=self.seq_len,
            active_rows=self.vfl.active_rows_only)

    @property
    def model(self) -> Optional[model_api.Model]:
        """The global model (sync-driver plane); built lazily so
        async-only sessions never construct it."""
        if self._model is None and self.model_cfg is not None:
            self._model = model_api.build_model(self.model_cfg,
                                                max_seq=self.seq_len)
        return self._model

    def init_params(self, key):
        """Engine-layout params ({"clients": (M, ...), "server": ...})."""
        return self.adapter.init_params(key)

    def params_from_global(self, global_params):
        """Replicate a global ``build_model`` param tree into the engine
        layout (each client party gets the same embedding table)."""
        if self.model_cfg is None:
            raise ValueError("params_from_global needs a ModelConfig-built "
                             "session (tabular/adapter sessions already use "
                             "the engine layout)")
        return lm_engine_params(global_params, self.n_clients)

    # ------------------------------------------------------ async driver --
    def run(self, params, x_parts, y, *, probs=None
            ) -> async_engine.EngineResult:
        """Asynchronous protocol simulation (staleness, blocks, sharding).

        ``x_parts``: (M, n, f) vertically partitioned features — token
        spans (int32) for LM sessions; ``y``: (n,) labels, or (n, S)
        next-token labels for LM sessions."""
        return async_engine._session_run(
            self.adapter, self.transport, self.vfl, self.engine,
            params, x_parts, y, probs=probs, mesh=self.mesh)

    def run_population(self, params, x_parts, y, *, probs=None,
                       fault_plan=None, population=None, channels=None,
                       state=None, ledger: Optional[Ledger] = None,
                       dp_releases: int = 0, until: Optional[int] = None,
                       stop_workers: bool = True
                       ) -> "async_engine.PopulationResult":
        """The asynchronous protocol over the REAL wire (``repro.wire``).

        Same schedule/RNG/staleness semantics as :meth:`run` — with
        ``FaultPlan.none()`` the two are bitwise-identical — but every
        client sits behind a wire backend (in-proc loopback by default;
        ``channels={m: backend}`` places party m behind e.g. a connected
        socket whose worker process runs ``ClientWorker.serve``), frames
        are genuinely serialized and metered at their actual byte size,
        and ``fault_plan`` injects deterministic drops/latency.
        ``state``/``until``/``ledger``/``dp_releases`` continue a
        checkpointed run exactly (see :meth:`save`'s ``async_state``)."""
        return async_engine.run_population(
            self.adapter, self.transport, self.vfl, self.engine,
            params, x_parts, y, probs=probs, fault_plan=fault_plan,
            population=population, channels=channels, state=state,
            ledger=ledger, dp_releases=dp_releases, until=until,
            stop_workers=stop_workers)

    # ------------------------------------------------------- sync driver --
    def sync_step(self, optimizer, *, vocab: Optional[int] = None):
        """Jitted cascade/baseline step over the GLOBAL model's loss —
        the ``launch/train.py`` plane. Requires a ModelConfig session."""
        if self.model_cfg is None:
            raise ValueError(
                "sync_step drives a global-model loss; build the session "
                "from a ModelConfig (tabular/adapter sessions train through "
                "Federation.run)")
        vocab = self.model_cfg.padded_vocab if vocab is None else vocab
        return cascade.make_step_for_method(
            self.transport.method, self.model.loss_fn,
            self.model.client_keys, self.vfl, optimizer, vocab=vocab,
            transport=self.transport)

    # -------------------------------------------------- certifier plane ---
    def boundary_meta(self) -> dict:
        """Boundary metadata for the jaxpr certifier
        (``repro.analysis.certify``): everything the information-flow
        rules need to size the legal bottleneck — method, q, block,
        whether a DP channel is configured — read off the session instead
        of asserted by the caller."""
        return {
            "method": self.transport.method,
            "sync": self.transport.sync,
            "zoo_wire": self.transport.zoo_wire,
            "dp": self.transport.noise is not None,
            "zoo_queries": self.vfl.zoo_queries,
            "block": 1 if self.transport.sync else self.engine.block_size,
            "batch": self.engine.batch_size,
            "n_clients": self.n_clients,
            "use_lanes": self.engine.use_lanes,
            "mesh_shards": self.engine.mesh_shards,
        }

    def traceable_train_step(self, *, table_shape=None):
        """The EXACT step closure the jitted scan body runs — sync,
        async, or device-sharded per the engine config — returned
        untraced so ``jax.make_jaxpr`` can walk it. Signature:
        ``step(params, table, m_blk, idx, key, x_parts, y) ->
        (params, table, h)``. The sharded variant needs ``table_shape``
        (the (M, n, e) embedding-table shape) to resolve the table's
        partition spec the same way ``run`` does."""
        if self.transport.sync:
            return async_engine._make_sync_step(
                self.adapter, self.transport, self.vfl)
        if self.mesh is not None:
            if table_shape is None:
                raise ValueError("the sharded step needs table_shape= to "
                                 "resolve the table partition spec")
            table_spec = resolve_spec(self.mesh, tuple(table_shape),
                                      self.adapter.table_logical,
                                      PARAM_RULES)
            return async_engine._make_sharded_step(
                self.adapter, self.transport, self.vfl,
                self.engine.use_lanes, self.mesh, self.engine.block_size,
                table_spec)
        return async_engine._make_async_step(
            self.adapter, self.transport, self.vfl, self.engine.use_lanes)

    def traceable_population_fns(self):
        """The population engine's jitted server-side pair
        ``(server_update, losses_fn)`` (see
        ``async_engine._population_fns``) — ``losses_fn`` is the
        server→client downlink closure the certifier traces: its whole
        output is client-bound."""
        return async_engine._population_fns(self.adapter, self.transport,
                                            self.vfl)

    # ------------------------------------------------------ party plane ---
    @property
    def client_keys(self) -> Tuple[str, ...]:
        """Top-level GLOBAL-layout keys forming the client partition."""
        if self.model_cfg is not None:
            return self.model.client_keys
        return ("clients",)

    @property
    def parties(self) -> Parties:
        """Typed party handles — the one way any plane addresses state.

        ``parties.server`` owns the backbone/head partition,
        ``parties.clients[m]`` owns client m's slice; both resolve against
        either param layout (engine ``{"clients", "server"}`` or the
        global ``build_model`` tree)."""
        keys = self.client_keys
        return Parties(
            server=ServerParty(client_keys=keys),
            clients=tuple(ClientParty(index=m, client_keys=keys)
                          for m in range(self.n_clients)))

    # ------------------------------------------------------ serve plane ---
    def serve_step(self):
        """Jitted one-token split-inference step (see
        :func:`repro.federation.serving.make_serve_step`): the client
        owning the current position embeds the token, the server decodes
        against its caches. Requires a ModelConfig-built session."""
        return serving.make_serve_step(self.adapter, self.n_clients,
                                       self.seq_len)

    def decode(self, params, prompts, *, gen_len: int,
               temperature: float = 0.0, seed: int = 0, key=None,
               ledger: Optional[Ledger] = None, use_scan: bool = True,
               chunked_prefill: bool = True) -> serving.ServeResult:
        """Split inference with the training party split.

        ``params`` may be the engine layout or a global ``build_model``
        tree (replicated into the engine layout via
        :meth:`params_from_global`). ``prompts``: (B, prompt_len) int32;
        ``prompt_len + gen_len`` must fit the session ``seq_len`` (the
        span split is sized to it). Serve-time wire traffic is logged
        through the Transport — pass ``ledger`` to extend a training
        run's totals instead of starting a fresh one.

        Decode runs as one compiled ``lax.scan`` (on-device sampling, one
        host transfer) over a chunk-prefilled cache by default;
        ``use_scan=False`` / ``chunked_prefill=False`` select the
        per-token oracle loops."""
        if self.model_cfg is None:
            raise ValueError(
                "decode needs a ModelConfig-built session (tabular/adapter "
                "sessions have no serve plane)")
        if not is_engine_layout(params):
            params = self.params_from_global(params)
        if key is None:
            key = jax.random.key(seed)
        return serving.run_decode(
            self.adapter, self.transport, n_clients=self.n_clients,
            seq_len=self.seq_len, embed_dim=self.model_cfg.d_model,
            vocab_size=self.model_cfg.vocab_size, params=params,
            prompts=prompts, gen_len=gen_len, temperature=temperature,
            key=key, ledger=ledger, use_scan=use_scan,
            chunked_prefill=chunked_prefill)

    def serve(self, params, *, max_batch: int = 4,
              temperature: float = 0.0, page_size: Optional[int] = None,
              n_pages: Optional[int] = None,
              max_queue: Optional[int] = None, preempt: bool = False,
              state: Optional[Any] = None):
        """A continuous-batching serve session over the split plane.

        Returns a :class:`repro.federation.scheduler.ServeScheduler`:
        ``submit(prompt, gen_len=...)`` queues requests, ``run()`` drains
        them through ``max_batch`` fixed slots — new requests are admitted
        as slots free up mid-flight, compiled multi-step decode blocks
        serve the churning mix, and each request gets its own exact wire
        ledger. Slot caches live in a shared page pool (``page_size``
        must divide ``seq_len``; ``n_pages`` caps pool memory and
        admission-gates requests on free pages when set below the
        ``max_batch`` worst case).

        Failure policy: ``max_queue`` bounds admission (``submit`` raises
        ``QueueFull`` past it) and ``preempt=True`` lets a page-starved
        queue head evict the in-flight request with the fewest tokens
        remaining (bitwise-exact resume). Pass a restored
        ``SessionState.serve_state`` as ``state`` to resume a mid-drain
        snapshot exactly — the scheduler's shape/pool config then comes
        from the snapshot, not from the keyword defaults."""
        from repro.federation.scheduler import ServeScheduler
        if self.model_cfg is None:
            raise ValueError(
                "serve needs a ModelConfig-built session (tabular/adapter "
                "sessions have no serve plane)")
        if not is_engine_layout(params):
            params = self.params_from_global(params)
        if state is not None:
            cfg = state.meta["config"]
            max_batch = int(cfg["max_batch"])
            temperature = float(cfg["temperature"])
            page_size = int(cfg["page_size"])
            n_pages = int(cfg["n_pages"])
            max_queue = cfg["max_queue"]
            preempt = bool(cfg["preempt"])
        srv = ServeScheduler(
            self.adapter, self.transport, params=params,
            n_clients=self.n_clients, seq_len=self.seq_len,
            embed_dim=self.model_cfg.d_model,
            vocab_size=self.model_cfg.vocab_size, max_batch=max_batch,
            temperature=temperature, page_size=page_size, n_pages=n_pages,
            max_queue=max_queue, preempt=preempt)
        if state is not None:
            srv._load_state(state)
        return srv

    # ------------------------------------------------- checkpoint plane ---
    def save(self, path: str, params, *, step: int = 0,
             opt_state: Optional[Any] = None,
             ledger: Optional[Ledger] = None, dp_releases: int = 0,
             async_state: Optional[async_engine.AsyncPlaneState] = None,
             serve_state: Optional[Any] = None,
             metadata: Optional[dict] = None) -> str:
        """Party-scoped checkpoint: one directory per party + session state.

        Layout::

            path/
              session.json     step, configs, ledger totals, DP releases
              server/          server party's leaves ONLY
              client_00/ ...   per-client slices   (engine layout), or
              clients/         the client partition (global layout)
              opt_server/, opt_clients/   optimizer state, split on the
                                          same party boundary (optional)
              async_plane/     the population engine's table/delay/clock
                               state (optional — mid-``run_population``
                               checkpoints; makes the resume bitwise)
              serve_plane/     the serve scheduler's full state (optional
                               — mid-drain checkpoints via
                               ``srv.snapshot()``; makes the resumed
                               drain's tokens and ledgers bitwise)

        The isolation is structural (:mod:`repro.federation.parties`):
        the server handle cannot address a client leaf, so its directory
        provably contains none — and vice versa. Returns ``path`` (the
        token ``Federation.restore`` consumes)."""
        os.makedirs(path, exist_ok=True)
        parties = self.parties
        engine_layout = is_engine_layout(params)
        if engine_layout:
            rows = jax.tree.leaves(params["clients"])[0].shape[0]
            if rows != len(parties.clients):
                raise ValueError(
                    f"params stack {rows} client parties but the session "
                    f"was built with n_clients={len(parties.clients)} — a "
                    "per-party save would silently drop rows; pass "
                    f"n_clients={rows} to Federation.build")
            save_checkpoint(os.path.join(path, parties.server.name),
                            parties.server.owned(params), step=step)
            for party in parties.clients:
                save_checkpoint(os.path.join(path, party.name),
                                party.owned(params), step=step)
        else:
            save_checkpoint(os.path.join(path, "server"),
                            parties.server.owned(params), step=step)
            save_checkpoint(os.path.join(path, "clients"),
                            parties.clients[0].owned(params), step=step)
        if opt_state is not None:
            opt_c, opt_s = self._split_opt_state(opt_state, engine_layout)
            save_checkpoint(os.path.join(path, "opt_server"), opt_s,
                            step=step)
            save_checkpoint(os.path.join(path, "opt_clients"), opt_c,
                            step=step)
        if async_state is not None:
            async_state.save(os.path.join(path, "async_plane"))
        if serve_state is not None:
            serve_state.save(os.path.join(path, "serve_plane"))

        ledger = ledger if ledger is not None else Ledger()
        eps, delta = self.transport.privacy_spent(dp_releases)
        manifest = {
            "version": CHECKPOINT_VERSION,
            "step": int(step),
            "layout": "engine" if engine_layout else "global",
            "has_opt_state": opt_state is not None,
            "model": self._model_manifest(),
            "vfl": dataclasses.asdict(self.vfl),
            "engine": dataclasses.asdict(self.engine),
            "noise": (None if self.transport.noise is None
                      else dataclasses.asdict(self.transport.noise)),
            "n_clients": self.n_clients,
            "seq_len": self.seq_len,
            "ledger_counts": ledger.to_counts(),
            "dp_releases": int(dp_releases),
            "dp_spent": [eps if math.isfinite(eps) else None, delta],
            "async_plane": async_state is not None,
            "serve_plane": serve_state is not None,
            "metadata": metadata or {},
        }
        # atomic + last: a session.json on disk always certifies complete
        # party/plane directories next to it
        atomic_write(os.path.join(path, SESSION_MANIFEST),
                     lambda f: json.dump(manifest, f, indent=2), mode="w")
        return path

    @classmethod
    def restore(cls, path: str, model_cfg: Optional[ModelLike] = None,
                ) -> Tuple["Federation", Any, SessionState]:
        """Rebuild (session, params, state) from a :meth:`save` directory.

        The session's configs (model, vfl, engine, DP channel) come from
        ``session.json``; only adapter-built sessions — whose model plane
        is an arbitrary callable bundle — need the caller to pass the
        ``model_cfg`` (the adapter) back in. ``state.step``/``opt_state``/
        ``ledger``/``dp_releases`` continue a training run exactly:
        re-drive the same batches from ``state.step`` and the trajectory
        is allclose to one that never stopped."""
        with open(os.path.join(path, SESSION_MANIFEST)) as f:
            manifest = json.load(f)
        if manifest["version"] != CHECKPOINT_VERSION:
            raise ValueError(
                f"checkpoint version {manifest['version']} != "
                f"{CHECKPOINT_VERSION}")

        model = cls._model_from_manifest(manifest["model"], model_cfg)
        vfl_d = dict(manifest["vfl"])
        if vfl_d.get("activation_probs") is not None:
            vfl_d["activation_probs"] = tuple(vfl_d["activation_probs"])
        noise_d = manifest["noise"]
        fed = cls.build(
            model, VFLConfig(**vfl_d),
            async_engine.EngineConfig(**manifest["engine"]),
            noise=None if noise_d is None else GaussianLossChannel(**noise_d),
            n_clients=manifest["n_clients"], seq_len=manifest["seq_len"])

        server_tree, _, _ = load_tree(os.path.join(path, "server"))
        if manifest["layout"] == "engine":
            client_trees = [
                load_tree(os.path.join(path, party.name))[0]
                for party in fed.parties.clients]
            params = fed.parties.assemble(server_tree, client_trees)
        else:
            client_tree, _, _ = load_tree(os.path.join(path, "clients"))
            params = fed.parties.merge_global(server_tree, client_tree)

        opt_state = None
        if manifest["has_opt_state"]:
            opt_s, _, _ = load_tree(os.path.join(path, "opt_server"))
            opt_c, _, _ = load_tree(os.path.join(path, "opt_clients"))
            opt_state = fed._merge_opt_state(
                opt_c, opt_s, manifest["layout"] == "engine")

        async_state = None
        if manifest.get("async_plane"):
            async_state = async_engine.AsyncPlaneState.load(
                os.path.join(path, "async_plane"))
        serve_state = None
        if manifest.get("serve_plane"):
            from repro.federation.scheduler import SchedulerState
            serve_state = SchedulerState.load(
                os.path.join(path, "serve_plane"))

        state = SessionState(
            step=manifest["step"], opt_state=opt_state,
            ledger=Ledger.from_counts(manifest["ledger_counts"]),
            dp_releases=manifest["dp_releases"],
            async_state=async_state, serve_state=serve_state,
            metadata=manifest.get("metadata", {}))
        return fed, params, state

    # ----------------------------------------------- checkpoint helpers ---
    def _model_manifest(self) -> dict:
        if self.model_cfg is not None:
            return {"kind": "model_config",
                    "data": dataclasses.asdict(self.model_cfg)}
        if (self._adapter is not None
                and self._adapter.name.startswith("tabular")):
            # a tabular adapter is fully determined by its PaperMLPConfig;
            # reconstruct it from the stacked client/server spec shapes
            spec = self._adapter.param_specs()
            M, f, e = spec["clients"]["w"].shape
            se, C = spec["server"]["w2"].shape
            return {"kind": "paper_mlp",
                    "data": dataclasses.asdict(PaperMLPConfig(
                        n_features=M * f, n_classes=C, n_clients=M,
                        client_embed=e, server_embed=se))}
        return {"kind": "adapter", "data": self.adapter.name}

    @staticmethod
    def _model_from_manifest(m: dict, model_cfg: Optional[ModelLike]):
        if model_cfg is not None:
            return model_cfg
        if m["kind"] == "model_config":
            return ModelConfig(**m["data"])
        if m["kind"] == "paper_mlp":
            return PaperMLPConfig(**m["data"])
        raise ValueError(
            f"checkpoint was saved from an adapter-built session "
            f"({m['data']!r}); pass the adapter back via "
            "Federation.restore(path, model_cfg=adapter)")

    def _split_opt_state(self, opt_state, engine_layout: bool):
        """Split optimizer state on the party boundary: per-parameter
        trees (momentum, adam moments) mirror the param layout and split
        like params; the step clock lives with the server (the session's
        round counter is server-side in the protocol)."""
        opt_c, opt_s = {}, {}
        for k, v in opt_state.items():
            if k == "step":
                opt_s[k] = v
            elif engine_layout:
                opt_c[k] = v["clients"]
                opt_s[k] = v["server"]
            else:
                opt_c[k], opt_s[k] = split_params(v, self.client_keys)
        return opt_c, opt_s

    def _merge_opt_state(self, opt_c, opt_s, engine_layout: bool):
        out = {}
        for k, v in opt_s.items():
            if k == "step":
                out[k] = jnp.asarray(v)
            elif engine_layout:
                out[k] = {"clients": opt_c[k], "server": v}
            else:
                out[k] = merge_params(opt_c.get(k, {}), v)
        return out
