"""The ``Federation`` session: one constructor for every training plane.

``Federation.build(model_cfg, vfl_cfg, engine_cfg)`` resolves the three
orthogonal choices every entry point used to wire by hand —

* the MODEL plane: a :class:`repro.core.adapters.ModelAdapter` (given
  directly, derived from a ``PaperMLPConfig``, or derived from any
  registered LM-scale ``ModelConfig`` via ``adapters.from_model_config``),
* the WIRE: a :class:`repro.federation.Transport` (canonical method name,
  ledger ownership, optional DP noise channel on the loss downlink),
* the EXECUTION substrate: the device-sharded client mesh, picked from
  ``engine_cfg.mesh_shards`` instead of a loose ``mesh=`` kwarg —

and both protocol drivers run off the same session object:
:meth:`Federation.run` for the asynchronous engine (staleness semantics,
``lax.scan``), :meth:`Federation.sync_step` for the jitted cascade step
factories that ``launch/train.py`` drives over real batches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from jax.sharding import Mesh

from repro.configs.base import ModelConfig, VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine, cascade
from repro.core.adapters import (ModelAdapter, from_model_config,
                                 lm_engine_params, tabular_adapter)
from repro.core.methods import canonical_method
from repro.core.privacy import GaussianLossChannel
from repro.federation.transport import Transport
from repro.launch.mesh import make_client_mesh
from repro.models import model_api

ModelLike = Union[ModelAdapter, ModelConfig, PaperMLPConfig]


@dataclasses.dataclass
class Federation:
    """A built training session; construct via :meth:`build`."""
    vfl: VFLConfig
    engine: async_engine.EngineConfig
    transport: Transport
    mesh: Optional[Mesh] = None
    # set for ModelConfig-built sessions (the sync-driver plane)
    model_cfg: Optional[ModelConfig] = None
    n_clients: int = 2
    seq_len: int = 32
    _adapter: Optional[ModelAdapter] = None
    _model: Optional[model_api.Model] = None

    # ----------------------------------------------------------- build ----
    @classmethod
    def build(cls, model_cfg: ModelLike,
              vfl_cfg: Optional[VFLConfig] = None,
              engine_cfg: Optional[async_engine.EngineConfig] = None, *,
              noise: Optional[GaussianLossChannel] = None,
              transport: Optional[Transport] = None,
              mesh: Optional[Mesh] = None,
              n_clients: int = 2, seq_len: int = 32) -> "Federation":
        """One constructor for every entry point.

        ``model_cfg`` may be a ready :class:`ModelAdapter`, the paper's
        ``PaperMLPConfig`` (tabular protocol), or any ``ModelConfig`` from
        the arch registry (clients own the embedding, server owns the
        backbone; ``n_clients``/``seq_len`` size the vertical token
        split). ``noise`` plugs a DP channel into the transport's loss
        downlink. ``mesh`` is normally derived from
        ``engine_cfg.mesh_shards``; passing an explicit ``Mesh`` is the
        back-compat escape hatch ``async_engine.run`` uses.
        """
        vfl = vfl_cfg if vfl_cfg is not None else VFLConfig()
        engine = (engine_cfg if engine_cfg is not None
                  else async_engine.EngineConfig())
        if transport is None:
            transport = Transport(engine.method, noise=noise)
        elif noise is not None:
            raise ValueError("pass noise= or a full transport=, not both")
        if canonical_method(engine.method) != transport.method:
            raise ValueError(
                f"engine_cfg.method {engine.method!r} and transport method "
                f"{transport.method!r} disagree")
        if mesh is not None and engine.mesh_shards:
            raise ValueError(
                f"both an explicit mesh= and engine_cfg.mesh_shards="
                f"{engine.mesh_shards} were given; set one (mesh_shards is "
                "the session-native spelling)")
        if mesh is None and engine.mesh_shards:
            mesh = make_client_mesh(engine.mesh_shards)

        adapter = cfg = None
        if isinstance(model_cfg, ModelAdapter):
            adapter = model_cfg
        elif isinstance(model_cfg, PaperMLPConfig):
            adapter = tabular_adapter(model_cfg)
            n_clients = model_cfg.n_clients
        elif isinstance(model_cfg, ModelConfig):
            cfg = model_cfg
        else:
            raise TypeError(
                f"model_cfg must be a ModelAdapter, PaperMLPConfig or "
                f"ModelConfig, got {type(model_cfg).__name__}")
        return cls(vfl=vfl, engine=engine, transport=transport, mesh=mesh,
                   model_cfg=cfg, n_clients=n_clients,
                   seq_len=seq_len, _adapter=adapter)

    # ------------------------------------------------------- model plane --
    @property
    def adapter(self) -> ModelAdapter:
        """The session's ModelAdapter (derived lazily for ModelConfig
        sessions — families without an async bridge, e.g. encoder-decoder,
        can still drive the sync path). ``vfl.active_rows_only`` gates the
        active-row ZOO mask, matching the sync plane's semantics; the
        derivation is re-resolved per access (``from_model_config`` is
        lru-cached) so a ``fed.vfl`` update never serves a stale mask."""
        if self._adapter is not None:
            return self._adapter
        return from_model_config(
            self.model_cfg, n_clients=self.n_clients, seq_len=self.seq_len,
            active_rows=self.vfl.active_rows_only)

    @property
    def model(self) -> Optional[model_api.Model]:
        """The global model (sync-driver plane); built lazily so
        async-only sessions never construct it."""
        if self._model is None and self.model_cfg is not None:
            self._model = model_api.build_model(self.model_cfg,
                                                max_seq=self.seq_len)
        return self._model

    def init_params(self, key):
        """Engine-layout params ({"clients": (M, ...), "server": ...})."""
        return self.adapter.init_params(key)

    def params_from_global(self, global_params):
        """Replicate a global ``build_model`` param tree into the engine
        layout (each client party gets the same embedding table)."""
        if self.model_cfg is None:
            raise ValueError("params_from_global needs a ModelConfig-built "
                             "session (tabular/adapter sessions already use "
                             "the engine layout)")
        return lm_engine_params(global_params, self.n_clients)

    # ------------------------------------------------------ async driver --
    def run(self, params, x_parts, y, *, probs=None
            ) -> async_engine.EngineResult:
        """Asynchronous protocol simulation (staleness, blocks, sharding).

        ``x_parts``: (M, n, f) vertically partitioned features — token
        spans (int32) for LM sessions; ``y``: (n,) labels, or (n, S)
        next-token labels for LM sessions."""
        return async_engine._session_run(
            self.adapter, self.transport, self.vfl, self.engine,
            params, x_parts, y, probs=probs, mesh=self.mesh)

    # ------------------------------------------------------- sync driver --
    def sync_step(self, optimizer, *, vocab: Optional[int] = None):
        """Jitted cascade/baseline step over the GLOBAL model's loss —
        the ``launch/train.py`` plane. Requires a ModelConfig session."""
        if self.model_cfg is None:
            raise ValueError(
                "sync_step drives a global-model loss; build the session "
                "from a ModelConfig (tabular/adapter sessions train through "
                "Federation.run)")
        vocab = self.model_cfg.padded_vocab if vocab is None else vocab
        return cascade.make_step_for_method(
            self.transport.method, self.model.loss_fn,
            self.model.client_keys, self.vfl, optimizer, vocab=vocab,
            transport=self.transport)
