"""Paged slot storage for the continuous-batching serve plane.

The first ServeScheduler stacked one dense ``cache_specs(1, seq_len)``
tree per slot, so every request paid ``seq_len``-padded cache memory no
matter how short it was, and peak slot-cache memory was always
``max_batch × seq_len``. This module rebuilds that storage sglang-style:

* Sequence-indexed cache leaves (attention K/V, the MLA latent — any
  leaf whose spec carries a ``"cache_seq"`` logical axis) move into ONE
  shared page pool of shape ``(layers, n_pages, page_size, *tail)``.
  Requests hold pages, not slots-worth of sequence: a request of total
  length L holds ``ceil(L / page_size)`` pages, and peak pool usage
  tracks the *actual* lengths in flight.
* Recurrent state leaves (SSM state, conv tails, RWKV wkv/shift — leaves
  with ``"cache_batch"`` but no ``"cache_seq"``) stay slot-stacked:
  their size is sequence-independent, so there is nothing to page.

Two pool pages are reserved:

* page ``0`` (``ZERO_PAGE``) is read-only zeros. Block-table entries of
  positions a request never reached point here, so gathers over a slot's
  full table read exact ``0.0`` beyond its allocation — bitwise-identical
  to the dense zero caches the paged pool replaces (masked positions
  contribute exactly ``exp(NEG_INF - max) = 0.0`` to attention either
  way, so values past ``cur_pos`` never matter; see
  ``attention.decode_attend``).
* page ``1`` (``TRASH_PAGE``) absorbs the writes of INACTIVE slots: the
  batched decode step always scatters a k/v row per slot, and routing
  retired slots' rows here means a freed page can be handed to the next
  request without re-zeroing — its stale contents sit beyond the new
  request's ``cur_pos`` and are masked exactly.

The host-side :class:`PageAllocator` is a plain free list; block tables
live on the host as ``(max_batch, seq_len // page_size)`` int32 rows and
ride into the compiled block step as a small device array per call.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Iterable

import jax
import numpy as np

from repro.models.common import ParamSpec

ZERO_PAGE = 0
TRASH_PAGE = 1
N_RESERVED = 2


def default_page_size(seq_len: int, cap: int = 8) -> int:
    """Largest page size <= ``cap`` that divides ``seq_len`` exactly.

    ``page_size`` must tile ``seq_len`` so a full block table gathers
    exactly ``seq_len`` positions — the same masked extent the dense
    slot caches exposed, which is what keeps the paged decode
    bitwise-equal to the dense path."""
    for p in range(min(cap, seq_len), 0, -1):
        if seq_len % p == 0:
            return p
    return 1


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def _is_spec(x: object) -> bool:
    return isinstance(x, ParamSpec)


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """How one dense cache leaf maps onto paged storage.

    ``pooled`` leaves drop their ``cache_batch`` axis and split their
    ``cache_seq`` axis into ``(n_pages, page_size)``; state leaves keep
    their layout with the batch axis widened to the slot count."""
    pooled: bool
    batch_axis: int
    seq_axis: int = -1


def leaf_plans(dense_specs: Any) -> Any:
    """LeafPlan tree matching ``cache_specs(1, seq_len)`` leaf-for-leaf."""

    def one(s: ParamSpec) -> LeafPlan:
        logical = s.logical if s.logical else (None,) * len(s.shape)
        if "cache_batch" not in logical:
            raise ValueError(
                f"cache spec leaf {s.shape} has no 'cache_batch' logical "
                f"axis ({logical}) — cannot slot-stack it")
        b = logical.index("cache_batch")
        if "cache_seq" in logical:
            q = logical.index("cache_seq")
            if q != b + 1:
                raise ValueError(
                    f"pooled leaf expects cache_seq right after "
                    f"cache_batch, got axes ({b}, {q}) in {logical}")
            return LeafPlan(pooled=True, batch_axis=b, seq_axis=q)
        return LeafPlan(pooled=False, batch_axis=b)

    return jax.tree.map(one, dense_specs, is_leaf=_is_spec)


def paged_specs(dense_specs: Any, *, n_slots: int, n_pages: int,
                page_size: int) -> Any:
    """Transform ``cache_specs(1, seq_len)`` into the paged layout."""
    plans = leaf_plans(dense_specs)

    def one(s: ParamSpec, plan: LeafPlan) -> ParamSpec:
        logical = s.logical if s.logical else (None,) * len(s.shape)
        if plan.pooled:
            b, q = plan.batch_axis, plan.seq_axis
            shape = (s.shape[:b] + (n_pages, page_size) + s.shape[q + 1:])
            log = (logical[:b] + ("cache_pages", None) + logical[q + 1:])
        else:
            b = plan.batch_axis
            shape = s.shape[:b] + (n_slots,) + s.shape[b + 1:]
            log = logical
        return ParamSpec(shape, s.dtype, log, s.init, s.scale)

    return jax.tree.map(one, dense_specs, plans, is_leaf=_is_spec)


def install_rows(page_ids: np.ndarray, n_tokens: int,
                 page_size: int) -> np.ndarray:
    """Flat pool-row indices for positions ``0 .. n_tokens-1`` of a
    request holding ``page_ids`` (prefill scatter targets)."""
    pos = np.arange(n_tokens)
    return (page_ids[pos // page_size].astype(np.int64) * page_size
            + pos % page_size).astype(np.int32)


class PageAllocator:
    """Host-side page free list (pages ``N_RESERVED..n_pages-1``).

    Tracks ``peak_in_use`` so the bench/tests can demonstrate that slot
    cache memory scales with the lengths actually in flight rather than
    ``max_batch × seq_len``."""

    def __init__(self, n_pages: int) -> None:
        if n_pages <= N_RESERVED:
            raise ValueError(
                f"need more than {N_RESERVED} pages (zero + trash are "
                f"reserved), got n_pages={n_pages}")
        self.n_pages = n_pages
        self._free = deque(range(N_RESERVED, n_pages))
        self.in_use = 0
        self.peak_in_use = 0

    @property
    def capacity(self) -> int:
        return self.n_pages - N_RESERVED

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> np.ndarray:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        ids = np.array([self._free.popleft() for _ in range(n)], np.int32)
        self.in_use += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def free_(self, ids: Iterable[int]) -> None:
        ids = list(int(i) for i in ids)
        for i in ids:
            if not N_RESERVED <= i < self.n_pages:
                raise ValueError(f"freeing invalid page id {i}")
        self._free.extend(ids)
        self.in_use -= len(ids)

    # ------------------------------------------------ durability hooks ----
    def snapshot(self) -> dict:
        """JSON-able state: free-list ORDER included, so a restored
        allocator hands out the same page ids in the same order — the
        resumed serve plane's allocations replay exactly."""
        return {"n_pages": self.n_pages, "free": [int(i) for i in self._free],
                "in_use": self.in_use, "peak_in_use": self.peak_in_use}

    @classmethod
    def restore(cls, snap: dict) -> "PageAllocator":
        alloc = cls(int(snap["n_pages"]))
        alloc._free = deque(int(i) for i in snap["free"])
        alloc.in_use = int(snap["in_use"])
        alloc.peak_in_use = int(snap["peak_in_use"])
        return alloc
