"""The wire as a first-class object.

Every byte that crosses the party boundary — embeddings up, scalar losses
(or, for the leaky FOO baselines, partial derivatives) down — is owned by
a :class:`Transport`: it resolves the protocol's canonical method name
once (``repro.core.methods``), builds the q-aware :class:`privacy.Ledger`
for a run, and exposes the ONE mutation point the protocol allows on the
downlink: a pluggable noise hook on the scalar-loss channel
(:class:`repro.core.privacy.GaussianLossChannel`, DPZV-style).

``Transport`` is a frozen value object: the async engine hashes it into
its compiled-runner cache key, and :meth:`downlink` is pure (identity when
no channel is configured — the trace is bitwise identical to the
pre-Transport engine), so it can sit inside the jitted scan body.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax

from repro.analysis import marks, tags
from repro.core.methods import (SYNC_METHODS, ZOO_WIRE_METHODS,
                                canonical_method)
from repro.core.privacy import (GaussianLossChannel, Ledger, Message,
                                serve_messages)

# fold_in salt deriving the downlink-noise key from a round/row key (2 is
# taken by the engine's per-row direction RNG; keep them disjoint)
NOISE_SALT = 7


@dataclasses.dataclass(frozen=True)
class Transport:
    """Wire protocol of one federation: canonical method + noise hook."""
    method: str = "cascaded"
    noise: Optional[GaussianLossChannel] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "method", canonical_method(self.method))
        if self.noise is not None:
            if self.method not in ZOO_WIRE_METHODS:
                raise ValueError(
                    f"the DP loss channel applies to the scalar-loss "
                    f"downlink of ZOO-wire methods; {self.method!r} sends "
                    "partial derivatives down (nothing to clip+noise)")
            if self.method in SYNC_METHODS:
                raise ValueError(
                    f"the sync simulation of {self.method!r} shares one "
                    "global ZOO draw across parties — per-client downlink "
                    "noise is only meaningful for the asynchronous methods")

    # ------------------------------------------------------- wire shape --
    @property
    def sync(self) -> bool:
        return self.method in SYNC_METHODS

    @property
    def zoo_wire(self) -> bool:
        return self.method in ZOO_WIRE_METHODS

    # ---------------------------------------------------------- downlink --
    @tags.wire("down", accounted_by="Transport.account", kind="loss",
               reason="the one legal downlink: scalar losses, DP-noised "
                      "when a channel is configured")
    def downlink(self, losses: jax.Array, key: jax.Array) -> jax.Array:
        """The scalar-loss downlink hook (server -> client).

        Identity numerics when no noise channel is configured (the
        compiled HLO is op-identical to a bare wire); otherwise clips +
        noises every scalar crossing down. Call with the round/row key —
        the noise stream is derived via a dedicated fold_in salt so
        direction draws are unchanged.

        Every return path factors through ``marks.wire_boundary`` (and,
        under a channel, ``marks.dp_noise``): runtime no-op identity
        primitives that anchor this — the ONE legal loss downlink — in
        the traced jaxpr so ``repro.analysis.ifc`` can certify the
        scalar bottleneck (IF302) and noise-before-wire (IF303) without
        string-matching on primitives."""
        if self.noise is None:
            return marks.wire_boundary(losses, kind="loss",
                                       direction="down")
        noised = marks.dp_noise(
            self.noise.apply(losses, jax.random.fold_in(key, NOISE_SALT)))
        return marks.wire_boundary(noised, kind="loss", direction="down")

    # --------------------------------------------------------- accounting --
    @tags.accounting
    def account(self, *, batch: int, embed: int, zoo_queries: int = 1,
                n_clients: int = 1, n_rounds: int = 1,
                ledger: Optional[Ledger] = None) -> Ledger:
        """Build (or extend) the run's wire ledger — the Transport owns
        accounting. Passing the ledger restored from a checkpoint makes a
        resumed run's totals continue exactly where the saved run left
        off."""
        ledger = Ledger() if ledger is None else ledger
        ledger.log_round(self.method, batch, embed,
                         zoo_queries=zoo_queries if self.zoo_wire else 1,
                         n_clients=n_clients, n_rounds=n_rounds)
        return ledger

    @tags.accounting
    def account_serve(self, *, batch: int, embed: int, n_steps: int = 1,
                      n_gen: Optional[int] = None,
                      ledger: Optional[Ledger] = None) -> Ledger:
        """Log ``n_steps`` split-inference steps: per step the owning
        client uploads one (batch, d_model) embedding, and on the
        ``n_gen`` generation steps (all of them if not given) the server
        returns the sampled token ids — prefill steps carry no downlink
        (the clients already own the prompt). Serve traffic lands in the
        same ledger as training, so a session's lifetime wire is one
        total."""
        n_gen = n_steps if n_gen is None else n_gen
        if not 0 <= n_gen <= n_steps:
            raise ValueError(f"n_gen={n_gen} outside [0, n_steps={n_steps}]")
        ledger = Ledger() if ledger is None else ledger
        ledger.messages.extend(
            serve_messages(batch, embed, with_token=False)
            * (n_steps - n_gen))
        ledger.messages.extend(serve_messages(batch, embed) * n_gen)
        return ledger

    @tags.accounting
    def account_serve_step(self, *, batch: int, embed: int,
                           gen: bool = True,
                           ledger: Optional[Ledger] = None) -> Ledger:
        """One split-inference step for one request: the continuous
        scheduler's metering grain. Logging per ACTIVE slot per step keeps
        every request's ledger exact under slot churn — a request's total
        is identical to what a solo :func:`serving.run_decode` of the same
        request would log."""
        return self.account_serve(batch=batch, embed=embed, n_steps=1,
                                  n_gen=1 if gen else 0, ledger=ledger)

    @tags.accounting
    def account_wire(self, message: Message, *, copies: int = 1,
                     ledger: Optional[Ledger] = None) -> Ledger:
        """Meter one MEASURED wire frame from a ``repro.wire`` backend.

        ``message.wired`` carries the actual serialized byte count (frame
        header + length prefix included), while ``message.nbytes`` stays
        the per-round formula — so the ledger's ``serialized_bytes`` is a
        measurement and ``total_bytes`` survives as its cross-check.
        ``copies > 1`` logs retransmissions of the same frame (a
        ``FaultPlan`` retry resends identical bytes, so dropped attempts
        cost wire bytes without changing the payload accounting shape)."""
        if message.wired is None:
            raise ValueError(
                "account_wire meters measured frames; build the Message "
                "with wired=<serialized byte count> (use account()/"
                "log_round for formula-only accounting)")
        ledger = Ledger() if ledger is None else ledger
        ledger.messages.extend([message] * copies)
        return ledger

    def releases(self, *, n_rounds: int, n_clients: int = 1,
                 zoo_queries: int = 1) -> int:
        """Gaussian-mechanism releases in a run: each activated client
        receives (1 clean + q perturbed) noised scalars per round. The
        single source of truth for the accountant's composition count."""
        if not self.zoo_wire:
            return 0
        return n_rounds * n_clients * (1 + zoo_queries)

    def privacy_spent(self, n_releases: int) -> Tuple[float, float]:
        """Total (ε, δ) after ``n_releases`` noised downlink scalars.

        (inf, 0) without a channel: the wire is structurally safe (§V)
        but carries no formal DP guarantee."""
        if self.noise is None:
            return math.inf, 0.0
        return self.noise.spent(n_releases)
