"""One federation API: sessions over models, transports over wires.

Every entry point — ``launch/train.py``, the examples, the benchmarks,
and the back-compat ``async_engine.run`` shim — constructs training the
same way now:

    from repro.federation import Federation, Transport
    fed = Federation.build(model_cfg, vfl_cfg, engine_cfg)
    result = fed.run(params, x_parts, y)        # async protocol (staleness)
    step   = fed.sync_step(optimizer)           # jitted cascade step

``model_cfg`` is ANY of: a ready ``ModelAdapter``, the paper's
``PaperMLPConfig``, or a registered LM-scale ``ModelConfig`` (the
``adapters.from_model_config`` bridge derives the embedding-client /
backbone-server split automatically). The wire is a first-class
:class:`Transport` owning the privacy ledger, canonical method names and
the DP noise hook on the scalar-loss downlink
(``repro.core.privacy.GaussianLossChannel``).

Migration table (old call → session call)
-----------------------------------------

===============================================  =============================================================
old                                              new
===============================================  =============================================================
``async_engine.run(ec, vfl, p, X, y)``           ``Federation.build(adapter_or_cfg, vfl, ec).run(p, X, y)``
``async_engine.run(..., adapter=ad)``            ``Federation.build(ad, vfl, ec).run(...)``
``async_engine.run(..., mesh=make_client_mesh(D))``  ``Federation.build(..., EngineConfig(mesh_shards=D)).run(...)``
``make_step_for_method(m, model.loss_fn, ...)``  ``Federation.build(model_cfg, vfl, EngineConfig(method=m), seq_len=S).sync_step(opt)``
``Ledger(); ledger.log_round(m, ...)``           ``fed.transport.account(batch=..., embed=..., ...)``
(no DP story)                                    ``Federation.build(..., noise=GaussianLossChannel(clip, ε, δ))``
===============================================  =============================================================

The old spellings keep working: ``async_engine.run`` is a thin wrapper
over a session, bitwise-identical at noise=0.
"""
from repro.core.privacy import GaussianLossChannel
from repro.federation.session import Federation
from repro.federation.transport import Transport

__all__ = ["Federation", "GaussianLossChannel", "Transport"]
