"""One federation API: party-scoped sessions over models, transports
over wires.

Every entry point — ``launch/train.py``, ``launch/serve.py``,
``launch/dryrun.py``, the examples, the benchmarks, and the back-compat
``async_engine.run`` shim — drives the whole lifecycle through the same
session object now:

    from repro.federation import Federation, Transport
    fed = Federation.build(model_cfg, vfl_cfg, engine_cfg)
    result = fed.run(params, x_parts, y)        # async protocol (staleness)
    step   = fed.sync_step(optimizer)           # jitted cascade step
    fed.parties                                 # ServerParty/ClientParty handles
    fed.save(path, params, step=k, ...)         # one checkpoint dir per party
    fed, params, state = Federation.restore(path)   # mid-training resume
    res = fed.decode(params, prompts, gen_len=16)   # split inference

``model_cfg`` is ANY of: a ready ``ModelAdapter``, the paper's
``PaperMLPConfig``, or a registered LM-scale ``ModelConfig`` (the
``adapters.from_model_config`` bridge derives the embedding-client /
backbone-server split automatically). The wire is a first-class
:class:`Transport` owning the privacy ledger, canonical method names and
the DP noise hook on the scalar-loss downlink
(``repro.core.privacy.GaussianLossChannel``).

Migration table (old call → session call)
-----------------------------------------

===============================================  =============================================================
old                                              new
===============================================  =============================================================
``async_engine.run(ec, vfl, p, X, y)``           ``Federation.build(adapter_or_cfg, vfl, ec).run(p, X, y)``
``async_engine.run(..., adapter=ad)``            ``Federation.build(ad, vfl, ec).run(...)``
``async_engine.run(..., mesh=make_client_mesh(D))``  ``Federation.build(..., EngineConfig(mesh_shards=D)).run(...)``
``make_step_for_method(m, model.loss_fn, ...)``  ``Federation.build(model_cfg, vfl, EngineConfig(method=m), seq_len=S).sync_step(opt)``
``Ledger(); ledger.log_round(m, ...)``           ``fed.transport.account(batch=..., embed=..., ...)``
(no DP story)                                    ``Federation.build(..., noise=GaussianLossChannel(clip, ε, δ))``
``save_checkpoint(path, params)``                ``fed.save(path, params, step=..., opt_state=..., ledger=..., dp_releases=...)``
``load_checkpoint(path, like)``                  ``Federation.restore(path)`` (rebuilds session + params + state)
``launch/serve.py`` global decode                ``fed.decode(params, prompts, gen_len=...)`` (split, wire in ledger)
===============================================  =============================================================

The old spellings keep working: ``async_engine.run`` is a thin wrapper
over a session, bitwise-identical at noise=0.
"""
from repro.core.privacy import GaussianLossChannel
from repro.federation.parties import ClientParty, Parties, ServerParty
from repro.federation.scheduler import (QueueFull, RequestResult,
                                        ServeRequest, ServeScheduler)
from repro.federation.serving import ServeResult
from repro.federation.session import Federation, SessionState
from repro.federation.transport import Transport

__all__ = ["ClientParty", "Federation", "GaussianLossChannel", "Parties",
           "QueueFull", "RequestResult", "ServeRequest", "ServeResult",
           "ServeScheduler", "ServerParty", "SessionState", "Transport"]
