"""The serve plane: split inference with the training party split.

Training never merges the parties — and neither does serving. Per decoded
position the OWNING client party (position ``t`` belongs to client
``t // span``, the same span split the training adapter uses) embeds the
current token on its own parameters and uploads one ``(batch, d_model)``
embedding; the server holds the backbone, head and every KV/SSM cache,
and returns only sampled token ids. Logits, caches and activations never
cross the wire, and every step's uplink/downlink lands in the session's
:class:`repro.core.privacy.Ledger` through the ``Transport`` — serve-time
traffic is accounted exactly like training rounds.

The loop below mirrors ``launch/serve.py``'s prefill-as-decode schedule
op for op (same sampling keys, same clamp), so split decode is
bitwise-identical to global decode on replicated client tables — the
serve-plane analogue of ``global_loss == model.loss_fn`` on the training
plane.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import ModelAdapter
from repro.core.privacy import Ledger


@dataclasses.dataclass
class ServeResult:
    """One ``Federation.decode`` call: generated tokens + wire totals."""
    tokens: np.ndarray              # (B, gen_len) sampled token ids
    logits: jnp.ndarray             # final-step logits (B, 1, vocab) —
                                    # server-side state, exposed for tests
    ledger: Ledger
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def wire_bytes(self) -> int:
        return self.ledger.total_bytes

    @property
    def transmits_gradients(self) -> bool:
        return self.ledger.transmits_gradients


@functools.lru_cache(maxsize=32)
def make_serve_step(adapter: ModelAdapter, n_clients: int, seq_len: int):
    """Jitted one-token split-inference step.

    ``step(params, tok, caches, t)``: the client owning position ``t``
    embeds ``tok`` (one dynamic gather into the stacked client params —
    the other parties' tables are never read), the server decodes against
    its caches. Compiled once; ``t`` is a traced scalar. lru-cached on
    (adapter, split) like the engine's ``_make_runner``, so a serving
    loop calling ``fed.decode`` per request reuses the compiled step
    instead of retracing the backbone every call (adapters are frozen
    value objects and the adapter factories are themselves cached, so
    equal configs hit)."""
    if adapter.client_embed is None or adapter.server_decode is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no serve plane (client_embed/"
            "server_decode hooks); build the session from a ModelConfig "
            "to serve split inference")
    span = seq_len // n_clients

    def step(params, tok, caches, t):
        m = t // span
        client_m = jax.tree.map(lambda a: a[m], params["clients"])
        e = adapter.client_embed(client_m, tok)
        logits, caches = adapter.server_decode(params["server"], e, caches,
                                               t)
        return logits, caches

    return jax.jit(step, donate_argnums=(2,))


def run_decode(adapter: ModelAdapter, transport, *, n_clients: int,
               seq_len: int, embed_dim: int, vocab_size: int, params,
               prompts, gen_len: int, temperature: float = 0.0,
               key=None, ledger: Optional[Ledger] = None) -> ServeResult:
    """Prefill + decode through the split serve step (the
    ``Federation.decode`` engine)."""
    B, prompt_len = prompts.shape
    max_seq = prompt_len + gen_len
    if max_seq > seq_len:
        raise ValueError(
            f"prompt_len + gen_len = {max_seq} exceeds the session "
            f"seq_len {seq_len} (the party span split is sized to it)")
    if key is None:
        key = jax.random.key(0)
    step = make_serve_step(adapter, n_clients, seq_len)
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        adapter.cache_specs(B, max_seq),
        is_leaf=lambda x: hasattr(x, "logical"))

    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, caches = step(params, prompts[:, t:t + 1], caches, t)
    prefill_s = time.time() - t0

    out_tokens = []
    t0 = time.time()
    for t in range(prompt_len, max_seq):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(
                jax.random.fold_in(key, 100 + t), lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = jnp.minimum(nxt, vocab_size - 1).astype(jnp.int32)
        out_tokens.append(np.asarray(nxt))
        logits, caches = step(params, nxt[:, None], caches, t)
    decode_s = time.time() - t0

    # every decode call uploads one embedding; only the gen_len sampled
    # tokens cross back down (the clients already hold the prompt)
    ledger = transport.account_serve(batch=B, embed=embed_dim,
                                     n_steps=max_seq, n_gen=gen_len,
                                     ledger=ledger)
    return ServeResult(tokens=np.stack(out_tokens, axis=1), logits=logits,
                       ledger=ledger, prefill_s=prefill_s,
                       decode_s=decode_s)
