"""The serve plane: split inference with the training party split.

Training never merges the parties — and neither does serving. The OWNING
client party (position ``t`` belongs to client ``t // span``, the same
span split the training adapter uses) embeds tokens on its own
parameters and uploads embeddings; the server holds the backbone, head
and every KV/SSM cache, and returns only sampled token ids. Logits,
caches and activations never cross the wire, and every step's
uplink/downlink lands in the session's :class:`repro.core.privacy.Ledger`
through the ``Transport`` — serve-time traffic is accounted exactly like
training rounds.

Throughput comes from three compiled layers (the per-token,
Python-dispatched loop of the first serve plane survives only as the
fallback/oracle):

* **scan decode** — the whole generation is ONE ``jax.lax.scan``: tokens
  are sampled on device inside the scan body (``fold_in`` keys per step,
  same stream as the eager loop), accumulated on device, and transferred
  to the host once at the end. Bitwise-equal to the per-token loop —
  which stays bitwise-equal to global decode.
* **chunked prefill** — each owning client embeds its WHOLE span of the
  prompt in one ``client_embed`` call and the server consumes the
  ``(B, chunk, d_model)`` upload through the adapter's ``server_prefill``
  hook (one compiled pass per span instead of one dispatch per token).
  Adapters without the hook fall back to the step loop.
* **AOT compile separation** — every program is lowered + compiled
  explicitly (memoized in ``_AOT_CACHE``), so ``prefill_s``/``decode_s``
  time pure execution and ``compile_s`` reports compilation honestly
  (the bench warm-up keys off this).

Continuous batching over these pieces lives in
:mod:`repro.federation.scheduler`.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import weakref
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import marks, tags
from repro.core.adapters import ModelAdapter
from repro.core.privacy import Ledger


@dataclasses.dataclass
class ServeResult:
    """One ``Federation.decode`` call: generated tokens + wire totals."""
    tokens: np.ndarray              # (B, gen_len) sampled token ids
    logits: jnp.ndarray             # final-step logits (B, 1, vocab) —
                                    # server-side state, exposed for tests
    ledger: Ledger
    prefill_s: float = 0.0          # pure execution (outputs blocked on)
    decode_s: float = 0.0           # pure execution (outputs blocked on)
    compile_s: float = 0.0          # AOT compilation, reported separately

    @property
    def wire_bytes(self) -> int:
        return self.ledger.total_bytes

    @property
    def transmits_gradients(self) -> bool:
        return self.ledger.transmits_gradients


# ============================================== compiled-program cache =====

# AOT executables memoized on (jitted fn, argument signature): timing must
# report compile separately from run, and jit's internal cache would fold
# the first compile into the first timed call. Keyed on abstract shapes so
# a serving loop (or the continuous scheduler) reuses executables across
# requests exactly like the old lru-cached jit did. LRU-bounded: a
# long-lived server cycling through many (prompt_len, gen_len) signatures
# must not accumulate executables forever.
_AOT_CACHE: Dict = {}
_AOT_CACHE_MAX = 256

# Signature memo for big containers (param trees): the old _sig flattened
# the FULL params pytree on every lookup — hundreds of leaves walked per
# serve step just to discover the same signature again. A container's
# signature is now memoized on its id(), guarded by (type, len) and a
# weakref to its first leaf: identity of the container plus identity of
# its first leaf pins the same live tree (a dead tree whose id got reused
# fails the anchor check, because its leaves died with it). Trees are
# treated as immutable once built — true for params/caches here, which
# are only ever REPLACED (donation returns fresh containers), never
# mutated in place.
_TREE_SIG_MEMO: Dict[int, Tuple] = {}
_TREE_SIG_MEMO_MAX = 512
_SIG_STATS = {"flattens": 0, "memo_hits": 0}


def _leaf_sig(leaf):
    if isinstance(leaf, (bool, int, float)):
        return ("py", type(leaf).__name__)
    return ("leaf", tuple(leaf.shape), str(leaf.dtype))


def _first_leaf(obj):
    for _ in range(64):
        if isinstance(obj, dict):
            if not obj:
                return None
            obj = obj[next(iter(obj))]
        elif isinstance(obj, (list, tuple)):
            if not obj:
                return None
            obj = obj[0]
        else:
            return obj
    return obj


def _container_sig(obj) -> Tuple:
    oid = id(obj)
    anchor = _first_leaf(obj)
    memo = _TREE_SIG_MEMO.get(oid)
    if memo is not None:
        ref, guard, sig = memo
        if guard == (type(obj), len(obj)) and (
                ref() is anchor if ref is not None else anchor is None):
            _SIG_STATS["memo_hits"] += 1
            return sig
    _SIG_STATS["flattens"] += 1
    leaves, treedef = jax.tree.flatten(obj)
    sig = (treedef, tuple(_leaf_sig(x) for x in leaves))
    try:
        ref = weakref.ref(anchor) if anchor is not None else None
    except TypeError:
        ref = None
    while len(_TREE_SIG_MEMO) >= _TREE_SIG_MEMO_MAX:
        del _TREE_SIG_MEMO[next(iter(_TREE_SIG_MEMO))]
    _TREE_SIG_MEMO[oid] = (ref, (type(obj), len(obj)), sig)
    return sig


def _sig(args) -> Tuple:
    out = []
    for a in args:
        if isinstance(a, (bool, int, float)):
            out.append(("py", type(a).__name__))
        elif isinstance(a, (dict, list, tuple)):
            out.append(("tree", _container_sig(a)))
        elif hasattr(a, "shape") and hasattr(a, "dtype"):
            out.append(_leaf_sig(a))
        else:
            _SIG_STATS["flattens"] += 1
            leaves, treedef = jax.tree.flatten(a)
            out.append(("tree", (treedef,
                                 tuple(_leaf_sig(x) for x in leaves))))
    return tuple(out)


def compiled_with_timing(jitted, *args):
    """(compiled_executable, compile_seconds) — 0.0 on a cache hit."""
    key = (jitted, _sig(args))
    hit = _AOT_CACHE.pop(key, None)
    if hit is not None:
        _AOT_CACHE[key] = hit          # refresh recency: dict order is the
        return hit, 0.0                # LRU list, eviction takes the front
    t0 = time.perf_counter()
    compiled = jitted.lower(*args).compile()
    dt = time.perf_counter() - t0
    while len(_AOT_CACHE) >= _AOT_CACHE_MAX:
        del _AOT_CACHE[next(iter(_AOT_CACHE))]
    _AOT_CACHE[key] = compiled
    return compiled, dt


def _require_serve_plane(adapter: ModelAdapter):
    if adapter.client_embed is None or adapter.server_decode is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no serve plane (client_embed/"
            "server_decode hooks); build the session from a ModelConfig "
            "to serve split inference")


# ===================================================== compiled steps ======

@functools.lru_cache(maxsize=32)
def make_serve_step(adapter: ModelAdapter, n_clients: int, seq_len: int):
    """Jitted one-token split-inference step.

    ``step(params, tok, caches, t)``: the client owning position ``t``
    embeds ``tok`` (one dynamic gather into the stacked client params —
    the other parties' tables are never read), the server decodes against
    its caches. Compiled once; ``t`` is a traced scalar. lru-cached on
    (adapter, split) like the engine's ``_make_runner``, so a serving
    loop calling ``fed.decode`` per request reuses the compiled step
    instead of retracing the backbone every call (adapters are frozen
    value objects and the adapter factories are themselves cached, so
    equal configs hit)."""
    _require_serve_plane(adapter)
    span = seq_len // n_clients

    @tags.wire("up", accounted_by="Transport.account_serve", kind="embedding",
               reason="split-inference uplink: the owning client's one-token "
                      "embedding; logits and caches stay server-side")
    def step(params, tok, caches, t):
        m = t // span
        client_m = jax.tree.map(lambda a: a[m], params["clients"])
        e = marks.wire_boundary(adapter.client_embed(client_m, tok),
                                kind="emb", direction="up")
        logits, caches = adapter.server_decode(params["server"], e, caches,
                                               t)
        return logits, caches

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=32)
def make_prefill_chunk(adapter: ModelAdapter, n_clients: int, seq_len: int):
    """Jitted chunked-prefill step: client ``m`` embeds its whole
    ``(B, chunk)`` span slice in ONE call and the server consumes the
    ``(B, chunk, d_model)`` upload through ``server_prefill``. Returns
    only the last position's logits (the decode seed); one compile per
    distinct chunk length."""
    _require_serve_plane(adapter)
    if adapter.server_prefill is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no server_prefill hook; use the "
            "per-token step loop")

    @tags.wire("up", accounted_by="Transport.account_serve", kind="embedding",
               reason="chunked-prefill uplink: one whole span embedding per "
                      "chunk; prefill carries no downlink")
    def chunk(params, toks, caches, t0, m):
        client_m = jax.tree.map(lambda a: a[m], params["clients"])
        e = marks.wire_boundary(adapter.client_embed(client_m, toks),
                                kind="emb", direction="up")
        logits, caches = adapter.server_prefill(params["server"], e, caches,
                                                t0)
        return logits[:, -1:], caches

    return jax.jit(chunk, donate_argnums=(2,))


@functools.lru_cache(maxsize=64)
def make_decode_scan(adapter: ModelAdapter, n_clients: int, seq_len: int,
                     prompt_len: int, gen_len: int, temperature: float,
                     vocab_size: int):
    """The whole generation as ONE compiled ``lax.scan``.

    Per step the body samples on device from the carried logits (same
    ``fold_in(key, 100 + t)`` stream and clamp as the eager loop — the
    paths are bitwise-interchangeable), hands the token to the owning
    client, and steps the server. Sampled tokens are scan outputs, so the
    host sees ONE (B, gen_len) transfer at the end instead of gen_len
    per-token syncs."""
    _require_serve_plane(adapter)
    span = seq_len // n_clients

    def run(params, logits0, caches, key):
        @tags.wire("up", accounted_by="Transport.account_serve",
                   kind="embedding",
                   reason="scan-compiled decode: per-step one-token uplink, "
                          "token ids come back as scan outputs")
        def body(carry, t):
            logits, caches = carry
            # the serve plane's only downlink: one sampled token id per
            # step to the owning client (never the logits)
            nxt = marks.wire_boundary(
                sample_token(logits, key, t, temperature, vocab_size),
                kind="token", direction="down")
            m = t // span
            client_m = jax.tree.map(lambda a: a[m], params["clients"])
            e = marks.wire_boundary(adapter.client_embed(client_m,
                                                         nxt[:, None]),
                                    kind="emb", direction="up")
            logits, caches = adapter.server_decode(params["server"], e,
                                                   caches, t)
            return (logits, caches), nxt

        (logits, caches), toks = jax.lax.scan(
            body, (logits0, caches),
            jnp.arange(prompt_len, prompt_len + gen_len))
        return toks.T, logits, caches               # (gen_len, B) -> (B, T)

    return jax.jit(run, donate_argnums=(2,))


def prefill_plan(prompt_len: int, span: int) -> List[Tuple[int, int, int]]:
    """Span-aligned chunk schedule ``[(t0, t1, owner_m)]`` covering the
    prompt: each chunk lies inside exactly one client party's span, so
    one party embeds it in one call."""
    plan = []
    t0 = 0
    while t0 < prompt_len:
        m = t0 // span
        t1 = min((m + 1) * span, prompt_len)
        plan.append((t0, t1, m))
        t0 = t1
    return plan


def zero_caches(adapter: ModelAdapter, batch: int, max_seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        adapter.cache_specs(batch, max_seq),
        is_leaf=lambda x: hasattr(x, "logical"))


def sample_token(logits, key, t, temperature, vocab_size):
    """THE serve-plane sampler: greedy, or categorical on the
    ``fold_in(key, 100 + t)`` stream. Pure jnp, so the eager fallback
    loop, the decode-scan body and the continuous scheduler's slot step
    all call this one function — the bitwise solo == scan == continuous
    guarantee hangs on there being exactly one implementation.
    ``temperature`` must be a static Python float; ``t`` may be traced."""
    lg = logits[:, -1].astype(jnp.float32)
    if temperature > 0:
        nxt = jax.random.categorical(
            jax.random.fold_in(key, 100 + t), lg / temperature, axis=-1)
    else:
        nxt = jnp.argmax(lg, axis=-1)
    return jnp.minimum(nxt, vocab_size - 1).astype(jnp.int32)


# ============================================================ run_decode ===

def run_decode(adapter: ModelAdapter, transport, *, n_clients: int,
               seq_len: int, embed_dim: int, vocab_size: int, params,
               prompts, gen_len: int, temperature: float = 0.0,
               key=None, ledger: Optional[Ledger] = None,
               use_scan: bool = True,
               chunked_prefill: bool = True) -> ServeResult:
    """Prefill + decode through the split serve plane (the
    ``Federation.decode`` engine).

    ``use_scan=False`` / ``chunked_prefill=False`` select the per-token
    step loop (the equivalence oracle; the fallback loop still keeps
    sampled tokens on device and transfers once at the end)."""
    prompts = jnp.asarray(prompts, jnp.int32)
    B, prompt_len = prompts.shape
    max_seq = prompt_len + gen_len
    if max_seq > seq_len:
        raise ValueError(
            f"prompt_len + gen_len = {max_seq} exceeds the session "
            f"seq_len {seq_len} (the party span split is sized to it)")
    if key is None:
        key = jax.random.key(0)
    span = seq_len // n_clients
    step = make_serve_step(adapter, n_clients, seq_len)
    caches = zero_caches(adapter, B, max_seq)
    compile_s = 0.0
    chunked = chunked_prefill and adapter.server_prefill is not None

    # ------------------------------------------------------- prefill ----
    if chunked:
        chunk_fn = make_prefill_chunk(adapter, n_clients, seq_len)
        plan = prefill_plan(prompt_len, span)
        progs = []
        for t0, t1, m in plan:
            prog, dt = compiled_with_timing(
                chunk_fn, params, prompts[:, t0:t1], caches, t0, m)
            compile_s += dt
            progs.append(prog)
        tic = time.perf_counter()
        logits = None
        for (t0, t1, m), prog in zip(plan, progs):
            logits, caches = prog(params, prompts[:, t0:t1], caches, t0, m)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - tic
    else:
        cstep, dt = compiled_with_timing(step, params, prompts[:, :1],
                                         caches, 0)
        compile_s += dt
        tic = time.perf_counter()
        logits = None
        for t in range(prompt_len):
            logits, caches = cstep(params, prompts[:, t:t + 1], caches, t)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - tic

    # -------------------------------------------------------- decode ----
    if use_scan:
        scan_fn = make_decode_scan(adapter, n_clients, seq_len, prompt_len,
                                   gen_len, float(temperature), vocab_size)
        prog, dt = compiled_with_timing(scan_fn, params, logits, caches, key)
        compile_s += dt
        tic = time.perf_counter()
        toks_dev, logits, caches = prog(params, logits, caches, key)
        out_tokens = np.asarray(jax.block_until_ready(toks_dev))
        decode_s = time.perf_counter() - tic
    else:
        cstep, dt = compiled_with_timing(step, params, prompts[:, :1],
                                         caches, prompt_len)
        compile_s += dt
        out = []
        tic = time.perf_counter()
        for t in range(prompt_len, max_seq):
            nxt = sample_token(logits, key, t, temperature, vocab_size)
            out.append(nxt)        # stays on device; one transfer at the end
            logits, caches = cstep(params, nxt[:, None], caches, t)
        out_tokens = np.asarray(
            jax.block_until_ready(jnp.stack(out, axis=1)))
        decode_s = time.perf_counter() - tic

    # every decode call uploads one embedding; only the gen_len sampled
    # tokens cross back down (the clients already hold the prompt)
    ledger = transport.account_serve(batch=B, embed=embed_dim,
                                     n_steps=max_seq, n_gen=gen_len,
                                     ledger=ledger)
    return ServeResult(tokens=out_tokens, logits=logits,
                       ledger=ledger, prefill_s=prefill_s,
                       decode_s=decode_s, compile_s=compile_s)
