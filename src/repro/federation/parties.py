"""Typed party handles: every plane addresses state through a party.

The paper's security argument is a statement about the PARTY boundary —
clients never expose internal state, the server never learns client
parameters. :class:`ServerParty` / :class:`ClientParty` make that boundary
an object: each handle knows which slice of a parameter tree it owns, in
both layouts the session trains in —

* the ENGINE layout ``{"clients": (M, ...), "server": ...}`` the async
  protocol runs on (client m owns row m of the stacked client pytree), and
* the GLOBAL layout of ``model_api.build_model`` the sync cascade trains
  (the client partition is the ``client_keys`` subtree — the replicated
  bottom layer every client party holds a copy of).

``Federation.save`` writes one checkpoint directory per party through
these handles, so the isolation property is structural: the server's
directory cannot contain a client leaf because the server handle cannot
even address one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Tuple, Union

import jax

from repro.analysis import tags
from repro.core.partition import merge_params, split_params


def is_engine_layout(params: Any) -> bool:
    """True for the async engine's {"clients", "server"} param layout."""
    return isinstance(params, dict) and set(params) == {"clients", "server"}


@dataclasses.dataclass(frozen=True)
class ServerParty:
    """The label/backbone owner: everything outside ``client_keys``."""
    client_keys: Tuple[str, ...]
    name: str = "server"

    @tags.party("server")
    def owned(self, params: Any) -> Any:
        """The server's slice of ``params`` (either layout)."""
        if is_engine_layout(params):
            return params["server"]
        _, server = split_params(params, self.client_keys)
        return server


@dataclasses.dataclass(frozen=True)
class ClientParty:
    """Feature-owner m: its stacked row (engine layout) or its copy of the
    replicated bottom layer (global layout — shared across parties)."""
    index: int
    client_keys: Tuple[str, ...]

    @property
    def name(self) -> str:
        return f"client_{self.index:02d}"

    @tags.party("client")
    def owned(self, params: Any) -> Any:
        if is_engine_layout(params):
            return jax.tree.map(lambda a: a[self.index], params["clients"])
        client, _ = split_params(params, self.client_keys)
        return client


@dataclasses.dataclass(frozen=True)
class Parties:
    """All handles of one federation: ``fed.parties.server`` plus
    ``fed.parties.clients[m]``; iterable server-first."""
    server: ServerParty
    clients: Tuple[ClientParty, ...]

    def __iter__(self) -> Iterator[Union[ServerParty, ClientParty]]:
        yield self.server
        yield from self.clients

    def __len__(self) -> int:
        return 1 + len(self.clients)

    def assemble(self, server_tree: Any, client_trees: Any) -> Any:
        """Inverse of the per-party split: stack the client slices back
        into the engine layout (the canonical party-scoped layout)."""
        import jax.numpy as jnp
        clients = jax.tree.map(lambda *rows: jnp.stack(rows), *client_trees)
        return {"clients": clients, "server": server_tree}

    def merge_global(self, server_tree: Any, client_tree: Any) -> Any:
        """Rebuild a GLOBAL-layout tree from its two party partitions."""
        return merge_params(client_tree, server_tree)
