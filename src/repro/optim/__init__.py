from repro.optim.optimizers import Optimizer, adamw, sgd
from repro.optim.schedule import constant, cosine, make_schedule, warmup_cosine

__all__ = ["Optimizer", "adamw", "sgd", "constant", "cosine",
           "make_schedule", "warmup_cosine"]
