"""Learning-rate schedules (fn(step) -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.float32(lr) * (final_frac + (1 - final_frac)
                                  * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        warm = jnp.float32(lr) * jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn


def inv_sqrt(lr: float, warmup: int = 100):
    """η = lr/√t — the paper's Corollary IV.10 choice (η = 1/√T)."""
    def fn(step):
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        return jnp.float32(lr) * jnp.minimum(t / warmup, jnp.sqrt(warmup / t))
    return fn


def make_schedule(name: str, lr: float, *, warmup: int = 0,
                  total_steps: int = 0):
    if name == "constant":
        return constant(lr)
    if name == "cosine":
        return warmup_cosine(lr, warmup, total_steps) if warmup else \
            cosine(lr, total_steps)
    if name == "inv_sqrt":
        return inv_sqrt(lr, max(warmup, 1))
    raise ValueError(f"unknown schedule {name!r}")
