"""Functional optimizers (no optax in the container — hand-rolled).

The paper applies *vanilla SGD* to every framework ("To make a fair
comparison, we applied the vanilla SGD strategy to all VFL frameworks"),
so production configs default to SGD; AdamW is provided for ablations and
small-scale runs. State and updates are pytree-structured and jit/pjit
friendly; params may be bf16 with fp32 optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable      # params -> state
    update: Callable    # (grads, state, params) -> (new_params, new_state)
    name: str = "sgd"


def _tree_zeros_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0,
        grad_clip: float = 0.0) -> Optimizer:
    """lr: float or schedule fn(step)->float."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = _tree_zeros_f32(params)
        return state

    def update(grads, state, params):
        step = state["step"]
        eta = lr_fn(step)
        grads = _clip(grads, grad_clip)
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32),
                grads, params)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            upd = mom
            new_state = {"step": step + 1, "mom": mom}
        else:
            upd = grads
            new_state = {"step": step + 1}
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - eta * u).astype(p.dtype),
            params, upd)
        return new_params, new_state

    return Optimizer(init=init, update=update, name="sgd")


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, grad_clip: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tree_zeros_f32(params),
                "v": _tree_zeros_f32(params)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr_fn(step)
        grads = _clip(grads, grad_clip)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init=init, update=update, name="adamw")


def _clip(grads, clip: float):
    if not clip:
        return grads
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)
