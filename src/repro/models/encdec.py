"""Encoder-decoder (Whisper) assembly.

The mel/conv frontend is a STUB per the assignment: inputs carry
precomputed frame embeddings (B, encoder_seq, frontend_dim); the client-side
projector maps them to d_model (this projector + the decoder token embedding
form the ZOO-updated client partition).

Serving: the encoder output is computed once at prefill and passed to every
decode step (``enc_out`` input), as a production server would cache it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ParamSpec, stack_layer_specs
from repro.models.layers import apply_norm, embed_lookup, norm_specs, unembed
from repro.models.mlp import mlp_apply, mlp_specs
from repro.models.transformer import _boundary, scan_apply, softmax_xent
from repro.sharding.rules import shard_constraint


def _enc_block_specs(cfg):
    return {"ln1": norm_specs(cfg, cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "ln2": norm_specs(cfg, cfg.d_model),
            "mlp": mlp_specs(cfg, cfg.d_model, cfg.d_ff)}


def _dec_block_specs(cfg):
    return {"ln1": norm_specs(cfg, cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "ln_x": norm_specs(cfg, cfg.d_model),
            "xattn": attn.attention_specs(cfg),
            "ln2": norm_specs(cfg, cfg.d_model),
            "mlp": mlp_specs(cfg, cfg.d_model, cfg.d_ff)}


def encdec_specs(cfg, max_seq: int):
    return {
        "proj": {"w": ParamSpec((cfg.frontend_dim, cfg.d_model),
                                cfg.param_dtype, ("frontend", "embed"), "scaled"),
                 "b": ParamSpec((cfg.d_model,), "float32", (None,), "zeros")},
        "embed": {"table": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                     cfg.param_dtype, ("vocab", "embed"))},
        "enc_pos": ParamSpec((cfg.encoder_seq, cfg.d_model), cfg.param_dtype,
                             (None, "embed")),
        "pos_embed": ParamSpec((max_seq, cfg.d_model), cfg.param_dtype,
                               ("vocab", "embed")),
        "enc_blocks": stack_layer_specs(_enc_block_specs(cfg),
                                        cfg.n_encoder_layers),
        "enc_final_norm": norm_specs(cfg, cfg.d_model),
        "blocks": stack_layer_specs(_dec_block_specs(cfg), cfg.n_layers),
        "final_norm": norm_specs(cfg, cfg.d_model),
        "lm_head": {"table": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                       cfg.param_dtype, ("vocab", "embed"),
                                       "scaled")},
    }


def encode(cfg, params, frames):
    """frames (B, Se, frontend_dim) -> enc_out (B, Se, d)."""
    x = (jnp.einsum("bsf,fd->bsd", frames.astype(jnp.bfloat16),
                    params["proj"]["w"])
         + params["proj"]["b"].astype(jnp.bfloat16))
    x = x + params["enc_pos"][None].astype(x.dtype)
    x = shard_constraint(x, ("batch", None, "embed_act"))

    def body(h, p_l):
        h = _boundary(cfg, h)
        a, _ = attn.attention_apply(cfg, p_l["attn"],
                                    apply_norm(cfg, p_l["ln1"], h),
                                    positions=jnp.arange(h.shape[1]),
                                    causal=False)
        h = h + a
        h = h + mlp_apply(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], h))
        return h, None
    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = scan_apply(cfg, body, x, params["enc_blocks"],
                      cfg.n_encoder_layers)
    return apply_norm(cfg, params["enc_final_norm"], x)


def decode_blocks(cfg, params, x, enc_out, *, positions, caches=None,
                  cur_pos=None, window=0):
    def body(h, xs):
        p_l, c_l = xs
        h = _boundary(cfg, h)
        a, new_c = attn.attention_apply(
            cfg, p_l["attn"], apply_norm(cfg, p_l["ln1"], h),
            positions=positions, cache=c_l, cur_pos=cur_pos, window=window)
        h = h + a
        xa, _ = attn.attention_apply(
            cfg, p_l["xattn"], apply_norm(cfg, p_l["ln_x"], h),
            positions=positions, kv_override=enc_out)
        h = h + xa
        h = h + mlp_apply(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], h))
        return h, new_c
    body = jax.checkpoint(body) if cfg.remat else body
    x, new_caches = scan_apply(cfg, body, x, (params["blocks"], caches),
                               cfg.n_layers)
    return x, new_caches


def forward(cfg, params, inputs, *, caches=None, cur_pos=None, window=0):
    """Train/prefill: inputs = {frames, tokens}. Decode: {tokens(B,1),
    enc_out} + caches."""
    tokens = inputs["tokens"]
    if caches is None:
        positions = jnp.arange(tokens.shape[1])
        enc_out = encode(cfg, params, inputs["frames"])
    else:
        positions = jnp.asarray(cur_pos)[None]
        enc_out = inputs["enc_out"]
    x = embed_lookup(params["embed"], tokens)
    pos_table = params["pos_embed"]
    x = x + jnp.take(pos_table,
                     jnp.clip(positions, 0, pos_table.shape[0] - 1),
                     axis=0).astype(x.dtype)
    x, new_caches = decode_blocks(cfg, params, x, enc_out,
                                  positions=positions, caches=caches,
                                  cur_pos=cur_pos, window=window)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["lm_head"], x)
    return logits, (new_caches if caches is not None else None), jnp.float32(0.0)


def seq2seq_loss(cfg, params, inputs, *, window=0):
    logits, _, _ = forward(cfg, params, inputs, window=window)
    ce = softmax_xent(logits[:, :-1], inputs["labels"][:, 1:], cfg.padded_vocab)
    return jnp.mean(ce), {}
