"""Primitive layers: norms, activations, RoPE, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


# ---------------------------------------------------------------- norms ----

def norm_specs(cfg, d: int):
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), "float32", (None,), "ones"),
                "bias": ParamSpec((d,), "float32", (None,), "zeros")}
    return {"scale": ParamSpec((d,), "float32", (None,), "ones")}


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def rms_norm_simple(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ----------------------------------------------------------- activations ---

def activation(name: str, x, gate=None):
    if name == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * x
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


# ------------------------------------------------------------------ RoPE ---

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float, has_heads: bool = True):
    """x: (..., S, H, hd) if has_heads else (..., S, hd); positions: (S,)
    (or (1,) for decode — broadcasts)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv   # (S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if has_heads:                                      # align with (S, H, hd)
        cos, sin = cos[..., :, None, :], sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- embedding ---

def embed_specs(cfg):
    return {"table": ParamSpec((cfg.padded_vocab, cfg.d_model),
                               cfg.param_dtype, ("vocab", "embed"), "normal")}


def embed_lookup(p, tokens, *, iota: bool = False):
    """Token embedding. iota=True uses the one-hot-matmul form: on a
    vocab-sharded table the plain gather triggers GSPMD's 'involuntary full
    rematerialization' (the table is replicated per device); the matmul
    form keeps the contraction shard-local (§Perf)."""
    if not iota:
        return jnp.take(p["table"], tokens, axis=0)
    table = p["table"]
    V = table.shape[0]
    onehot = jax.nn.one_hot(tokens, V, dtype=table.dtype)
    return jnp.einsum("...v,vd->...d", onehot, table)


def unembed(p, x):
    """x (..., d) -> logits (..., padded_vocab)."""
    return jnp.einsum("...d,vd->...v", x, p["table"])
