"""Dense MLP blocks (SwiGLU / GELU / squared-ReLU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import activation
from repro.sharding.rules import shard_constraint


def mlp_specs(cfg, d: int, d_ff: int):
    pd = cfg.param_dtype
    sp = {
        "w_up": ParamSpec((d, d_ff), pd, ("embed", "ffn"), "scaled"),
        "w_down": ParamSpec((d_ff, d), pd, ("ffn", "embed"), "scaled"),
    }
    if cfg.act == "swiglu":
        sp["w_gate"] = ParamSpec((d, d_ff), pd, ("embed", "ffn"), "scaled")
    return sp


def mlp_apply(cfg, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"]) if cfg.act == "swiglu" else None
    h = activation(cfg.act, h, gate)
    h = shard_constraint(h, ("batch", None, "ffn_act"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]).astype(x.dtype)
