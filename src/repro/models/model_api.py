"""Unified model API: every assigned architecture becomes a ``Model`` with

* ``param_specs``            — ParamSpec pytree (abstract; materialize for real runs)
* ``loss_fn(params, batch)`` — global-model training loss (FOO baselines use
                               it directly; the cascade partitions it)
* ``forward / serve_decode`` — inference entry points
* ``input_specs(shape)``     — ShapeDtypeStruct stand-ins for every input of
                               the requested (shape × mode), incl. caches
* ``client_keys``            — top-level param keys forming the ZOO client
                               partition (embedding + modality projector)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as attn_mod
from repro.models import encdec, rwkv as rwkv_mod, ssm as ssm_mod, transformer
from repro.models.common import ParamSpec, abstract

# window used by the sliding-window (long_500k) variants
LONG_WINDOW = 4096


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_specs: Any
    loss_fn: Callable            # (params, batch) -> (loss, aux)
    forward_fn: Callable         # (params, inputs) -> logits
    decode_fn: Callable          # (params, inputs, caches, cur_pos) -> (logits, caches)
    client_keys: Tuple[str, ...]

    def input_specs(self, shape: ShapeConfig, *, window: int = 0):
        return build_input_specs(self.cfg, shape)

    def cache_specs(self, shape: ShapeConfig):
        return build_cache_specs(self.cfg, shape.global_batch, shape.seq_len)


def _client_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    keys = ["embed"]
    if cfg.frontend_dim:
        keys.append("proj")
    return tuple(keys)


def build_model(cfg: ModelConfig, *, max_seq: int = 8192,
                window: int = 0, window_gather: bool = False,
                gather_experts: bool = False) -> Model:
    """window > 0 selects the sliding-window attention variant (used for
    long_500k on attention archs). window_gather / gather_experts are
    §Perf decode variants (see attention.decode_attend / moe.moe_apply)."""
    if cfg.is_encoder_decoder:
        specs = encdec.encdec_specs(cfg, max_seq)

        def loss_fn(params, batch):
            return encdec.seq2seq_loss(cfg, params, batch, window=window)

        def forward_fn(params, inputs):
            return encdec.forward(cfg, params, inputs, window=window)[0]

        def decode_fn(params, inputs, caches, cur_pos):
            logits, new_caches, _ = encdec.forward(
                cfg, params, inputs, caches=caches, cur_pos=cur_pos,
                window=window)
            return logits, new_caches
    else:
        specs = transformer.backbone_specs(cfg, max_seq)

        def loss_fn(params, batch):
            return transformer.lm_loss(cfg, params, batch, window=window)

        def forward_fn(params, inputs):
            return transformer.forward(cfg, params, inputs, window=window)[0]

        def decode_fn(params, inputs, caches, cur_pos):
            logits, new_caches, _ = transformer.forward(
                cfg, params, inputs, caches=caches, cur_pos=cur_pos,
                window=window, window_gather=window_gather,
                gather_experts=gather_experts)
            return logits, new_caches

    return Model(cfg=cfg, param_specs=specs, loss_fn=loss_fn,
                 forward_fn=forward_fn, decode_fn=decode_fn,
                 client_keys=_client_keys(cfg))


# ============================================================ input specs ==

def build_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, ParamSpec]:
    """ParamSpec dict for the *data* inputs of (cfg, shape).

    Decode shapes get tokens (B,1); caches come from build_cache_specs."""
    B, S = shape.global_batch, shape.seq_len
    sp: Dict[str, ParamSpec] = {}
    if shape.is_decode:
        sp["tokens"] = ParamSpec((B, 1), "int32", ("batch", None))
        if cfg.is_encoder_decoder:
            sp["enc_out"] = ParamSpec((B, cfg.encoder_seq, cfg.d_model),
                                      "bfloat16", ("batch", None, "embed_act"))
        return sp

    if cfg.family == "vlm":
        s_text = S - cfg.n_vision_tokens
        sp["tokens"] = ParamSpec((B, s_text), "int32", ("batch", None))
        sp["labels"] = ParamSpec((B, s_text), "int32", ("batch", None))
        sp["patch_embeds"] = ParamSpec((B, cfg.n_vision_tokens, cfg.frontend_dim),
                                       "bfloat16", ("batch", None, None))
    elif cfg.is_encoder_decoder:
        sp["tokens"] = ParamSpec((B, S), "int32", ("batch", None))
        sp["labels"] = ParamSpec((B, S), "int32", ("batch", None))
        sp["frames"] = ParamSpec((B, cfg.encoder_seq, cfg.frontend_dim),
                                 "bfloat16", ("batch", None, None))
    else:
        sp["tokens"] = ParamSpec((B, S), "int32", ("batch", None))
        sp["labels"] = ParamSpec((B, S), "int32", ("batch", None))
    if shape.kind == "prefill":
        sp.pop("labels", None)
    return sp


def build_cache_specs(cfg: ModelConfig, batch: int, seq: int):
    """Stacked per-layer decode state for the family (None for non-decode)."""
    if cfg.is_encoder_decoder:
        return attn_mod.cache_specs(cfg, batch, seq)
    if cfg.family == "ssm":
        return rwkv_mod.rwkv_state_specs(cfg, batch, cfg.d_model)
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        d_in = cfg.ssm_expand * cfg.d_model
        H = d_in // cfg.ssm_head_dim
        ssm_states = {
            "ssm": ParamSpec((n_super, cfg.attn_every, batch, H,
                              cfg.ssm_head_dim, cfg.ssm_state), "float32",
                             (None, "layers", "cache_batch", "cache_heads",
                              None, None)),
            "conv": ParamSpec((n_super, cfg.attn_every, batch,
                               ssm_mod.CONV_W - 1, d_in), "float32",
                              (None, "layers", "cache_batch", None, "ssm_inner")),
        }
        hd = cfg.resolved_head_dim
        attn_caches = {
            "k": ParamSpec((n_super, batch, seq, cfg.n_kv_heads, hd),
                           "bfloat16",
                           ("layers", "cache_batch", "cache_seq",
                            "cache_heads", None)),
            "v": ParamSpec((n_super, batch, seq, cfg.n_kv_heads, hd),
                           "bfloat16",
                           ("layers", "cache_batch", "cache_seq",
                            "cache_heads", None)),
        }
        return (ssm_states, attn_caches)
    if cfg.first_k_dense and cfg.n_experts:
        full = attn_mod.cache_specs(cfg, batch, seq)

        def split(sp: ParamSpec, n):
            return ParamSpec((n,) + sp.shape[1:], sp.dtype, sp.logical,
                             sp.init, sp.scale)
        dense = {k: split(v, cfg.first_k_dense) for k, v in full.items()}
        main = {k: split(v, cfg.n_layers - cfg.first_k_dense)
                for k, v in full.items()}
        return {"dense": dense, "main": main}
    return attn_mod.cache_specs(cfg, batch, seq)


def abstract_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for data inputs (+ caches & cur_pos for decode)."""
    data = abstract(build_input_specs(cfg, shape))
    if not shape.is_decode:
        return data, None, None
    caches = abstract(build_cache_specs(cfg, shape.global_batch, shape.seq_len))
    cur_pos = jax.ShapeDtypeStruct((), jnp.int32)
    return data, caches, cur_pos
