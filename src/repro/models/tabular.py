"""The paper's base experiment model (§VI-A-b): MLP over vertically
partitioned tabular features.

* M clients, each a single FC layer: c_m = relu(x_m @ W_m + b_m)
  (client params stacked along a leading M axis so the async engine can
  dynamically index the activated client inside ``lax.scan``).
* server: two FC layers over the concatenation [c_1 .. c_M].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import PaperMLPConfig
from repro.models.common import ParamSpec


def param_specs(cfg: PaperMLPConfig):
    f, e = cfg.features_per_client, cfg.client_embed
    M, se, C = cfg.n_clients, cfg.server_embed, cfg.n_classes
    return {
        "clients": {
            "w": ParamSpec((M, f, e), "float32",
                           ("clients", None, None), "scaled"),
            "b": ParamSpec((M, e), "float32", ("clients", None), "zeros"),
        },
        "server": {
            "w1": ParamSpec((M * e, se), "float32", (None, None), "scaled"),
            "b1": ParamSpec((se,), "float32", (None,), "zeros"),
            "w2": ParamSpec((se, C), "float32", (None, None), "scaled"),
            "b2": ParamSpec((C,), "float32", (None,), "zeros"),
        },
    }


CLIENT_KEYS = ("clients",)


def client_forward(client_m, x_m):
    """client_m: {w (f,e), b (e)}; x_m (B, f) -> (B, e)."""
    return jax.nn.relu(x_m @ client_m["w"] + client_m["b"])


def all_clients_forward(clients, x_parts):
    """clients stacked (M, ...), x_parts (M, B, f) -> (M, B, e)."""
    return jax.vmap(client_forward)(clients, x_parts)


def server_forward(server, c_all):
    """c_all (M, B, e) -> logits (B, C)."""
    M, B, e = c_all.shape
    h = c_all.transpose(1, 0, 2).reshape(B, M * e)
    h = jax.nn.relu(h @ server["w1"] + server["b1"])
    return h @ server["w2"] + server["b2"]


def xent(logits, labels):
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(lse - gold)


def global_loss(params, batch):
    """Synchronous global loss (Split-Learning view of the same model)."""
    x_parts, y = batch["x_parts"], batch["y"]
    c = all_clients_forward(params["clients"], x_parts)
    logits = server_forward(params["server"], c)
    return xent(logits, y), {"logits": logits}


def accuracy(params, x_parts, y):
    c = all_clients_forward(params["clients"], x_parts)
    logits = server_forward(params["server"], c)
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
