"""Parameter machinery: abstract param specs, init, sharding trees.

The framework is pure functional JAX (no flax): a model is described by a
pytree of :class:`ParamSpec` leaves. The same spec tree serves three uses:

* ``abstract(tree)``       -> ShapeDtypeStruct tree (dry-run, no allocation)
* ``materialize(tree, k)`` -> concrete arrays (smoke tests / real training)
* ``shardings(tree, mesh)``-> NamedSharding tree (pjit in_shardings)
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import PARAM_RULES, Rules, named_sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: str = "bfloat16"
    logical: Tuple[Optional[str], ...] = ()
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.logical) in (0, len(self.shape)), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def abstract(tree):
    """ShapeDtypeStruct pytree — inputs to jit.lower, zero allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        tree, is_leaf=is_spec)


def shardings(tree, mesh, rules: Rules = PARAM_RULES):
    def one(s: ParamSpec):
        logical = s.logical if s.logical else (None,) * len(s.shape)
        return named_sharding(mesh, s.shape, logical, rules)
    return jax.tree.map(one, tree, is_leaf=is_spec)


def materialize(tree, key, dtype_override: Optional[str] = None):
    """Concrete init. Each leaf gets a key derived from its path so init is
    order-independent, stable under refactors AND across processes (the
    path digest is crc32, not the per-process-salted builtin ``hash`` —
    a fresh run must draw the same parameters in every interpreter for
    kill/resume traces to be comparable to straight-through runs)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_spec)[0]
    treedef = jax.tree.structure(tree, is_leaf=is_spec)

    def init_one(path, s: ParamSpec):
        pstr = "/".join(str(p) for p in path)
        sub = jax.random.fold_in(
            key, np.uint32(zlib.crc32(pstr.encode()) & 0x7FFFFFFF))
        dt = jnp.dtype(dtype_override or s.dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        if s.init == "scaled":          # fan-in scaled
            fan_in = s.shape[0] if s.shape else 1
            return (jax.random.normal(sub, s.shape, jnp.float32)
                    * (1.0 / np.sqrt(max(fan_in, 1)))).astype(dt)
        return (jax.random.normal(sub, s.shape, jnp.float32) * s.scale).astype(dt)

    leaves = [init_one(p, s) for p, s in leaves_with_paths]
    return jax.tree.unflatten(treedef, leaves)


def param_count(tree) -> int:
    return int(sum(int(np.prod(s.shape)) for s in _leaves(tree)))


def param_bytes(tree) -> int:
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                   for s in _leaves(tree)))


# ---------------------------------------------------------------------------
# paged decode context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PageContext:
    """Batched paged-decode context threaded through ``backbone_apply``.

    Present only on the continuous scheduler's batched decode step:
    sequence-indexed cache leaves arrive as shared page pools
    ``(n_pages, page_size, *tail)`` per layer instead of slot-stacked
    ``(B, S, *tail)`` slices, and ``tables``/``active`` say where each
    slot's rows live and whether its write should land in the pool at
    all (inactive slots write to the reserved trash page). Constructed
    inside traced code — never crosses a jit boundary itself."""
    tables: jax.Array        # (B, pages_per_seq) int32 page ids
    active: jax.Array        # (B,) int32 — 0 routes writes to TRASH_PAGE
    page_size: int
    trash_page: int = 1

    def gather_rows(self) -> jax.Array:
        """(B, pages_per_seq * page_size) flat pool-row ids covering each
        slot's full (masked) sequence extent."""
        B, npt = self.tables.shape
        rows = (self.tables[:, :, None] * self.page_size
                + jnp.arange(self.page_size)[None, None, :])
        return rows.reshape(B, npt * self.page_size)

    def write_rows(self, cur_pos: jax.Array):
        """Per-slot (dest_page, in_page) for the token at ``cur_pos``
        (B,); inactive slots are routed to the trash page."""
        B = self.tables.shape[0]
        page_of = cur_pos // self.page_size
        dest = self.tables[jnp.arange(B), page_of]
        dest = jnp.where(self.active > 0, dest, self.trash_page)
        return dest, cur_pos % self.page_size


def freeze_state(active, new, old):
    """``where(active, new, old)`` with (B,)-active broadcast to any rank:
    inactive slots' recurrent state stays EXACTLY frozen under the
    batched decode step (their inputs are zeroed, but decay would still
    drift the state — freezing keeps retired slots inert and finite)."""
    a = active.reshape(active.shape + (1,) * (new.ndim - 1))
    # anchor to the carried state's dtype: if ``new`` came out of an f32
    # accumulation while the carry is bf16, a bare where() would promote
    # the carry and destabilize the scan signature (TH203)
    return jnp.where(a > 0, new.astype(old.dtype), old)


# ---------------------------------------------------------------------------
# small helpers shared by the model files
# ---------------------------------------------------------------------------

def dense_spec(d_in: int, d_out: int, logical=("embed", "ffn"), dtype="bfloat16",
               init="scaled") -> ParamSpec:
    return ParamSpec((d_in, d_out), dtype, logical, init)


def stack_layer_specs(layer_tree, n_layers: int, axis_name: str = "layers"):
    """Prepend a stacked leading dim to every leaf of a single-layer tree.

    ``axis_name`` is the logical name of the new axis: "layers" for the
    scanned transformer stack, "clients" for the VFL party plane (the
    async engine's per-client parameter stack)."""
    def one(s: ParamSpec):
        logical = s.logical if s.logical else (None,) * len(s.shape)
        return ParamSpec((n_layers,) + tuple(s.shape), s.dtype,
                         (axis_name,) + tuple(logical), s.init, s.scale)
    return jax.tree.map(one, layer_tree, is_leaf=is_spec)


def chunk_divisor(seq: int, cap: int) -> int:
    """Largest chunk length <= ``cap`` that divides ``seq`` exactly.

    The chunked recurrent forms (wkv6 / SSD) scan over fixed-size chunks
    and require the sequence to tile evenly; prefill chunks arrive at
    arbitrary span lengths, so pick the best even tiling (worst case 1,
    which degenerates to the exact per-token recurrence)."""
    for c in range(min(cap, seq), 1, -1):
        if seq % c == 0:
            return c
    return 1
