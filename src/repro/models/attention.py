"""Attention: GQA/MQA/MHA + MLA, causal/sliding-window, KV cache decode.

Two execution paths:
* ``mha_chunked`` — pure-jnp online-softmax attention with query chunking
  (the XLA path, also the oracle for the Pallas flash kernel).
* decode path — one new token against a (possibly sequence-sharded) cache,
  computed as a masked einsum over the full cache (baseline) or a gathered
  sliding window (``window_gather=True``, a §Perf optimization).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import apply_rope, rms_norm_simple
from repro.sharding.rules import shard_constraint

NEG_INF = -1e30


# ------------------------------------------------------------ param specs --

def attention_specs(cfg, d: Optional[int] = None):
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim
    pd = cfg.param_dtype
    if cfg.use_mla:
        sp = {
            "wq_a": ParamSpec((d, cfg.q_lora_rank), pd, ("embed", "latent"), "scaled"),
            "q_norm": ParamSpec((cfg.q_lora_rank,), "float32", (None,), "ones"),
            "wq_b": ParamSpec((cfg.q_lora_rank,
                               cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)),
                              pd, ("latent", "heads_out"), "scaled"),
            "wkv_a": ParamSpec((d, cfg.kv_lora_rank + cfg.qk_rope_dim), pd,
                               ("embed", None), "scaled"),
            "kv_norm": ParamSpec((cfg.kv_lora_rank,), "float32", (None,), "ones"),
            "wkv_b": ParamSpec((cfg.kv_lora_rank,
                                cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)),
                               pd, ("latent", "heads_out"), "scaled"),
            "wo": ParamSpec((cfg.n_heads * cfg.v_head_dim, d), pd,
                            ("heads_out", "embed"), "scaled"),
        }
        return sp
    sp = {
        "wq": ParamSpec((d, cfg.n_heads * hd), pd, ("embed", "heads_out"), "scaled"),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), pd, ("embed", "kv_out"), "scaled"),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), pd, ("embed", "kv_out"), "scaled"),
        "wo": ParamSpec((cfg.n_heads * hd, d), pd, ("heads_out", "embed"), "scaled"),
    }
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), "float32", (None,), "ones")
        sp["k_norm"] = ParamSpec((hd,), "float32", (None,), "ones")
    return sp


def cache_specs(cfg, batch: int, seq: int, dtype="bfloat16"):
    """Abstract KV-cache layout for decode shapes."""
    hd = cfg.resolved_head_dim
    if cfg.use_mla:
        # MLA caches the compressed latent + shared rope key only.
        width = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"latent": ParamSpec((cfg.n_layers, batch, seq, width), dtype,
                                    ("layers", "cache_batch", "cache_seq", None))}
    return {
        "k": ParamSpec((cfg.n_layers, batch, seq, cfg.n_kv_heads, hd), dtype,
                       ("layers", "cache_batch", "cache_seq", "cache_heads", None)),
        "v": ParamSpec((cfg.n_layers, batch, seq, cfg.n_kv_heads, hd), dtype,
                       ("layers", "cache_batch", "cache_seq", "cache_heads", None)),
    }


# ------------------------------------------------- chunked full attention --

def mha_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                q_chunk: int = 512, logit_softcap: float = 0.0,
                q_offset: int = 0, scale: Optional[float] = None):
    """q: (B, Sq, Hq, hd), k/v: (B, Skv, Hkv, hd). GQA via head grouping.

    Scans over query chunks; each chunk materializes (B, H, qc, Skv) scores
    — bounded memory for 32k prefill. ``window`` > 0 enables sliding-window
    masking (keys older than ``window`` are masked out).
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    vd = v.shape[-1]                                     # may differ (MLA)
    G = Hq // Hkv
    scale = hd ** -0.5 if scale is None else scale
    qc = min(q_chunk, Sq)
    pad = (-Sq) % qc                                     # ragged Sq (whisper
    if pad:                                              # encoder: 1500)
        q = jnp.concatenate(
            [q, jnp.zeros((B, pad, Hq, hd), q.dtype)], axis=1)
        Sq_p = Sq + pad
    else:
        Sq_p = Sq
    n_chunks = Sq_p // qc

    qr = q.reshape(B, n_chunks, qc, Hkv, G, hd)
    kpos = jnp.arange(Skv)

    def one_chunk(carry, qi):
        qch, idx = qi                                    # (B, qc, Hkv, G, hd)
        qpos = q_offset + idx * qc + jnp.arange(qc)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qch.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = jnp.ones((qc, Skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, outs = jax.lax.scan(
        one_chunk, None,
        (qr.transpose(1, 0, 2, 3, 4, 5), jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, Hq, vd)
    return out[:, :Sq]


# ------------------------------------------------------------ decode path --

def decode_attend(q, k_cache, v_cache, cur_pos, *, window: int = 0,
                  logit_softcap: float = 0.0, window_gather: bool = False,
                  scale: Optional[float] = None):
    """One-token decode. q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd).

    Baseline reads the full cache with a position mask. With
    ``window_gather`` and window>0, dynamic-slices only the live window —
    cuts the HBM read from S to W keys (§Perf optimization).
    """
    B, _, Hq, hd = q.shape
    _, S, Hkv, _ = k_cache.shape
    vd = v_cache.shape[-1]                               # may differ (MLA)
    G = Hq // Hkv
    scale = hd ** -0.5 if scale is None else scale
    qr = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * scale
    cur_pos = jnp.asarray(cur_pos)

    if window_gather and window > 0 and window < S:
        assert cur_pos.ndim == 0, "window_gather needs a shared cur_pos"
        start = jnp.clip(cur_pos + 1 - window, 0, S - window)
        k_cache = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        v_cache = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        kpos = start + jnp.arange(window)
    else:
        kpos = jnp.arange(S)

    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if cur_pos.ndim:                                     # per-row positions
        mask = kpos[None, :] <= cur_pos[:, None]
        if window > 0:
            mask &= kpos[None, :] > (cur_pos[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        mask = kpos <= cur_pos
        if window > 0:
            mask &= kpos > (cur_pos - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, vd).astype(q.dtype)


# ------------------------------------------------------- paged cache ops --

def paged_update_gather(pool, row, dest_page, in_page, gather_rows):
    """Write one row per batch element into the page pool, then gather
    each element's full (masked) sequence extent back out.

    pool: (n_pages, page_size, *tail); row: (B, *tail) the new entry;
    dest_page/in_page: (B,) write coordinates (inactive rows land on the
    trash page — never read); gather_rows: (B, S_pad) flat pool rows.
    Returns (new_pool, gathered (B, S_pad, *tail))."""
    P, pg = pool.shape[:2]
    flat = pool.reshape((P * pg,) + pool.shape[2:])
    flat = flat.at[dest_page * pg + in_page].set(row.astype(pool.dtype))
    return flat.reshape(pool.shape), flat[gather_rows]


# -------------------------------------------------------------- GQA block --

def attention_apply(cfg, p, x, *, positions, cache=None, cur_pos=None,
                    window: int = 0, kv_override=None, causal=True,
                    window_gather: bool = False, paging=None):
    """Full attention sub-layer. Returns (out, new_cache_slice).

    cache: dict(k=(B,S,Hkv,hd), v=...) for this layer, or None. With
    ``paging`` set (the continuous scheduler's batched decode step) the
    cache leaves are shared page pools (n_pages, page_size, Hkv, hd)
    instead, ``cur_pos`` is a per-row (B,) vector, and the new k/v row is
    scattered through the slot's block table.
    kv_override: (B, Se, d) source for cross-attention (whisper decoder).
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    else:
        src = kv_override
        Se = src.shape[1]
        k = jnp.einsum("bsd,dh->bsh", src, p["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", src, p["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])

    if cfg.pos == "rope" and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q = shard_constraint(q, ("batch", None, "heads_act", None))
    new_cache = None
    if paging is not None and kv_override is None:
        # paged decode: one token per slot. Scatter the new k/v row into
        # the shared pool through the slot's block table, gather the
        # slot's full seq_len extent back, and attend with the per-row
        # position mask — masked positions (stale page contents, the
        # zero page) contribute exactly 0.0, so this is bitwise-equal to
        # the dense slot-stacked path it replaces.
        assert S == 1, "paged attention decodes one token per slot"
        dest_page, in_page = paging.write_rows(cur_pos)
        rows = paging.gather_rows()
        pool_k, k_cache = paged_update_gather(
            cache["k"], k[:, 0], dest_page, in_page, rows)
        pool_v, v_cache = paged_update_gather(
            cache["v"], v[:, 0], dest_page, in_page, rows)
        o = decode_attend(q, k_cache, v_cache, cur_pos, window=window,
                          logit_softcap=cfg.attn_logit_softcap)
        new_cache = {"k": pool_k, "v": pool_v}
    elif cache is not None and kv_override is None:
        # decode: write this step's k/v at cur_pos, attend over the cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cur_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cur_pos, axis=1)
        if S == 1:
            o = decode_attend(q, k_cache, v_cache, cur_pos, window=window,
                              logit_softcap=cfg.attn_logit_softcap,
                              window_gather=window_gather)
        else:
            # chunked prefill: the whole S-token chunk attends causally
            # over the updated cache in one pass. The causal mask offset
            # by cur_pos hides both the future and the not-yet-written
            # (zero) cache slots past cur_pos + S.
            o = mha_chunked(q, k_cache, v_cache, causal=True, window=window,
                            logit_softcap=cfg.attn_logit_softcap,
                            q_offset=cur_pos)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = mha_chunked(q, k, v, causal=causal and kv_override is None,
                        window=window,
                        logit_softcap=cfg.attn_logit_softcap)
    o = shard_constraint(o, ("batch", None, "heads_act", None))
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, cfg.n_heads * hd),
                     p["wo"]).astype(dt)
    return out, new_cache


# -------------------------------------------------------------- MLA block --

def _mla_absorbed_decode(cfg, p, q_nope, q_rope, lat, kr, cur_pos, *,
                         window: int, scale: float):
    """Weight-absorbed MLA decode (§Perf): fold W_uk into the query and
    W_uv into the output so attention runs directly against the latent
    cache — per step the cache read is S·(r+rd) instead of the expanded
    S·H·(nd+vd) (~72× less HBM traffic for deepseek-v3)."""
    B, S1, H, nd = q_nope.shape
    r = cfg.kv_lora_rank
    vd = cfg.v_head_dim
    wkv_b = p["wkv_b"].reshape(r, H, nd + vd)
    w_uk, w_uv = wkv_b[..., :nd], wkv_b[..., nd:]

    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # (B,1,H,r)
    s = (jnp.einsum("bshr,bkr->bhsk", q_lat, lat.astype(jnp.float32))
         + jnp.einsum("bshd,bkd->bhsk", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    kpos = jnp.arange(lat.shape[1])
    cur_pos = jnp.asarray(cur_pos)
    if cur_pos.ndim:                                      # per-row positions
        mask = kpos[None, :] <= cur_pos[:, None]
        if window > 0:
            mask &= kpos[None, :] > (cur_pos[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        mask = kpos <= cur_pos
        if window > 0:
            mask &= kpos > (cur_pos - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)                    # (B,H,1,S)
    ctx = jnp.einsum("bhsk,bkr->bshr", pattn, lat.astype(jnp.float32))
    o = jnp.einsum("bshr,rhv->bshv", ctx, w_uv.astype(jnp.float32))
    return o.astype(q_nope.dtype)                         # (B,1,H,vd)


def _mla_expand(cfg, p, latent, k_rope, dtype):
    """Expand latent -> per-head (k, v); k = [k_nope | k_rope(bcast)]."""
    B, S, _ = latent.shape
    H, nd, vd, rd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
    kv = jnp.einsum("bkr,rh->bkh", latent, p["wkv_b"]).reshape(B, S, H, nd + vd)
    kv = shard_constraint(kv, ("batch", None, "heads_act", None))
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    k = shard_constraint(k, ("batch", None, "heads_act", None))
    return k.astype(dtype), v.astype(dtype)


def mla_apply(cfg, p, x, *, positions, cache=None, cur_pos=None,
              window: int = 0, paging=None):
    """DeepSeek-V3 Multi-head Latent Attention.

    Cache stores only (kv_lora_rank + qk_rope_dim) per token; k/v are
    re-expanded from the latent on use (baseline; the weight-absorbed
    variant that scores directly in latent space is a §Perf candidate).
    Query/key are concatenated [nope|rope] so the chunked GQA path is reused
    (scale = (nd+rd)^-1/2 matches DeepSeek's).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    scale = (nd + rd) ** -0.5

    qa = rms_norm_simple(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", qa, p["wq_b"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shard_constraint(q, ("batch", None, "heads_act", None))

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])        # (B,S,lora+rd)
    latent = rms_norm_simple(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., cfg.kv_lora_rank:], positions,
                        cfg.rope_theta, has_heads=False)   # (B,S,rd) shared

    new_cache = None
    if paging is not None:
        # paged decode over the latent pool (n_pages, page_size, width):
        # same scatter-through-table + full-extent gather as the GQA path.
        assert S == 1, "paged MLA decodes one token per slot"
        packed = jnp.concatenate([latent, k_rope], axis=-1)
        dest_page, in_page = paging.write_rows(cur_pos)
        pool, lat_cache = paged_update_gather(
            cache["latent"], packed[:, 0], dest_page, in_page,
            paging.gather_rows())
        new_cache = {"latent": pool}
        lat = lat_cache[..., :cfg.kv_lora_rank].astype(dt)
        kr = lat_cache[..., cfg.kv_lora_rank:].astype(dt)
        if cfg.mla_absorb:
            o = _mla_absorbed_decode(cfg, p, q_nope, q_rope, lat, kr,
                                     cur_pos, window=window, scale=scale)
        else:
            k, v = _mla_expand(cfg, p, lat, kr, dt)
            o = decode_attend(q, k, v, cur_pos, window=window, scale=scale)
    elif cache is not None:
        packed = jnp.concatenate([latent, k_rope], axis=-1)
        lat_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], packed.astype(cache["latent"].dtype), cur_pos, axis=1)
        new_cache = {"latent": lat_cache}
        lat = lat_cache[..., :cfg.kv_lora_rank].astype(dt)
        kr = lat_cache[..., cfg.kv_lora_rank:].astype(dt)
        if S > 1:
            # chunked prefill: expand the latent cache once and run the
            # whole chunk causally against it (absorption is a per-token
            # decode optimization; chunks amortize the expansion anyway)
            k, v = _mla_expand(cfg, p, lat, kr, dt)
            o = mha_chunked(q, k, v, causal=True, window=window,
                            scale=scale, q_offset=cur_pos)
        elif cfg.mla_absorb:
            o = _mla_absorbed_decode(cfg, p, q_nope, q_rope, lat, kr,
                                     cur_pos, window=window, scale=scale)
        else:
            k, v = _mla_expand(cfg, p, lat, kr, dt)
            o = decode_attend(q, k, v, cur_pos, window=window, scale=scale)
    else:
        k, v = _mla_expand(cfg, p, latent, k_rope, dt)
        o = mha_chunked(q, k, v, causal=True, window=window, scale=scale)
    o = shard_constraint(o, ("batch", None, "heads_act", None))
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * vd),
                     p["wo"]).astype(dt)
    return out, new_cache
