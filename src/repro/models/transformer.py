"""Decoder-only transformer assembly for all decoder families.

Covers: dense (internlm2/granite/phi3/nemotron), moe (qwen3/deepseek incl.
MLA + first-k-dense + MTP), vlm (internvl2 — stub patch embeds + projector),
ssm (rwkv6), hybrid (zamba2 — mamba2 trunk + shared attention block).

Layers are scanned (stacked params, ``lax.scan``) with optional remat so the
61-layer configs lower quickly and the HLO stays compact. KV caches ride the
scan as per-layer xs/ys.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ParamSpec, freeze_state, stack_layer_specs
from repro.models.layers import (apply_norm, embed_lookup, norm_specs,
                                 unembed)
from repro.models.mlp import mlp_apply, mlp_specs
from repro.sharding.rules import shard_constraint


# ============================================================ param specs ==

def _attn_block_specs(cfg, d_ff: Optional[int] = None, moe: bool = False):
    sp = {"ln1": norm_specs(cfg, cfg.d_model),
          "ln2": norm_specs(cfg, cfg.d_model)}
    if cfg.use_mla:
        sp["attn"] = attn.attention_specs(cfg)
    else:
        sp["attn"] = attn.attention_specs(cfg)
    if moe:
        sp["moe"] = moe_mod.moe_specs(cfg, cfg.d_model)
    else:
        sp["mlp"] = mlp_specs(cfg, cfg.d_model, d_ff or cfg.d_ff)
    return sp


def _rwkv_block_specs(cfg):
    return {"ln1": norm_specs(cfg, cfg.d_model),
            "tmix": rwkv_mod.rwkv_specs(cfg, cfg.d_model),
            "ln2": norm_specs(cfg, cfg.d_model),
            "cmix": rwkv_mod.rwkv_channel_mix_specs(cfg, cfg.d_model)}


def _mamba_block_specs(cfg):
    return {"ln1": norm_specs(cfg, cfg.d_model),
            "ssm": ssm_mod.ssm_specs(cfg, cfg.d_model)}


def backbone_specs(cfg, max_seq: int):
    """Full parameter spec tree for a decoder-only config."""
    sp = {"embed": {"table": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                       cfg.param_dtype, ("vocab", "embed"))},
          "final_norm": norm_specs(cfg, cfg.d_model),
          "lm_head": {"table": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                         cfg.param_dtype, ("vocab", "embed"),
                                         "scaled")}}
    if cfg.pos == "learned":
        sp["pos_embed"] = ParamSpec((max_seq, cfg.d_model), cfg.param_dtype,
                                    ("vocab", "embed"))
    if cfg.frontend_dim:
        sp["proj"] = {"w": ParamSpec((cfg.frontend_dim, cfg.d_model),
                                     cfg.param_dtype, ("frontend", "embed"),
                                     "scaled"),
                      "b": ParamSpec((cfg.d_model,), "float32", (None,), "zeros")}

    if cfg.family == "ssm":
        sp["blocks"] = stack_layer_specs(_rwkv_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        inner = stack_layer_specs(_mamba_block_specs(cfg), cfg.attn_every)
        sp["blocks"] = stack_layer_specs(inner, n_super)
        sp["shared_block"] = _attn_block_specs(cfg)
    elif cfg.n_experts:
        n_moe = cfg.n_layers - cfg.first_k_dense
        sp["blocks"] = stack_layer_specs(
            _attn_block_specs(cfg, moe=True), n_moe)
        if cfg.first_k_dense:
            sp["dense_blocks"] = stack_layer_specs(
                _attn_block_specs(cfg, moe=False), cfg.first_k_dense)
        if cfg.n_mtp:
            sp["mtp"] = {"block": _attn_block_specs(cfg, moe=False),
                         "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                           cfg.param_dtype, ("embed", None),
                                           "scaled"),
                         "norm": norm_specs(cfg, cfg.d_model)}
    else:
        sp["blocks"] = stack_layer_specs(_attn_block_specs(cfg), cfg.n_layers)
    return sp


# ============================================================== blocks =====

def _attn_block_apply(cfg, p, x, *, positions, cache=None, cur_pos=None,
                      window=0, decode=False, window_gather=False,
                      gather_experts=False, paging=None):
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.use_mla:
        a, new_cache = attn.mla_apply(cfg, p["attn"], h, positions=positions,
                                      cache=cache, cur_pos=cur_pos,
                                      window=window, paging=paging)
    else:
        a, new_cache = attn.attention_apply(
            cfg, p["attn"], h, positions=positions, cache=cache,
            cur_pos=cur_pos, window=window, window_gather=window_gather,
            paging=paging)
    if cfg.rs_outputs:
        # force the TP output projection's partial sums to land directly in
        # the seq-sharded residual layout => reduce-scatter, not all-reduce
        a = shard_constraint(a, ("batch", "seq_act", "embed_act"))
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    aux = jnp.float32(0.0)
    if "moe" in p:
        m, aux = moe_mod.moe_apply(cfg, p["moe"], h, decode=decode,
                                   gather_experts=gather_experts)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    if cfg.rs_outputs:
        m = shard_constraint(m, ("batch", "seq_act", "embed_act"))
    return x + m, new_cache, aux


def _rwkv_block_apply(cfg, p, x, *, state=None, active=None):
    h = apply_norm(cfg, p["ln1"], x)
    tstate = None if state is None else {"wkv": state["wkv"],
                                         "shift": state["shift"]}
    t, new_t = rwkv_mod.rwkv_time_mix(cfg, p["tmix"], h, state=tstate)
    x = x + t
    h2 = apply_norm(cfg, p["ln2"], x)
    prev_c = None if state is None else state["shift_c"].astype(x.dtype)
    c = rwkv_mod.rwkv_channel_mix(cfg, p["cmix"], h2, prev=prev_c)
    new_state = None
    if state is not None:
        new_state = {"wkv": new_t["wkv"], "shift": new_t["shift"],
                     "shift_c": h2[:, -1].astype(state["shift_c"].dtype)}
        if active is not None:
            new_state = jax.tree.map(
                lambda n, o: freeze_state(active, n, o), new_state, state)
    return x + c, new_state


def _mamba_block_apply(cfg, p, x, *, state=None, active=None):
    h = apply_norm(cfg, p["ln1"], x)
    s, new_state = ssm_mod.ssm_apply(cfg, p["ssm"], h, state=state)
    if state is not None and active is not None:
        new_state = jax.tree.map(
            lambda n, o: freeze_state(active, n, o), new_state, state)
    return x + s, new_state


# ======================================================== backbone passes ==

def _maybe_remat(cfg, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _boundary(cfg, x):
    """Block boundary: sequence-parallel residual sharding (the remat-saved
    tensor). See DESIGN.md §6 — 16× smaller activation checkpoints."""
    if cfg.seq_shard_acts:
        return shard_constraint(x, ("batch", "seq_act", "embed_act"))
    return x


def scan_apply(cfg, body, carry, xs, n: int):
    """lax.scan over stacked layer params, or an unrolled Python loop when
    cfg.scan_layers=False (used by the dry-run cost-model probes — XLA's
    cost_analysis counts a while-loop body ONCE, so probes unroll)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        xs_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if ys and any(leaf is not None for leaf in jax.tree.leaves(ys[0])):
        ys_stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys_stacked = None
    return carry, ys_stacked


def backbone_apply(cfg, params, x, *, positions, caches=None, cur_pos=None,
                   window=0, window_gather=False, gather_experts=False,
                   paging=None):
    """Run the stacked blocks. x: (B,S,d) embeddings.

    caches: family-specific stacked state (leading dim = layers), or None.
    ``paging`` (a :class:`repro.models.common.PageContext`) switches the
    sequence-indexed cache leaves to the paged-pool layout with per-row
    positions (the continuous scheduler's batched decode step); recurrent
    state leaves are then slot-batched and frozen on inactive rows.
    Returns (hidden (B,S,d), new_caches, aux_losses).
    """
    decode = caches is not None
    active = None if paging is None else paging.active

    if cfg.family == "ssm":
        def body(h, xs):
            p_l, st_l = xs
            h2, new_st = _rwkv_block_apply(cfg, p_l, _boundary(cfg, h),
                                           state=st_l, active=active)
            return h2, new_st
        body = _maybe_remat(cfg, body)
        x, new_caches = scan_apply(cfg, body, x, (params["blocks"], caches),
                                   cfg.n_layers)
        return x, new_caches, jnp.float32(0.0)

    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        shared_p = params["shared_block"]

        def super_body(h, xs):
            p_sup, st_sup, attn_cache = xs
            h = _boundary(cfg, h)

            # inner: attn_every mamba blocks
            def inner(h2, xs2):
                p_l, st_l = xs2
                h3, new_st = _mamba_block_apply(cfg, p_l, _boundary(cfg, h2),
                                                state=st_l, active=active)
                return h3, new_st
            h, new_sts = scan_apply(cfg, inner, h, (p_sup, st_sup),
                                    cfg.attn_every)
            # shared attention block (weights reused across sites)
            h, new_attn_cache, _ = _attn_block_apply(
                cfg, shared_p, h, positions=positions, cache=attn_cache,
                cur_pos=cur_pos, window=window, decode=decode,
                window_gather=window_gather, paging=paging)
            return h, (new_sts, new_attn_cache)
        super_body = _maybe_remat(cfg, super_body)

        if decode:
            ssm_states, attn_caches = caches
        else:
            ssm_states, attn_caches = None, None
        xs = (params["blocks"], ssm_states, attn_caches)
        x, new_caches = scan_apply(cfg, super_body, x, xs, n_super)
        return x, new_caches, jnp.float32(0.0)

    # attention families (dense / moe / vlm backbone)
    aux_total = jnp.float32(0.0)

    def body(carry, xs):
        h, aux = carry
        p_l, c_l = xs
        h2, new_c, a = _attn_block_apply(
            cfg, p_l, _boundary(cfg, h), positions=positions, cache=c_l,
            cur_pos=cur_pos, window=window, decode=decode,
            window_gather=window_gather, gather_experts=gather_experts,
            paging=paging)
        return (h2, aux + a), new_c
    body = _maybe_remat(cfg, body)

    if cfg.first_k_dense and cfg.n_experts:
        dense_caches = None if caches is None else caches["dense"]
        (x, aux_total), new_dense = scan_apply(
            cfg, body, (x, aux_total), (params["dense_blocks"], dense_caches),
            cfg.first_k_dense)
    else:
        new_dense = None

    n_main = (cfg.n_layers - cfg.first_k_dense
              if (cfg.first_k_dense and cfg.n_experts) else cfg.n_layers)
    main_caches = None
    if caches is not None:
        main_caches = caches["main"] if isinstance(caches, dict) and "main" in caches else caches
    (x, aux_total), new_main = scan_apply(
        cfg, body, (x, aux_total), (params["blocks"], main_caches), n_main)

    if new_dense is not None:
        new_caches = {"dense": new_dense, "main": new_main}
    else:
        new_caches = new_main
    return x, (new_caches if decode else None), aux_total


# ============================================================== forward ====

def embed_inputs(cfg, params, inputs, *, positions):
    """Map raw inputs -> (B,S,d) embeddings. Handles VLM patch concat.

    This is the CLIENT part of the cascade partition (DESIGN.md §2)."""
    emb_scale = 1.0
    if cfg.family == "vlm" and "patch_embeds" in inputs:
        tokens = inputs["tokens"]                       # (B, S_text)
        patches = inputs["patch_embeds"]                # (B, Nv, frontend)
        te = embed_lookup(params["embed"], tokens, iota=cfg.iota_embed)
        pe = (jnp.einsum("bnf,fd->bnd", patches.astype(te.dtype),
                         params["proj"]["w"])
              + params["proj"]["b"].astype(te.dtype))
        x = jnp.concatenate([pe, te], axis=1)
    else:
        tokens = inputs["tokens"]
        x = embed_lookup(params["embed"], tokens, iota=cfg.iota_embed)
    if cfg.pos == "learned":
        pos_table = params["pos_embed"]
        pe = jnp.take(pos_table, jnp.clip(positions, 0, pos_table.shape[0] - 1),
                      axis=0)
        x = x + pe.astype(x.dtype)
    x = shard_constraint(x, ("batch", None, "embed_act"))
    return x * emb_scale


def forward(cfg, params, inputs, *, caches=None, cur_pos=None, window=0,
            window_gather=False, gather_experts=False):
    """Full forward. Training/prefill: inputs over S. Decode: S==1.

    Returns (logits (B,S,vocab), new_caches, aux)."""
    if caches is None:
        S = inputs["tokens"].shape[1]
        if cfg.family == "vlm" and "patch_embeds" in inputs:
            S += cfg.n_vision_tokens
        positions = jnp.arange(S)
    else:
        # decode positions: cur_pos for the classic one-token step, or a
        # cur_pos-offset run for a multi-token chunk (chunked prefill)
        positions = jnp.asarray(cur_pos) + jnp.arange(
            inputs["tokens"].shape[1])                  # (S,)
    x = embed_inputs(cfg, params, inputs, positions=positions)
    h, new_caches, aux = backbone_apply(
        cfg, params, x, positions=positions, caches=caches, cur_pos=cur_pos,
        window=window, window_gather=window_gather,
        gather_experts=gather_experts)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = unembed(params["lm_head"], h)
    logits = shard_constraint(logits, ("batch", None, "vocab_act"))
    return logits, new_caches, aux


# ============================================================= loss ========

def lm_loss(cfg, params, inputs, *, window=0, label_mask=None):
    """Next-token CE over the text positions. Returns (loss, aux_dict)."""
    logits, _, aux = forward(cfg, params, inputs, window=window)
    labels = inputs["labels"]
    if cfg.family == "vlm":
        # logits cover [vision; text]; predict text tokens only
        logits = logits[:, cfg.n_vision_tokens:]
    ce = softmax_xent(logits[:, :-1], labels[:, 1:], cfg.padded_vocab)
    mask = jnp.ones_like(labels[:, 1:], jnp.float32) if label_mask is None \
        else label_mask[:, 1:].astype(jnp.float32)
    loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    if cfg.n_mtp and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(cfg, params, inputs, window=window)
    return loss + aux, {"aux": aux}


def _mtp_loss(cfg, params, inputs, *, window=0):
    """DeepSeek-style multi-token-prediction head (depth-1): one extra
    block predicts t+2 from [emb(tok_t) ; emb(tok_{t+1})]. (Simplified:
    the combiner consumes embeddings rather than final hidden states, so
    the MTP head costs one block + one unembed — see DESIGN.md §8.)"""
    tokens, labels = inputs["tokens"], inputs["labels"]
    x = embed_lookup(params["embed"], tokens, iota=cfg.iota_embed)
    # combine shifted embedding with itself as a cheap proxy for h_t
    e_next = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
    comb = jnp.concatenate([x, e_next], axis=-1)
    h = jnp.einsum("bsd,de->bse", comb, params["mtp"]["proj"])
    h, _, _ = _attn_block_apply(cfg, params["mtp"]["block"], h,
                                positions=jnp.arange(h.shape[1]),
                                window=window)
    h = apply_norm(cfg, params["mtp"]["norm"], h)
    lg = unembed(params["lm_head"], h)
    ce = softmax_xent(lg[:, :-2], labels[:, 2:], cfg.padded_vocab)
    return jnp.mean(ce)


def softmax_xent(logits, labels, vocab):
    """Stable CE, SPMD-safe over a vocab-sharded logits dim.

    take_along_axis over a sharded dim makes GSPMD all-gather the full
    fp32 logits (tens of GB for 128k vocabs); the masked-reduction form
    below stays shard-local and only all-reduces (B,S) scalars."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vidx == labels[..., None], logits, 0.0), axis=-1)
    return lse - gold
