"""RWKV6 (Finch) — time-mix with data-dependent per-channel decay.

Per head (key dim K = value dim V = rwkv_head_dim):

    S_t   = diag(w_t) S_{t-1} + k_t v_t^T            (K, V) state
    y_t   = r_t @ (S_{t-1} + diag(u) k_t v_t^T)

with w_t ∈ (0,1)^K *data-dependent* (the Finch contribution) via a small
lora: w_t = exp(-exp(w0 + tanh(x_t A) B)). Train/prefill use a chunked
form (scan over chunks, (c×c) intra matrices, (K,V) carried state);
decode updates the state directly. Channel-mix is the squared-relu FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

W_LORA = 64


def rwkv_specs(cfg, d: int):
    pd = cfg.param_dtype
    return {
        "w_r": ParamSpec((d, d), pd, ("embed", "heads_out"), "scaled"),
        "w_k": ParamSpec((d, d), pd, ("embed", "heads_out"), "scaled"),
        "w_v": ParamSpec((d, d), pd, ("embed", "heads_out"), "scaled"),
        "w_g": ParamSpec((d, d), pd, ("embed", "heads_out"), "scaled"),
        "w_o": ParamSpec((d, d), pd, ("heads_out", "embed"), "scaled"),
        "decay_base": ParamSpec((d,), "float32", (None,), "zeros"),
        "decay_lora_a": ParamSpec((d, W_LORA), pd, ("embed", None), "scaled"),
        "decay_lora_b": ParamSpec((W_LORA, d), pd, (None, None), "scaled"),
        "bonus_u": ParamSpec((d,), "float32", (None,), "zeros"),
        "mix_r": ParamSpec((d,), "float32", (None,), "zeros"),
        "mix_k": ParamSpec((d,), "float32", (None,), "zeros"),
        "mix_v": ParamSpec((d,), "float32", (None,), "zeros"),
        "ln_x": ParamSpec((d,), "float32", (None,), "ones"),
    }


def rwkv_state_specs(cfg, batch: int, d: int, dtype="float32"):
    """Recurrent decode state (wkv matrix + token-shift tails). As in
    `ssm_state_specs`, "cache_batch" with no "cache_seq" axis tells the
    paged serve plane these leaves are sequence-independent: the
    continuous scheduler slot-stacks them and freezes inactive rows
    (`common.freeze_state`) rather than paging them."""
    H = cfg.n_rwkv_heads
    K = cfg.rwkv_head_dim
    return {
        "wkv": ParamSpec((cfg.n_layers, batch, H, K, K), dtype,
                         ("layers", "cache_batch", "cache_heads", None, None)),
        "shift": ParamSpec((cfg.n_layers, batch, d), dtype,
                           ("layers", "cache_batch", None)),
        "shift_c": ParamSpec((cfg.n_layers, batch, d), dtype,
                             ("layers", "cache_batch", None)),
    }


def _token_shift(x, mix, prev=None):
    """lerp(x_t, x_{t-1}, mix). prev: (B,d) last token of previous step."""
    B, S, d = x.shape
    if prev is None:
        prev = jnp.zeros((B, d), x.dtype)
    xs = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    m = jax.nn.sigmoid(mix).astype(x.dtype)
    return x * (1 - m) + xs * m


def wkv6_recurrent_ref(r, k, v, w, u):
    """Naive token scan — oracle. r,k,v,w: (B,S,H,K); u: (H,K)."""
    B, S, H, K = r.shape

    def step(S_, t):
        r_t, k_t, v_t, w_t = t                          # (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + u[None, :, :, None] * kv)
        S_ = S_ * w_t[..., None] + kv
        return S_, out

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    _, ys = jax.lax.scan(
        step, S0,
        tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w)))
    return ys.transpose(1, 0, 2, 3)


def wkv6_chunked(r, k, v, w, u, chunk, state0=None):
    """Chunked wkv6. r,k,v,w (B,S,H,K); u (H,K). Returns (y, final_state).

    Derivation: with cw_t = sum_{s<=t} log w_s, the weight of k_j on the
    readout at i>j is exp(cw_i - cw_j) / w_i ... concretely
    S_{i-1} contains k_j scaled by prod_{s=j+1..i-1} w_s = exp(cw_{i-1}-cw_j).
    """
    B, S, H, K = r.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    f32 = jnp.float32

    rr = r.reshape(B, nc, c, H, K).transpose(1, 0, 2, 3, 4).astype(f32)
    kk = k.reshape(B, nc, c, H, K).transpose(1, 0, 2, 3, 4).astype(f32)
    vv = v.reshape(B, nc, c, H, K).transpose(1, 0, 2, 3, 4).astype(f32)
    ww = w.reshape(B, nc, c, H, K).transpose(1, 0, 2, 3, 4).astype(f32)

    if state0 is None:
        state0 = jnp.zeros((B, H, K, K), f32)

    ii = jnp.arange(c)
    strict = (ii[:, None] > ii[None, :])                  # j < i
    diag = jnp.eye(c, dtype=bool)

    def scan_fn(S_, t):
        r_c, k_c, v_c, w_c = t                            # (B,c,H,K)
        lw = jnp.log(jnp.maximum(w_c, 1e-20))
        cw = jnp.cumsum(lw, axis=1)                       # (B,c,H,K)
        # intra: coeff(i,j) = exp(cw_{i-1} - cw_j) for j<i ; u·k_i on diag.
        # Stability: factor around the chunk-midpoint cum-decay so both
        # exp() factors stay within fp32 range (decay is also clamped at
        # rwkv_time_mix; see DESIGN.md numerics note).
        ref = cw[:, c // 2][:, None]                      # (B,1,H,K)
        ri = r_c * jnp.exp(cw - lw - ref)                 # r_i e^{cw_{i-1}-ref}
        kj = k_c * jnp.exp(ref - cw)                      # k_j e^{ref-cw_j}
        A = jnp.einsum("bihk,bjhk->bijh", ri, kj)
        A = jnp.where(strict[None, :, :, None], A, 0.0)
        Adiag = jnp.einsum("bihk,hk,bihk->bih", r_c, u, k_c)
        y = jnp.einsum("bijh,bjhv->bihv", A, v_c)
        y = y + Adiag[..., None] * v_c
        # inter: r_i e^{cw_{i-1}} @ S_prev (exponent <= 0: stable)
        ri0 = r_c * jnp.exp(cw - lw)
        y = y + jnp.einsum("bihk,bhkv->bihv", ri0, S_)
        # state: S' = e^{cw_last} S + sum_j e^{cw_last - cw_j} k_j v_j^T
        # (both exponents <= 0: stable)
        wtot = jnp.exp(cw[:, -1])                         # (B,H,K)
        kj2 = k_c * jnp.exp(cw[:, -1][:, None] - cw)
        S_ = (S_ * wtot[..., None]
              + jnp.einsum("bjhk,bjhv->bhkv", kj2, v_c))
        return S_, y

    final, ys = jax.lax.scan(scan_fn, state0, (rr, kk, vv, ww))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, K)
    return y, final


def rwkv_time_mix(cfg, p, x, *, state=None):
    """x (B,S,d) -> (out, new_state). state: dict(wkv (B,H,K,K), shift (B,d))."""
    B, S, d = x.shape
    H, K = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    dt_ = x.dtype

    prev = None if state is None else state["shift"].astype(dt_)
    xr = _token_shift(x, p["mix_r"], prev)
    xk = _token_shift(x, p["mix_k"], prev)
    xv = _token_shift(x, p["mix_v"], prev)

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, p["w_g"]))

    # data-dependent decay (the Finch contribution)
    lora = jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", x, p["decay_lora_a"])),
        p["decay_lora_b"])
    # clamp per-token log-decay to [-4, -1e-3]: keeps the chunked form's
    # exp() factors in fp32 range (chunk 32 -> max half-range exponent 64)
    log_w = -jnp.exp(p["decay_base"] + lora.astype(jnp.float32))
    w = jnp.exp(jnp.clip(log_w, -4.0, -1e-3))
    w = w.reshape(B, S, H, K)
    u = p["bonus_u"].reshape(H, K)

    r4 = shard_constraint(r, ("batch", None, "heads_act", None))
    if state is None:
        y, _ = wkv6_chunked(r4, k, v, w, u, cfg.rwkv_chunk)
        new_state = None
    elif S > 1:
        # chunked prefill with carried state: the same chunked form as
        # training, seeded from the decode state (wkv6_chunked threads
        # state0 across chunks). Chunk length must tile S and stay small
        # enough for the mid-point exp factoring (see clamp above).
        c = common.chunk_divisor(S, cfg.rwkv_chunk)
        y, S1 = wkv6_chunked(r4, k, v, w, u, c,
                             state0=state["wkv"].astype(jnp.float32))
        new_state = {"wkv": S1.astype(state["wkv"].dtype),
                     "shift": x[:, -1].astype(state["shift"].dtype)}
    else:
        S0 = state["wkv"].astype(jnp.float32)
        k0 = k[:, 0].astype(jnp.float32)
        v0 = v[:, 0].astype(jnp.float32)
        r0 = r[:, 0].astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", k0, v0)
        y = jnp.einsum("bhk,bhkv->bhv", r0, S0 + u[None, :, :, None] * kv)[:, None]
        S1 = S0 * w[:, 0][..., None] + kv
        new_state = {"wkv": S1.astype(state["wkv"].dtype),
                     "shift": x[:, -1].astype(state["shift"].dtype)}

    # group-norm-ish per head then output gate
    y = y.reshape(B, S, d).astype(jnp.float32)
    mu = jnp.mean(y.reshape(B, S, H, K), -1, keepdims=True)
    var = jnp.var(y.reshape(B, S, H, K), -1, keepdims=True)
    y = ((y.reshape(B, S, H, K) - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    y = y * p["ln_x"]
    out = jnp.einsum("bse,ed->bsd", (y.astype(dt_) * g.astype(dt_)), p["w_o"])
    return out, new_state


def rwkv_channel_mix_specs(cfg, d: int):
    pd = cfg.param_dtype
    return {
        "w_k": ParamSpec((d, cfg.d_ff), pd, ("embed", "ffn"), "scaled"),
        "w_v": ParamSpec((cfg.d_ff, d), pd, ("ffn", "embed"), "scaled"),
        "w_r": ParamSpec((d, d), pd, ("embed", None), "scaled"),
        "mix_k": ParamSpec((d,), "float32", (None,), "zeros"),
        "mix_r": ParamSpec((d,), "float32", (None,), "zeros"),
    }


def rwkv_channel_mix(cfg, p, x, *, prev=None):
    xk = _token_shift(x, p["mix_k"], prev)
    xr = _token_shift(x, p["mix_r"], prev)
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k))
    k = shard_constraint(k, ("batch", None, "ffn_act"))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]))
    return (r * kv).astype(x.dtype)
