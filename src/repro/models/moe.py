"""Mixture-of-Experts with expert-parallel sharding.

Three dispatch paths (selected automatically):

* ``train/prefill`` — per-batch-row sort/scatter **capacity dispatch**:
  within each batch row, (S·k) token-expert pairs are sorted by expert id,
  positioned by rank-in-expert, and scattered into an (E, C, d) buffer with
  capacity C = ceil(S·k/E · capacity_factor). Expert matmuls are then dense
  batched GEMMs einsum'd against (E, d, f) weights — FLOPs ≈ active-token
  FLOPs × capacity_factor, and the expert dim shards over the "model" mesh
  axis (expert parallelism; the scatter induces the all-to-all).
  All per-row ops vectorize over the (data-sharded) batch dim, so dispatch
  never communicates across data shards.
* ``decode, large batch`` — dense loop over experts with masking: every
  expert computes every token. With B·k >= E every expert's weights must be
  read anyway, so decode stays memory-optimal even though FLOPs (cheap,
  decode is memory-bound) are inflated E/k×.
* ``decode, tiny batch`` (B·k << E, e.g. long_500k) — gather only the
  routed experts' weights (B·k weight rows instead of E) — §Perf
  optimization, enabled with ``gather_experts=True``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import activation
from repro.sharding.rules import shard_constraint


def moe_specs(cfg, d: int):
    pd = cfg.param_dtype
    E, f = cfg.n_experts, cfg.moe_d_ff
    sp = {
        # router is tiny (d×E fp32) — keep it replicated; FSDP-sharding it
        # makes GSPMD reshard the full fp32 activation stream instead
        "router": ParamSpec((d, E), "float32", (None, None), "scaled"),
        "w_up": ParamSpec((E, d, f), pd, ("experts", "expert_d", None), "scaled"),
        "w_down": ParamSpec((E, f, d), pd, ("experts", None, "expert_d"), "scaled"),
    }
    if cfg.act == "swiglu":
        sp["w_gate"] = ParamSpec((E, d, f), pd, ("experts", "expert_d", None), "scaled")
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        sp["shared_up"] = ParamSpec((d, fs), pd, ("embed", "ffn"), "scaled")
        sp["shared_down"] = ParamSpec((fs, d), pd, ("ffn", "embed"), "scaled")
        if cfg.act == "swiglu":
            sp["shared_gate"] = ParamSpec((d, fs), pd, ("embed", "ffn"), "scaled")
    return sp


def _router(cfg, p, x):
    """x (B,S,d) -> (gates (B,S,k) fp32 normalized, idx (B,S,k), aux loss)."""
    # keep x bf16 on the wire; accumulate in fp32 via the dot itself
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # switch-style load-balance loss
    E = cfg.n_experts
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / cfg.top_k                                   # (E,)
    aux = E * jnp.sum(me * ce) * cfg.load_balance_coef
    return gates, idx, aux


def _expert_ffn_grouped(cfg, p, xb):
    """xb (B,G,E,C,d) -> same, through the per-expert MLP (E sharded)."""
    h = jnp.einsum("bgecd,edf->bgecf", xb, p["w_up"])
    g = jnp.einsum("bgecd,edf->bgecf", xb, p["w_gate"]) \
        if cfg.act == "swiglu" else None
    h = activation(cfg.act, h, g)
    h = shard_constraint(h, ("batch", None, "experts", None, None))
    y = jnp.einsum("bgecf,efd->bgecd", h, p["w_down"])
    # pin the einsum output to expert-parallel BEFORE the reverse
    # all-to-all, otherwise GSPMD back-propagates the group sharding into
    # the einsum and replicates the expert weights (14 GiB for deepseek).
    return shard_constraint(y, ("batch", None, "experts", None, None))


def moe_apply_dispatch(cfg, p, x):
    """Grouped sort-based capacity dispatch (train & prefill).

    GATHER-ONLY + GROUP-LOCAL: each batch row's sequence is split into
    ``moe_groups`` groups aligned with the sequence-parallel shards, and
    dispatch (sort, rank, capacity) happens *within* a group — so all the
    index math and token gathers are shard-local, and the single reshard
    (group-sharded -> expert-sharded) of the (…,E,C,d) buffer lowers to an
    all-to-all, exactly the EP pattern of production MoE systems. Large
    scatters are avoided entirely (GSPMD would replicate them).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = min(cfg.moe_groups, S)
    while S % G:                                         # smoke-size guard
        G -= 1
    Sg = S // G
    N = Sg * k                                           # pairs per group
    C = max(int(math.ceil(N / E * cfg.capacity_factor)), 4)

    gates, idx, aux = _router(cfg, p, x)                 # (B,S,k)
    xg = x.reshape(B, G, Sg, d)
    xg = shard_constraint(xg, ("batch", "seq_act", None, None))
    flat_e = idx.reshape(B, G, N)                        # expert id per pair
    flat_g = gates.reshape(B, G, N)
    tok_of_pair = jnp.repeat(jnp.arange(Sg), k)[None, None]      # (1,1,N)
    tok_of_pair = jnp.broadcast_to(tok_of_pair, (B, G, N))

    order = jnp.argsort(flat_e, axis=-1, stable=True)    # sort pairs by expert
    inv_order = jnp.argsort(order, axis=-1)
    se = jnp.take_along_axis(flat_e, order, -1)
    st = jnp.take_along_axis(tok_of_pair, order, -1)

    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=2)
    starts = jnp.cumsum(counts, axis=-1) - counts        # (B,G,E) exclusive
    rank = jnp.arange(N)[None, None] - jnp.take_along_axis(starts, se, -1)
    keep = rank < C

    # dispatch: gather the c-th pair of each expert from the sorted stream
    xs = jnp.take_along_axis(xg, st[..., None], axis=2)  # (B,G,N,d)
    idx_ec = starts[..., None] + jnp.arange(C)[None, None, None]  # (B,G,E,C)
    valid = (jnp.arange(C)[None, None, None]
             < jnp.minimum(counts, C)[..., None])
    idx_flat = jnp.clip(idx_ec.reshape(B, G, E * C), 0, N - 1)
    xb = jnp.take_along_axis(xs, idx_flat[..., None], axis=2)    # (B,G,EC,d)
    xb = xb * valid.reshape(B, G, E * C, 1).astype(xb.dtype)
    xb = xb.reshape(B, G, E, C, d)
    # the reshard below IS the all-to-all: groups -> experts
    xb = shard_constraint(xb, ("batch", None, "experts", None, None))

    yb = _expert_ffn_grouped(cfg, p, xb)
    yb = shard_constraint(yb, ("batch", "seq_act", None, None, None)) \
        .reshape(B, G, E * C, d)

    # return path: pair n reads slot (se[n], rank[n]) — another gather
    slot = jnp.clip(se * C + jnp.clip(rank, 0, C - 1), 0, E * C - 1)
    ys = jnp.take_along_axis(yb, slot[..., None], axis=2)        # (B,G,N,d)
    sg = jnp.take_along_axis(flat_g, order, -1)
    ys = ys * (sg * keep)[..., None]

    # unsort (gather via inverse permutation), pairs -> (Sg, k), sum
    ys = jnp.take_along_axis(ys, inv_order[..., None], axis=2)
    out = jnp.sum(ys.reshape(B, G, Sg, k, d).astype(jnp.float32), axis=3)
    out = out.reshape(B, S, d)

    if cfg.n_shared_experts:
        out = out + _shared(cfg, p, x)
    return out.astype(x.dtype), aux


def moe_apply_dense(cfg, p, x):
    """Masked dense loop (decode with large batch): every expert runs every
    token; contributions are gated by the router mask."""
    B, S, d = x.shape
    E = cfg.n_experts
    gates, idx, aux = _router(cfg, p, x)
    comb = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32)
                   * gates[..., None], axis=2)            # (B,S,E)

    h = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"]) if cfg.act == "swiglu" else None
    h = activation(cfg.act, h, g)
    y = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), comb)

    if cfg.n_shared_experts:
        out = out + _shared(cfg, p, x)
    return out.astype(x.dtype), aux


def moe_apply_gather(cfg, p, x):
    """Tiny-batch decode: gather the k routed experts' weights per token.
    Reads B·k expert weight sets instead of E (§Perf for long_500k)."""
    B, S, d = x.shape
    assert S == 1
    gates, idx, aux = _router(cfg, p, x)                  # (B,1,k)
    idxf = idx[:, 0]                                      # (B,k)
    up = jnp.take(p["w_up"], idxf, axis=0)                # (B,k,d,f)
    down = jnp.take(p["w_down"], idxf, axis=0)            # (B,k,f,d)
    h = jnp.einsum("bd,bkdf->bkf", x[:, 0], up)
    if cfg.act == "swiglu":
        gate_w = jnp.take(p["w_gate"], idxf, axis=0)
        g = jnp.einsum("bd,bkdf->bkf", x[:, 0], gate_w)
    else:
        g = None
    h = activation(cfg.act, h, g)
    y = jnp.einsum("bkf,bkfd->bkd", h, down)
    out = jnp.einsum("bkd,bk->bd", y.astype(jnp.float32), gates[:, 0])[:, None]
    if cfg.n_shared_experts:
        out = out + _shared(cfg, p, x)
    return out.astype(x.dtype), aux


def _shared(cfg, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
    g = jnp.einsum("bsd,df->bsf", x, p["shared_gate"]) if cfg.act == "swiglu" else None
    h = activation(cfg.act, h, g)
    return jnp.einsum("bsf,fd->bsd", h, p["shared_down"]).astype(jnp.float32)


def moe_apply(cfg, p, x, *, decode: bool = False, gather_experts: bool = False):
    if decode and gather_experts and x.shape[0] * cfg.top_k <= cfg.n_experts:
        return moe_apply_gather(cfg, p, x)
    if decode:
        return moe_apply_dense(cfg, p, x)
    return moe_apply_dispatch(cfg, p, x)
