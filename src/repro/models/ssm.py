"""Mamba2 (SSD) layer — chunked scan for train/prefill, state step for decode.

Recurrence per head h (state N = cfg.ssm_state, head dim P = ssm_head_dim):

    a_t    = exp(-softplus(dt_t) * exp(A_log_h))            scalar per head
    S_t    = a_t * S_{t-1} + softplus(dt_t) * (x_t ⊗ B_t)   (P, N)
    y_t    = S_t @ C_t + D_h * x_t                           (P,)

The chunked (SSD) form scans over chunks of length ``ssm_chunk``: within a
chunk the contribution is an attention-like (c×c) masked matrix; across
chunks only the (P×N) state is carried — sub-quadratic in sequence length
and TPU-friendly (all chunk math is dense matmuls for the MXU).

A short causal depthwise conv (width 4) precedes the SSM per Mamba2; its
tail is carried as decode state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

CONV_W = 4


def ssm_specs(cfg, d: int):
    pd = cfg.param_dtype
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    return {
        "w_in": ParamSpec((d, 2 * d_in), pd, ("embed", "ssm_inner"), "scaled"),
        "w_bc": ParamSpec((d, 2 * N), pd, ("embed", None), "scaled"),
        "w_dt": ParamSpec((d, H), pd, ("embed", None), "scaled"),
        "dt_bias": ParamSpec((H,), "float32", (None,), "zeros"),
        "A_log": ParamSpec((H,), "float32", (None,), "zeros"),
        "D": ParamSpec((H,), "float32", (None,), "ones"),
        "conv_w": ParamSpec((CONV_W, d_in), pd, (None, "ssm_inner"), "scaled"),
        "w_out": ParamSpec((d_in, d), pd, ("ssm_inner", "embed"), "scaled"),
    }


def ssm_state_specs(cfg, batch: int, d: int, dtype="float32"):
    """Recurrent decode state. The logical axis names are load-bearing
    for the paged serve plane (`federation/paging.py`): "cache_batch"
    WITHOUT a "cache_seq" axis marks these leaves as sequence-independent
    state, so the continuous scheduler slot-stacks them (batch axis
    widened to the slot count, rows frozen via `common.freeze_state`
    while a slot is inactive) instead of paging them."""
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    return {
        "ssm": ParamSpec((cfg.n_layers, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                         dtype, ("layers", "cache_batch", "cache_heads", None, None)),
        "conv": ParamSpec((cfg.n_layers, batch, CONV_W - 1, d_in), dtype,
                          ("layers", "cache_batch", None, "ssm_inner")),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv. x (B,S,D), w (W,D), tail (B,W-1,D) or None."""
    B, S, D = x.shape
    pad = (jnp.zeros((B, CONV_W - 1, D), x.dtype) if tail is None
           else tail.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + S] * w[i] for i in range(CONV_W))
    new_tail = xp[:, S:]                                  # last W-1 inputs
    if tail is not None:
        # keep the carried state in its spec dtype: the values are already
        # rounded to x.dtype, so the widening store is exact — and a
        # decode step's cache signature stays stable call over call
        # (the serve plane compiles its steps ahead of time)
        new_tail = new_tail.astype(tail.dtype)
    return out, new_tail


def _ssd_chunked(xh, a, dt, Bm, Cm, chunk, state0=None):
    """Chunked SSD scan.

    xh (B,S,H,P), a (B,S,H) decay in (0,1], dt (B,S,H), Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c

    xr = xh.reshape(B, nc, c, H, P)
    ar = a.reshape(B, nc, c, H)
    dtr = dt.reshape(B, nc, c, H)
    Br = Bm.reshape(B, nc, c, N)
    Cr = Cm.reshape(B, nc, c, N)

    la = jnp.log(jnp.maximum(ar, 1e-20)).astype(jnp.float32)
    cum = jnp.cumsum(la, axis=2)                          # (B,nc,c,H) log prod a_1..t

    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)

    def scan_fn(state, inp):
        x_c, cum_c, dt_c, B_c, C_c = inp                  # (B,c,H,P) etc.
        # intra-chunk: y[i] += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i·B_j) x_j
        seg = cum_c[:, :, None, :] - cum_c[:, None, :, :]     # (B,i,j,H)
        mask = jnp.tril(jnp.ones((x_c.shape[1], x_c.shape[1]), bool))
        # double-where: exp() must never see the +inf upper triangle or its
        # cotangent NaNs the backward pass
        seg = jnp.where(mask[None, :, :, None], seg, 0.0)
        dec = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))              # (B,i,j)
        M = dec * cb[..., None] * dt_c[:, None, :, :]         # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, x_c.astype(jnp.float32))
        # inter-chunk: y[i] += exp(cum_i) * C_i @ state^T
        y_inter = jnp.einsum("bin,bhpn->bihp", C_c.astype(jnp.float32),
                             state) * jnp.exp(cum_c)[..., None]
        # state update: S' = a_total*S + sum_j exp(cum_last-cum_j) dt_j x_j⊗B_j
        w_j = jnp.exp(cum_c[:, -1:, :] - cum_c) * dt_c        # (B,c,H)
        ds = jnp.einsum("bjhp,bjn,bjh->bhpn", x_c.astype(jnp.float32),
                        B_c.astype(jnp.float32), w_j)
        state = state * jnp.exp(cum_c[:, -1])[:, :, None, None] + ds
        return state, (y_intra + y_inter)

    final, ys = jax.lax.scan(
        scan_fn, state0,
        (xr.transpose(1, 0, 2, 3, 4), cum.transpose(1, 0, 2, 3),
         dtr.transpose(1, 0, 2, 3), Br.transpose(1, 0, 2, 3),
         Cr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, final


def ssd_recurrent_ref(xh, a, dt, Bm, Cm):
    """Naive per-token recurrence — oracle for the chunked form (tests)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]

    def step(state, t):
        x_t, a_t, dt_t, B_t, C_t = t
        state = (state * a_t[:, :, None, None]
                 + jnp.einsum("bhp,bn,bh->bhpn", x_t, B_t, dt_t))
        y = jnp.einsum("bhpn,bn->bhp", state, C_t)
        return state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, state0,
        (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
         a.transpose(1, 0, 2).astype(jnp.float32),
         dt.transpose(1, 0, 2).astype(jnp.float32),
         Bm.transpose(1, 0, 2).astype(jnp.float32),
         Cm.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2, 3)


def ssm_apply(cfg, p, x, *, state=None):
    """Mamba2 mixer. x (B,S,d). state: dict(ssm,conv) for decode or None.

    Returns (out (B,S,d), new_state)."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    dt_ = x.dtype

    zx = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xin = jnp.split(zx, 2, axis=-1)                    # gate, stream
    xin = shard_constraint(xin, ("batch", None, "ffn_act"))

    conv_tail = None if state is None else state["conv"]
    xin, new_tail = _causal_conv(xin, p["conv_w"], conv_tail)
    xin = jax.nn.silu(xin)

    bc = jnp.einsum("bsd,dn->bsn", x, p["w_bc"])
    Bm, Cm = jnp.split(bc, 2, axis=-1)                    # (B,S,N)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])           # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                # (B,S,H)

    xh = xin.reshape(B, S, H, P)

    if state is None:
        y, _ = _ssd_chunked(xh, a, dt, Bm, Cm, cfg.ssm_chunk)
        new_state = None
    elif S > 1:
        # chunked prefill with carried state: the training-time SSD form
        # seeded from the decode state (_ssd_chunked threads state0).
        c = common.chunk_divisor(S, cfg.ssm_chunk)
        y, s1 = _ssd_chunked(xh, a, dt, Bm, Cm, c,
                             state0=state["ssm"].astype(jnp.float32))
        new_state = {"ssm": s1.astype(state["ssm"].dtype), "conv": new_tail}
    else:
        s0 = state["ssm"].astype(jnp.float32)             # (B,H,P,N)
        s1 = (s0 * a[:, 0, :, None, None]
              + jnp.einsum("bhp,bn,bh->bhpn",
                           xh[:, 0].astype(jnp.float32),
                           Bm[:, 0].astype(jnp.float32), dt[:, 0]))
        y = jnp.einsum("bhpn,bn->bhp", s1, Cm[:, 0].astype(jnp.float32))[:, None]
        new_state = {"ssm": s1.astype(state["ssm"].dtype), "conv": new_tail}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = (y.reshape(B, S, d_in) * jax.nn.silu(z.astype(jnp.float32)))
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_), p["w_out"])
    return out, new_state
