"""Pallas TPU kernels for the cascade's compute hot spots.

Each kernel package ships:
* ``kernel.py`` — pl.pallas_call with explicit BlockSpec VMEM tiling
* ``ops.py``    — jit'd public wrapper (interpret=True on CPU)
* ``ref.py``    — pure-jnp oracle used by the allclose test sweeps
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.ssd_chunk.ops import ssd_chunk
from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul

__all__ = ["flash_attention", "rmsnorm", "ssd_chunk", "zoo_dual_matmul"]
