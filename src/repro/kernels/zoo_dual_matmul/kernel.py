"""Fused clean+perturbed client forward: y = xW and ŷ = x(W+μU) in ONE pass.

The cascade's client computes both c = F_m(w) and ĉ = F_m(w+μu) every round
(paper Alg. 1 line 4). Done naively that is two full forwards — 2× HBM
traffic on x and W(+U). This kernel reads each x/W/U tile into VMEM once
and emits both outputs: for the memory-bound embedding/projection client
models this halves the bytes moved (x read once, and ŷ's extra work is one
fused multiply-add on tiles already resident in VMEM).

Tiling: grid over (M/bm, N/bn); each program reads the full-K stripes
x (bm, K), W/U (K, bn) — for the assigned configs K = d_model ≤ 7168 so the
working set (bm·K + 2·K·bn + 2·bm·bn at bf16) stays well under VMEM, and
bm/bn are 128-multiples for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dual_matmul_kernel(x_ref, w_ref, u_ref, mu_ref, y_ref, y_hat_ref):
    x = x_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    mu = mu_ref[0]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # ŷ = xW + μ(xU): reuse the xW product already in registers
    yu = jnp.dot(x, u, preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    y_hat_ref[...] = (y + mu * yu).astype(y_hat_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def zoo_dual_matmul_pallas(x, w, u, mu, *, bm: int = 128, bn: int = 128,
                           interpret: bool = False):
    """x (M, K), w/u (K, N), mu scalar -> (y (M, N), y_hat (M, N))."""
    M, K = x.shape
    _, N = w.shape
    bm = min(bm, M)
    bn = min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    mu_arr = jnp.asarray([mu], jnp.float32)

    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _dual_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((M, N), x.dtype),
        ],
        interpret=interpret,
    )(x, w, u, mu_arr)


def _dual_matmul_stacked_kernel(x_ref, w_ref, u_ref, mu_ref,
                                y_ref, y_hat_ref, acc_ref):
    """Stacked ZOO fan-out: ŷ_l = xW + μ(xU_l) for all q lanes.

    Grid is (M/bm, N/bn, q) with the lane axis innermost, so for a fixed
    output tile the xW product is computed ONCE (lane 0), parked in a VMEM
    scratch accumulator, and re-used by every perturbation lane while the
    x/W tiles stay resident — HBM traffic on x and W is constant in q."""
    lane = pl.program_id(2)
    x = x_ref[...]

    @pl.when(lane == 0)
    def _():
        acc_ref[...] = jnp.dot(x, w_ref[...],
                               preferred_element_type=jnp.float32)
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)

    yu = jnp.dot(x, u_ref[0], preferred_element_type=jnp.float32)
    y_hat_ref[0] = (acc_ref[...] + mu_ref[0] * yu).astype(y_hat_ref.dtype)


def _dual_matmul_stacked_bias_relu_kernel(x_ref, w_ref, u_ref, b_ref,
                                          ub_ref, mu_ref, y_ref, y_hat_ref,
                                          acc_ref):
    """Stacked fan-out with the tabular client's bias+ReLU epilogue fused.

    Same lane-innermost tiling as :func:`_dual_matmul_stacked_kernel`; the
    scratch accumulator parks the RAW xW product (bias-free, so every
    perturbation lane can re-derive its own pre-activation), and each
    lane's bias add + ReLU runs on the tile while it is still resident in
    VMEM — the activated outputs go straight to HBM, so the epilogue costs
    zero extra memory traffic vs the unfused matmul alone (the unfused
    path re-reads both outputs from HBM to add bias and clamp)."""
    lane = pl.program_id(2)
    x = x_ref[...]
    b = b_ref[0]

    @pl.when(lane == 0)
    def _():
        acc_ref[...] = jnp.dot(x, w_ref[...],
                               preferred_element_type=jnp.float32)
        y_ref[...] = jnp.maximum(acc_ref[...] + b, 0.0).astype(y_ref.dtype)

    yu = jnp.dot(x, u_ref[0], preferred_element_type=jnp.float32)
    mu = mu_ref[0]
    # lane l pre-activation: x(W + μU_l) + (b + μu_b_l)
    pre = acc_ref[...] + mu * yu + (b + mu * ub_ref[0])
    y_hat_ref[0] = jnp.maximum(pre, 0.0).astype(y_hat_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def zoo_dual_matmul_stacked_bias_relu_pallas(x, w, us, b, ub, mu, *,
                                             bm: int = 128, bn: int = 128,
                                             interpret: bool = False):
    """x (M, K), w (K, N), us (q, K, N), b (N,), ub (q, N), mu scalar ->
    (y (M, N), y_hat (q, M, N)) with the epilogue fused:
    y = relu(xW + b), ŷ_l = relu(x(W + μU_l) + b + μu_b_l)."""
    M, K = x.shape
    _, N = w.shape
    q = us.shape[0]
    assert us.shape == (q, K, N), (us.shape, (q, K, N))
    assert b.shape == (N,) and ub.shape == (q, N), (b.shape, ub.shape)
    bm = min(bm, M)
    bn = min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    mu_arr = jnp.asarray([mu], jnp.float32)
    b2 = b.astype(jnp.float32)[None]                      # (1, N)
    ub2 = ub.astype(jnp.float32)                          # (q, N)

    grid = (M // bm, N // bn, q)
    return pl.pallas_call(
        _dual_matmul_stacked_bias_relu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j, l: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, K, bn), lambda i, j, l: (l, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1,), lambda i, j, l: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
            pl.BlockSpec((1, bm, bn), lambda i, j, l: (l, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((q, M, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, us, b2, ub2, mu_arr)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def zoo_dual_matmul_stacked_pallas(x, w, us, mu, *, bm: int = 128,
                                   bn: int = 128, interpret: bool = False):
    """x (M, K), w (K, N), us (q, K, N), mu scalar ->
    (y (M, N), y_hat (q, M, N)) with ŷ_l = x(W + μU_l)."""
    M, K = x.shape
    _, N = w.shape
    q = us.shape[0]
    assert us.shape == (q, K, N), (us.shape, (q, K, N))
    bm = min(bm, M)
    bn = min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    mu_arr = jnp.asarray([mu], jnp.float32)

    grid = (M // bm, N // bn, q)
    return pl.pallas_call(
        _dual_matmul_stacked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j, l: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, K, bn), lambda i, j, l: (l, 0, j)),
            pl.BlockSpec((1,), lambda i, j, l: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
            pl.BlockSpec((1, bm, bn), lambda i, j, l: (l, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((q, M, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, us, mu_arr)
