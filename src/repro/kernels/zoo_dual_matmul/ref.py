"""Pure-jnp oracle for the fused dual matmul."""
import jax.numpy as jnp


def zoo_dual_matmul_ref(x, w, u, mu):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y_hat = jnp.dot(x.astype(jnp.float32),
                    w.astype(jnp.float32) + mu * u.astype(jnp.float32))
    return y.astype(x.dtype), y_hat.astype(x.dtype)


def zoo_dual_matmul_stacked_ref(x, w, us, mu):
    """x (M,K), w (K,N), us (q,K,N) -> (y (M,N), y_hat (q,M,N))."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    yu = jnp.einsum("mk,qkn->qmn", x.astype(jnp.float32),
                    us.astype(jnp.float32))
    return y.astype(x.dtype), (y[None] + mu * yu).astype(x.dtype)


def zoo_dual_matmul_stacked_bias_relu_ref(x, w, us, b, ub, mu):
    """Unfused oracle for the bias+ReLU epilogue: y = relu(xW + b),
    ŷ_l = relu(x(W + μU_l) + b + μu_b_l)."""
    y, y_hat = zoo_dual_matmul_stacked_ref(x, w, us, mu)
    clean = jnp.maximum(y.astype(jnp.float32) + b.astype(jnp.float32), 0.0)
    pert = jnp.maximum(
        y_hat.astype(jnp.float32)
        + (b.astype(jnp.float32)[None] + mu * ub.astype(jnp.float32))[:, None, :],
        0.0)
    return clean.astype(x.dtype), pert.astype(x.dtype)
