"""Pure-jnp oracle for the fused dual matmul."""
import jax.numpy as jnp


def zoo_dual_matmul_ref(x, w, u, mu):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y_hat = jnp.dot(x.astype(jnp.float32),
                    w.astype(jnp.float32) + mu * u.astype(jnp.float32))
    return y.astype(x.dtype), y_hat.astype(x.dtype)


def zoo_dual_matmul_stacked_ref(x, w, us, mu):
    """x (M,K), w (K,N), us (q,K,N) -> (y (M,N), y_hat (q,M,N))."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    yu = jnp.einsum("mk,qkn->qmn", x.astype(jnp.float32),
                    us.astype(jnp.float32))
    return y.astype(x.dtype), (y[None] + mu * yu).astype(x.dtype)
