from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul

__all__ = ["zoo_dual_matmul"]
