from repro.kernels.zoo_dual_matmul.ops import (
    zoo_dual_matmul, zoo_dual_matmul_stacked)

__all__ = ["zoo_dual_matmul", "zoo_dual_matmul_stacked"]
