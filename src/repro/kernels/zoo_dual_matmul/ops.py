"""Public wrapper: pallas on TPU, interpret-mode pallas elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.zoo_dual_matmul.kernel import (
    zoo_dual_matmul_pallas, zoo_dual_matmul_stacked_bias_relu_pallas,
    zoo_dual_matmul_stacked_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def zoo_dual_matmul(x, w, u, mu, *, bm: int = 128, bn: int = 128):
    """y = x @ w ; y_hat = x @ (w + mu*u) — one fused pass."""
    return zoo_dual_matmul_pallas(x, w, u, mu, bm=bm, bn=bn,
                                  interpret=not _on_tpu())


def zoo_dual_matmul_stacked(x, w, us, mu, *, b=None, ub=None,
                            bm: int = 128, bn: int = 128):
    """y = x @ w ; y_hat[l] = x @ (w + mu*us[l]) for all q lanes — the xW
    product is computed once and shared across lanes.

    Passing ``b`` (N,) and ``ub`` (q, N) fuses the tabular client's
    bias+ReLU epilogue into the same pass: returns
    (relu(xW + b), relu(x(W + μU_l) + b + μu_b_l)) with the activation
    applied on tiles still resident in VMEM."""
    if (b is None) != (ub is None):
        raise ValueError("pass both b and ub for the fused epilogue, "
                         "or neither")
    if b is not None:
        return zoo_dual_matmul_stacked_bias_relu_pallas(
            x, w, us, b, ub, mu, bm=bm, bn=bn, interpret=not _on_tpu())
    return zoo_dual_matmul_stacked_pallas(x, w, us, mu, bm=bm, bn=bn,
                                          interpret=not _on_tpu())
