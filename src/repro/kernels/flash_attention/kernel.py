"""Causal (+ sliding-window) flash attention — the server backbone hotspot.

Online-softmax tiling adapted to TPU: the grid walks (batch·heads, q-blocks,
kv-blocks); the kv dimension is the *innermost* grid axis so the running
max/denominator/accumulator persist in VMEM scratch across kv steps
(TPU grids execute sequentially over the trailing axis — this replaces the
CUDA pattern of an in-kernel loop with shared-memory tiles; see DESIGN.md
hardware-adaptation notes). Block shapes are MXU-aligned (128 multiples).

Causal + window masking is applied per tile; fully-masked kv tiles are
skipped via ``pl.when`` so the causal kernel does ~half the work and a
window kernel touches only O(W) keys per query row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # tile-level skip: entirely above the diagonal / outside the window
    live = jnp.bool_(True)
    if causal:
        live = live & (k_start <= q_start + bq - 1)
    if window > 0:
        live = live & (k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q,k,v: (BH, S, d) — batch and heads pre-flattened (GQA callers
    broadcast kv heads first). Returns (BH, S, d)."""
    BH, S, d = q.shape
    Skv = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, Skv)
    assert S % bq == 0 and Skv % bk == 0
    n_kv = Skv // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            _scratch((bq, 1)),
            _scratch((bq, 1)),
            _scratch((bq, d)),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
