from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """Flash attention over (BH, S, d) tensors (heads pre-flattened)."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk,
        interpret=jax.default_backend() != "tpu")
