"""Pure-jnp oracle for flash attention (materialized scores)."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: (BH, S, d) -> (BH, S, d)."""
    BH, S, d = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
