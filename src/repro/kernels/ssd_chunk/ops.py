from __future__ import annotations

import jax

from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas


def ssd_chunk(xh, a, dt, bm, cm, *, chunk: int = 128):
    """Mamba2 SSD over (BH, S, ·) tensors (batch·heads pre-flattened;
    B/C broadcast over heads by the caller)."""
    return ssd_chunk_pallas(xh, a, dt, bm, cm, chunk=chunk,
                            interpret=jax.default_backend() != "tpu")
