"""Mamba2 SSD chunked scan — the hybrid/SSM train-time hotspot.

Per (batch·head) the recurrence  S_t = a_t·S_{t-1} + dt_t·(x_t ⊗ B_t),
y_t = S_t·C_t  is evaluated chunk-by-chunk: within a chunk the
contribution is a (c×c) masked attention-like matrix (MXU matmuls); across
chunks only the (P×N) state is carried. TPU adaptation: the chunk index is
the TRAILING grid axis (sequential on TPU), so the state lives in VMEM
scratch across grid steps — the CUDA version's cross-block shared-memory
handoff becomes a scratch-carry, and all (c,c)/(c,N)/(P,N) tiles are
MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (c, P)
    a = a_ref[0].astype(jnp.float32)          # (c, 1)
    dt = dt_ref[0].astype(jnp.float32)        # (c, 1)
    bm = b_ref[0].astype(jnp.float32)         # (c, N)
    cm = c_ref[0].astype(jnp.float32)         # (c, N)

    la = jnp.log(jnp.maximum(a, 1e-20))
    cum = jnp.cumsum(la, axis=0)              # (c, 1)

    # intra-chunk: M[i,j] = exp(cum_i - cum_j)·dt_j·(C_i·B_j), j<=i
    seg = cum - cum.T                          # (c, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = jj <= ii
    seg = jnp.where(mask, seg, 0.0)
    dec = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # (c, c)
    m = dec * cb * dt.T
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)        # (c, P)

    # inter-chunk: y += exp(cum_i)·(C_i @ S_prev^T);  S (P, N)
    state = state_scr[...]
    y = y + jnp.exp(cum) * jnp.dot(cm, state.T,
                                   preferred_element_type=jnp.float32)

    # state update: S' = a_tot·S + Σ_j exp(cum_last - cum_j)·dt_j·x_j⊗B_j
    w = jnp.exp(cum[-1:] - cum) * dt                              # (c, 1)
    ds = jnp.dot((x * w).T, bm, preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[-1]) + ds

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(xh, a, dt, bm, cm, *, chunk: int = 128,
                     interpret: bool = False):
    """xh (BH, S, P); a/dt (BH, S); bm/cm (BH, S, N) -> y (BH, S, P)."""
    BH, S, P = xh.shape
    N = bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, a[..., None], dt[..., None], bm, cm)
