from repro.kernels.ssd_chunk.ops import ssd_chunk

__all__ = ["ssd_chunk"]
