"""Pure-jnp oracle: the naive per-token SSD recurrence."""
import jax
import jax.numpy as jnp


def ssd_chunk_ref(xh, a, dt, bm, cm):
    """xh (BH,S,P); a/dt (BH,S); bm/cm (BH,S,N) -> (BH,S,P)."""
    BH, S, P = xh.shape
    N = bm.shape[-1]

    def step(state, t):
        x_t, a_t, dt_t, b_t, c_t = t
        state = (state * a_t[:, None, None]
                 + jnp.einsum("bp,bn,b->bpn", x_t, b_t, dt_t))
        return state, jnp.einsum("bpn,bn->bp", state, c_t)

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (xh.transpose(1, 0, 2).astype(jnp.float32),
         a.T.astype(jnp.float32), dt.T.astype(jnp.float32),
         bm.transpose(1, 0, 2).astype(jnp.float32),
         cm.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2).astype(xh.dtype)
