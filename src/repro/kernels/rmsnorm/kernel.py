"""RMSNorm Pallas kernel — row-tiled, fp32 accumulation in VMEM.

Every transformer block calls the norm 2-4×; at d_model 6-7k the op is
purely memory-bound, so the win is a single HBM read/write per element with
the reduction and scale fused (XLA sometimes splits the mean-square
reduction from the scale multiply into two passes).

Tiling: grid over row blocks (bm, d); d stays whole per tile (d ≤ 8192
-> bm·d·4B ≤ 4MB VMEM at bm=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm_pallas(x, scale, *, bm: int = 128, eps: float = 1e-6,
                   interpret: bool = False):
    """x (M, d), scale (d,) -> (M, d)."""
    M, d = x.shape
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, d), x.dtype),
        interpret=interpret,
    )(x, scale)
