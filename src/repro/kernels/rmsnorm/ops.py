from __future__ import annotations

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


def rmsnorm(x, scale, *, eps: float = 1e-6, bm: int = 128):
    """Fused RMSNorm over the last dim of a (M, d) array."""
    return rmsnorm_pallas(x, scale, bm=bm, eps=eps,
                          interpret=jax.default_backend() != "tpu")
