"""Asynchronous VFL engine (paper §III-C / Alg. 1) — host-level protocol
simulation with exact staleness semantics, compiled as one jitted
``lax.scan``.

Per global round t (matching Fig. 2):
  * a block of clients {m_t} is activated (schedule drawn from p_m,
    assumption IV.6; ``block_size=1`` recovers the paper's one-client
    rounds, larger blocks vmap several concurrent activations per round
    for many-client scaling studies)
  * each picks a sample batch i_t, computes c/ĉ and "uploads" them
  * the server evaluates h/ĥ against its *embedding table* — the latest
    (stale, delay τ_{i,m}) embeddings of all other clients (assumption IV.7)
  * the server does one local FOO step (ours/VAFL) or ZOO step (ZOO-VFL)
  * each activated client does one ZOO step (ours/ZOO-VFL) or FOO step
    (VAFL); concurrent clients see each other's STALE embeddings only
  * table rows (m, i_t) refresh; delay counters update per §III-C

The model plane is abstracted behind :class:`repro.core.adapters.ModelAdapter`,
so the same scan body drives arbitrary ``repro.models`` client/server
pairs — not just the paper's tabular MLP. The scan body is jitted once per
(adapter, method, vfl, block) and cached, so repeated runs (benchmark
sweeps) skip retracing.

Synchronous baselines (Split-Learning, Syn-ZOO-VFL) activate *all* clients
every round with fresh embeddings (no table staleness).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VFLConfig
from repro.core import zoo
from repro.core.adapters import ModelAdapter, tabular_adapter

SYNC_METHODS = ("split", "syn-zoo")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "cascaded"   # cascaded | vafl | zoo-vfl | split | syn-zoo
    steps: int = 1000
    batch_size: int = 64
    seed: int = 0
    # >1 activates several clients per round (drawn without replacement)
    # and runs their updates as one vmapped block
    block_size: int = 1
    # route the client's clean+perturbed fan-out through the adapter's
    # fused lanes hook (e.g. the zoo_dual_matmul Pallas kernel)
    use_lanes: bool = False


@dataclasses.dataclass
class EngineResult:
    params: dict
    losses: np.ndarray          # (T,)
    max_delay_seen: int
    mean_delay: float


def make_schedule(key, steps: int, n_clients: int,
                  probs: Optional[Tuple[float, ...]] = None,
                  block_size: int = 1):
    """Activation sequence m_t — independent draws (assumption IV.6).

    block_size > 1 draws that many DISTINCT clients per round; returns
    (steps,) for block_size == 1, else (steps, block_size)."""
    p = (jnp.ones(n_clients) / n_clients if probs is None
         else jnp.asarray(probs))
    if block_size == 1:
        return jax.random.choice(key, n_clients, (steps,), p=p)
    keys = jax.random.split(key, steps)
    return jax.vmap(
        lambda k: jax.random.choice(k, n_clients, (block_size,),
                                    replace=False, p=p))(keys)


def run(cfg_engine: EngineConfig, vfl: VFLConfig, params, x_parts, y,
        *, probs=None, adapter: Optional[ModelAdapter] = None) -> EngineResult:
    """x_parts: (M, n, f) vertically partitioned features; y: (n,) labels."""
    adapter = adapter if adapter is not None else tabular_adapter()
    M, n, f = x_parts.shape
    T, bs = cfg_engine.steps, cfg_engine.batch_size
    sync = cfg_engine.method in SYNC_METHODS
    if sync and cfg_engine.use_lanes:
        raise ValueError(
            f"use_lanes only applies to asynchronous ZOO-client methods, "
            f"not {cfg_engine.method!r} (the sync step has no per-client "
            "fan-out to route through the fused kernel)")
    if sync and cfg_engine.block_size != 1:
        raise ValueError(
            f"block_size={cfg_engine.block_size} has no meaning for the "
            f"synchronous method {cfg_engine.method!r} (every client is "
            "activated every round)")
    block = 1 if sync else cfg_engine.block_size
    key = jax.random.key(cfg_engine.seed)
    k_sched, k_idx, k_zoo = jax.random.split(key, 3)

    schedule = make_schedule(k_sched, T, M, probs, block)
    if schedule.ndim == 1:
        schedule = schedule[:, None]                     # (T, 1)
    sample_idx = jax.random.randint(k_idx, (T, bs), 0, n)
    zoo_keys = jax.random.split(k_zoo, T)

    # server-side table of latest client embeddings per sample (Fig. 2)
    table0 = jax.vmap(adapter.client_forward)(params["clients"],
                                              x_parts)   # (M, n, e)
    delays0 = jnp.zeros((M, n), jnp.int32)

    runner = _make_runner(adapter, cfg_engine.method, vfl, sync, block,
                          cfg_engine.use_lanes)
    (params, table, delays), (losses, maxd) = runner(
        params, table0, delays0, schedule, sample_idx, zoo_keys, x_parts, y)

    return EngineResult(params=params, losses=np.asarray(losses),
                        max_delay_seen=int(jnp.max(maxd)),
                        mean_delay=float(jnp.mean(delays)))


# ------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _make_runner(adapter: ModelAdapter, method: str, vfl: VFLConfig,
                 sync: bool, block: int, use_lanes: bool):
    """Build + jit the full scan for one (adapter, method, vfl, block).

    lru-cached so benchmark sweeps that re-enter ``run`` with the same
    protocol reuse the compiled executable instead of retracing."""
    step_fn = (_make_sync_step(adapter, method, vfl) if sync
               else _make_async_step(adapter, method, vfl, use_lanes))

    def scan_all(params, table0, delays0, schedule, sample_idx, zoo_keys,
                 x_parts, y):
        def body(carry, t_in):
            params, table, delays = carry
            m_blk, idx, k = t_in
            params, table, loss = step_fn(params, table, m_blk, idx, k,
                                          x_parts, y)
            # delay bookkeeping (§III-C): activated (m,i) resets, others +1
            delays = delays + 1
            if sync:
                delays = delays * 0
            else:
                delays = delays.at[m_blk[:, None], idx[None, :]].set(0)
            return (params, table, delays), (loss, jnp.max(delays))

        return jax.lax.scan(body, (params, table0, delays0),
                            (schedule, sample_idx, zoo_keys))

    return jax.jit(scan_all)


def _make_async_step(adapter: ModelAdapter, method: str, vfl: VFLConfig,
                     use_lanes: bool):
    """One asynchronous round for the activated client block {m_t}."""
    if use_lanes and adapter.client_lanes is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no client_lanes hook; "
            "run with use_lanes=False")

    def client_zoo_grad(server, c_stale, m, client_m, x_m, yb, key):
        """ZOO (ours / zoo-vfl): only losses cross the wire."""
        if use_lanes:
            # stacked fan-out through the adapter's fused dual-pass (the
            # zoo_dual_matmul Pallas kernel for the tabular client)
            u_stack, d_eff = zoo.sample_directions(
                key, client_m, vfl.zoo_queries, vfl.zoo_dist)
            phi = zoo.phi_factor(vfl.zoo_dist, d_eff)
            c_lanes = adapter.client_lanes(client_m, u_stack, vfl.mu, x_m)
            losses = jax.vmap(
                lambda cf: adapter.server_loss(server, c_stale.at[m].set(cf),
                                               yb))(c_lanes)
            return zoo.grad_from_losses(u_stack, losses[1:], losses[0],
                                        vfl.mu, phi)

        def c_loss(cm):
            cb = c_stale.at[m].set(adapter.client_forward(cm, x_m))
            return adapter.server_loss(server, cb, yb)

        g, _, _ = zoo.zoo_gradient(key, c_loss, client_m, vfl.mu,
                                   vfl.zoo_dist, vfl.zoo_queries,
                                   unrolled=vfl.zoo_unrolled_oracle)
        return g

    def client_foo_grad(server, c_stale, m, client_m, x_m, yb):
        """VAFL (privacy-leaky): server sends ∂L/∂c_m; client backprops."""
        def c_loss(cm):
            cb = c_stale.at[m].set(adapter.client_forward(cm, x_m))
            return adapter.server_loss(server, cb, yb)
        return jax.grad(c_loss)(client_m)

    def step(params, table, m_blk, idx, key, x_parts, y):
        clients, server = params["clients"], params["server"]
        yb = y[idx]
        client_blk = jax.tree.map(lambda a: a[m_blk], clients)   # (R, ...)
        x_blk = x_parts[m_blk[:, None], idx[None, :]]            # (R, bs, f)

        # stale embeddings of all clients for this batch; fresh per block
        c_stale = table[:, idx]                                  # (M, bs, e)
        c_fresh = jax.vmap(adapter.client_forward)(client_blk, x_blk)
        c_batch = c_stale.at[m_blk].set(c_fresh)

        # ---- server update (sees every activated client fresh) ----------
        if method in ("cascaded", "vafl"):
            h, g_server = jax.value_and_grad(adapter.server_loss)(
                server, jax.lax.stop_gradient(c_batch), yb)
            server = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, server, g_server)
        else:  # zoo-vfl: server trains itself with ZOO too
            def s_loss(s):
                return adapter.server_loss(s, c_batch, yb)
            g_server, h, _ = zoo.zoo_gradient(
                jax.random.fold_in(key, 1), s_loss, server, vfl.mu,
                vfl.zoo_dist, unrolled=vfl.zoo_unrolled_oracle)
            server = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, server, g_server)

        # ---- client updates (concurrent: each sees others STALE) --------
        keys = jax.random.split(jax.random.fold_in(key, 2), m_blk.shape[0])
        if method == "vafl":
            g_blk = jax.vmap(
                lambda m, cm, xm: client_foo_grad(server, c_stale, m, cm,
                                                  xm, yb)
            )(m_blk, client_blk, x_blk)
        else:
            g_blk = jax.vmap(
                lambda m, cm, xm, k: client_zoo_grad(server, c_stale, m, cm,
                                                     xm, yb, k)
            )(m_blk, client_blk, x_blk, keys)
        new_client_blk = jax.tree.map(
            lambda cm, g: cm - vfl.lr_client * g, client_blk, g_blk)
        clients = jax.tree.map(
            lambda all_, new: all_.at[m_blk].set(new), clients,
            new_client_blk)

        # refresh the table with the block's (pre-update) fresh embeddings
        table = table.at[m_blk[:, None], idx[None, :]].set(c_fresh)
        return {"clients": clients, "server": server}, table, h

    return step


def _make_sync_step(adapter: ModelAdapter, method: str, vfl: VFLConfig):
    """Synchronous rounds: Split-Learning (FOO) / Syn-ZOO-VFL."""

    def step(params, table, m_blk, idx, key, x_parts, y):
        xb = x_parts[:, idx, :]                          # (M, bs, f)
        yb = y[idx]

        if method == "split":
            h, grads = jax.value_and_grad(adapter.global_loss)(params, xb,
                                                               yb)
        else:  # syn-zoo: every party (server + each client) does ZOO
            grads, h, _ = zoo.zoo_gradient(
                key, lambda p: adapter.global_loss(p, xb, yb), params,
                vfl.mu, vfl.zoo_dist, vfl.zoo_queries,
                unrolled=vfl.zoo_unrolled_oracle)
        params = jax.tree.map(
            lambda w, g: w - vfl.lr_server * g, params, grads)
        return params, table, h

    return step
