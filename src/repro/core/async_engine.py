"""Asynchronous VFL engine (paper §III-C / Alg. 1) — host-level protocol
simulation with exact staleness semantics, compiled as one jitted
``lax.scan``.

Per global round t (matching Fig. 2):
  * a block of clients {m_t} is activated (schedule drawn from p_m,
    assumption IV.6; ``block_size=1`` recovers the paper's one-client
    rounds, larger blocks vmap several concurrent activations per round
    for many-client scaling studies)
  * each picks a sample batch i_t, computes c/ĉ and "uploads" them
  * the server evaluates h/ĥ against its *embedding table* — the latest
    (stale, delay τ_{i,m}) embeddings of all other clients (assumption IV.7)
  * the server does one local FOO step (ours/VAFL) or ZOO step (ZOO-VFL)
  * each activated client does one ZOO step (ours/ZOO-VFL) or FOO step
    (VAFL); concurrent clients see each other's STALE embeddings only
  * table rows (m, i_t) refresh; delay counters update per §III-C

The model plane is abstracted behind :class:`repro.core.adapters.ModelAdapter`,
so the same scan body drives arbitrary ``repro.models`` client/server
pairs — the paper's tabular MLP, or any LM-scale ``ModelConfig`` via
``adapters.from_model_config``. The wire plane is abstracted behind
:class:`repro.federation.Transport`, which owns the ledger, canonical
method names, and the optional DP noise hook applied to every scalar loss
crossing the downlink (``EngineResult`` then reports the spent (ε, δ)).
The scan body is jitted once per (adapter, transport, vfl, block, mesh)
and cached, so repeated runs (benchmark sweeps) skip retracing.

:func:`run` is the back-compat entry: it wraps a
``repro.federation.Federation`` session (the canonical constructor) and
is bitwise-identical to the pre-session engine at noise=0.

Device-sharded client block (``mesh=`` path)
--------------------------------------------
Passing a ``("data",)`` mesh (see :func:`repro.launch.mesh.make_client_mesh`)
shard_maps the round's client block across devices: each device hosts
``block_size / D`` of the activated clients plus ``M / D`` rows of the
embedding table (partitioned via the "clients" logical axis of
``repro.sharding.rules``). Per round, the only cross-device traffic is

  * an ``all_gather`` of the per-shard stale table slices and fresh block
    embeddings at the server-loss boundary (the wire of Fig. 2), and
  * a ``psum`` replicating the block's sparse client-parameter updates
    (activated clients are distinct, so shard contributions are disjoint
    and the sum is float-exact).

Every client's ZOO fan-out — the q× forward passes that dominate a round —
runs on its own shard with per-row RNG derived by ``fold_in`` on the
GLOBAL row index, so the sharded engine draws the exact perturbation
directions of the single-device engine: block_size=1 on a 1-shard mesh is
bitwise identical, larger blocks agree to float-reassociation.

Synchronous baselines (Split-Learning, Syn-ZOO-VFL) activate *all* clients
every round with fresh embeddings (no table staleness).

Population plane (``run_population``)
-------------------------------------
:func:`run_population` runs the SAME protocol over a real wire
(``repro.wire``): every client party lives behind a
:class:`~repro.wire.backend.WireBackend` endpoint (in-proc loopback by
default; a TCP socket puts it in another process), messages are genuinely
serialized, and the ledger meters actual frame bytes. A
:class:`~repro.wire.faults.FaultPlan` injects per-party drops/latency in
deterministic virtual time, and :class:`PopulationConfig` adds
straggler admission and bounded-staleness forcing on top of the sampled
activation schedule. With ``FaultPlan.none()`` the run is
bitwise-identical to the in-process engine; the engine's full mutable
state is an :class:`AsyncPlaneState` that checkpoints and resumes
exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import marks, tags
from repro.configs.base import VFLConfig
from repro.core import zoo
from repro.core.adapters import ModelAdapter, tabular_adapter
from repro.core.methods import SYNC_METHODS
from repro.core.privacy import Ledger, Message
from repro.sharding.rules import PARAM_RULES, resolve_spec

CLIENT_AXIS = "data"        # mesh axis the client block shards over


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "cascaded"   # any spelling in repro.core.methods
    steps: int = 1000
    batch_size: int = 64
    seed: int = 0
    # >1 activates several clients per round (drawn without replacement)
    # and runs their updates as one vmapped block
    block_size: int = 1
    # route the client's clean+perturbed fan-out through the adapter's
    # fused lanes hook (e.g. the zoo_dual_matmul Pallas kernel)
    use_lanes: bool = False
    # >0 shards the client block + table rows over that many devices
    # (Federation builds the ("data",) mesh via launch.mesh.make_client_mesh;
    # must divide both block_size and the client count)
    mesh_shards: int = 0


@dataclasses.dataclass
class EngineResult:
    params: dict
    losses: np.ndarray          # (T,)
    max_delay_seen: int
    mean_delay: float
    # wire accounting (q-aware privacy ledger owned by the Transport)
    wire_bytes: int = 0
    transmits_gradients: bool = False
    ledger: Optional[Ledger] = None
    # DP budget spent on the loss downlink ((inf, 0) without a noise
    # channel: structurally safe wire, no formal guarantee)
    epsilon: float = math.inf
    delta: float = 0.0


def make_schedule(key, steps: int, n_clients: int,
                  probs: Optional[Tuple[float, ...]] = None,
                  block_size: int = 1):
    """Activation sequence m_t — independent draws (assumption IV.6).

    block_size > 1 draws that many DISTINCT clients per round; returns
    (steps,) for block_size == 1, else (steps, block_size)."""
    p = (jnp.ones(n_clients) / n_clients if probs is None
         else jnp.asarray(probs))
    if block_size == 1:
        return jax.random.choice(key, n_clients, (steps,), p=p)
    keys = jax.random.split(key, steps)
    return jax.vmap(
        lambda k: jax.random.choice(k, n_clients, (block_size,),
                                    replace=False, p=p))(keys)


def _validate_mesh(mesh: Mesh, sync: bool, method: str, block: int, M: int):
    if sync:
        raise ValueError(
            f"mesh sharding only applies to asynchronous methods, not "
            f"{method!r} (sync rounds have no client block to shard)")
    if CLIENT_AXIS not in mesh.shape:
        raise ValueError(
            f"engine mesh needs a {CLIENT_AXIS!r} axis, got "
            f"{dict(mesh.shape)} (use repro.launch.mesh.make_client_mesh)")
    D = mesh.shape[CLIENT_AXIS]
    if block % D:
        raise ValueError(
            f"block_size={block} not divisible by the mesh "
            f"{CLIENT_AXIS!r} axis ({D} shards)")
    if M % D:
        raise ValueError(
            f"n_clients={M} not divisible by the mesh {CLIENT_AXIS!r} "
            f"axis ({D} shards): the embedding table rows cannot split")


def run(cfg_engine: EngineConfig, vfl: VFLConfig, params, x_parts, y,
        *, probs=None, adapter: Optional[ModelAdapter] = None,
        mesh: Optional[Mesh] = None) -> EngineResult:
    """Back-compat wrapper over the ``repro.federation`` session API.

    x_parts: (M, n, f) vertically partitioned features; y: (n,) labels.
    ``mesh``: optional ``("data",)`` mesh — new callers set
    ``EngineConfig.mesh_shards`` instead and let the session build it.
    Bitwise-identical to ``Federation.build(...).run(...)`` at noise=0
    (there is no noise knob here; DP runs go through the session)."""
    from repro.federation import Federation
    fed = Federation.build(
        adapter if adapter is not None else tabular_adapter(),
        vfl, cfg_engine, mesh=mesh)
    return fed.run(params, x_parts, y, probs=probs)


def _session_run(adapter: ModelAdapter, transport, vfl: VFLConfig,
                 cfg_engine: EngineConfig, params, x_parts, y,
                 *, probs=None, mesh: Optional[Mesh] = None) -> EngineResult:
    """The engine proper, driven by a ``Federation`` session.

    ``transport`` (a ``repro.federation.Transport``) supplies the
    canonical method, the wire ledger, and the downlink noise hook; the
    session supplies the adapter and the (already-built) mesh."""
    method = transport.method
    M, n, f = x_parts.shape
    T, bs = cfg_engine.steps, cfg_engine.batch_size
    sync = method in SYNC_METHODS
    if sync and cfg_engine.use_lanes:
        raise ValueError(
            f"use_lanes only applies to asynchronous ZOO-client methods, "
            f"not {method!r} (the sync step has no per-client "
            "fan-out to route through the fused kernel)")
    if sync and cfg_engine.block_size != 1:
        raise ValueError(
            f"block_size={cfg_engine.block_size} has no meaning for the "
            f"synchronous method {method!r} (every client is "
            "activated every round)")
    block = 1 if sync else cfg_engine.block_size
    if mesh is not None:
        _validate_mesh(mesh, sync, method, block, M)
    key = jax.random.key(cfg_engine.seed)
    k_sched, k_idx, k_zoo = jax.random.split(key, 3)

    schedule = make_schedule(k_sched, T, M, probs, block)
    if schedule.ndim == 1:
        schedule = schedule[:, None]                     # (T, 1)
    sample_idx = jax.random.randint(k_idx, (T, bs), 0, n)
    zoo_keys = jax.random.split(k_zoo, T)

    # server-side table of latest client embeddings per sample (Fig. 2)
    table0 = jax.vmap(adapter.client_forward)(params["clients"],
                                              x_parts)   # (M, n, e)
    delays0 = jnp.zeros((M, n), jnp.int32)
    table_spec = None
    if mesh is not None:
        # partition the table rows via the "clients" logical axis rule
        table_spec = resolve_spec(mesh, table0.shape, adapter.table_logical,
                                  PARAM_RULES)
        table0 = jax.device_put(table0, NamedSharding(mesh, table_spec))

    runner = _make_runner(adapter, transport, vfl, sync, block,
                          cfg_engine.use_lanes, mesh, table_spec)
    (params, table, delays), (losses, maxd) = runner(
        params, table0, delays0, schedule, sample_idx, zoo_keys, x_parts, y)

    # the Transport owns the q-gating (queries only fan out on ZOO wires)
    ledger = transport.account(batch=bs, embed=int(table0.shape[-1]),
                               zoo_queries=vfl.zoo_queries,
                               n_clients=M if sync else block, n_rounds=T)
    eps, delta = transport.privacy_spent(transport.releases(
        n_rounds=T, n_clients=M if sync else block,
        zoo_queries=vfl.zoo_queries))

    return EngineResult(params=params, losses=np.asarray(losses),
                        max_delay_seen=int(jnp.max(maxd)),
                        mean_delay=float(jnp.mean(delays)),
                        wire_bytes=ledger.total_bytes,
                        transmits_gradients=ledger.transmits_gradients,
                        ledger=ledger, epsilon=eps, delta=delta)


# ------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _make_runner(adapter: ModelAdapter, transport, vfl: VFLConfig,
                 sync: bool, block: int, use_lanes: bool,
                 mesh: Optional[Mesh] = None, table_spec: Optional[P] = None):
    """Build + jit the full scan for one (adapter, transport, vfl, block,
    mesh).

    lru-cached so benchmark sweeps that re-enter ``run`` with the same
    protocol reuse the compiled executable instead of retracing (the
    Transport is a frozen value object, so a noise-channel change is a
    cache miss and a no-noise Transport hashes like any other key)."""
    if sync:
        step_fn = _make_sync_step(adapter, transport, vfl)
    elif mesh is not None:
        step_fn = _make_sharded_step(adapter, transport, vfl, use_lanes,
                                     mesh, block, table_spec)
    else:
        step_fn = _make_async_step(adapter, transport, vfl, use_lanes)

    def scan_all(params, table0, delays0, schedule, sample_idx, zoo_keys,
                 x_parts, y):
        def body(carry, t_in):
            params, table, delays = carry
            m_blk, idx, k = t_in
            params, table, loss = step_fn(params, table, m_blk, idx, k,
                                          x_parts, y)
            # delay bookkeeping (§III-C): activated (m,i) resets, others +1
            delays = delays + 1
            if sync:
                delays = delays * 0
            else:
                delays = delays.at[m_blk[:, None], idx[None, :]].set(0)
            return (params, table, delays), (loss, jnp.max(delays))

        return jax.lax.scan(body, (params, table0, delays0),
                            (schedule, sample_idx, zoo_keys))

    return jax.jit(scan_all)


def _row_keys(key, rows):
    """Per-client-row RNG: fold the round key on the GLOBAL row index, so
    a block row draws the same directions no matter which device shard it
    lands on (single-device and sharded engines agree bitwise)."""
    k = jax.random.fold_in(key, 2)
    return jax.vmap(lambda r: jax.random.fold_in(k, r))(rows)


def _make_client_grad_fns(adapter: ModelAdapter, transport,
                          vfl: VFLConfig, use_lanes: bool):
    """Per-activated-client gradient closures shared by the single-device
    and sharded async steps (both vmap them over their block rows).

    Every scalar loss the client consumes passes through
    ``transport.downlink`` — the identity for a bare wire (same jaxpr as
    the pre-Transport engine), clip+noise under a DP channel. Adapters
    with a ``row_mask`` hook (active-row embedding clients) restrict the
    ZOO perturbation to the rows the batch touches."""
    if use_lanes and adapter.client_lanes is None:
        raise ValueError(
            f"adapter {adapter.name!r} has no client_lanes hook; "
            "run with use_lanes=False")
    if transport.noise is not None and vfl.zoo_unrolled_oracle:
        raise ValueError(
            "the DP loss channel requires the stacked lane path "
            "(vfl.zoo_unrolled_oracle=False); the unrolled per-query loop "
            "is a noise-free numerical test oracle")

    def _row_mask(client_m, x_m):
        return (adapter.row_mask(client_m, x_m)
                if adapter.row_mask is not None else None)

    @tags.wire("up", accounted_by="Transport.account", kind="embedding",
               reason="ZOO uplink: clean + q perturbed embeddings; the "
                      "loss downlink is sanitized via transport.downlink")
    def client_zoo_grad(server, c_stale, m, client_m, x_m, yb, key):
        """ZOO (ours / zoo-vfl): only losses cross the wire."""
        mask = _row_mask(client_m, x_m)
        if use_lanes:
            # stacked fan-out through the adapter's fused dual-pass (the
            # zoo_dual_matmul Pallas kernel for the tabular client)
            u_stack, d_eff = zoo.sample_directions(
                key, client_m, vfl.zoo_queries, vfl.zoo_dist, mask)
            phi = zoo.phi_factor(vfl.zoo_dist, d_eff)
            c_lanes = marks.wire_boundary(
                adapter.client_lanes(client_m, u_stack, vfl.mu, x_m),
                kind="emb", direction="up")
            losses = jax.vmap(
                lambda cf: adapter.server_loss(server, c_stale.at[m].set(cf),
                                               yb))(c_lanes)
            losses = transport.downlink(losses, key)
            return zoo.grad_from_losses(u_stack, losses[1:], losses[0],
                                        vfl.mu, phi)

        def c_loss(cm):
            cf = marks.wire_boundary(adapter.client_forward(cm, x_m),
                                     kind="emb", direction="up")
            cb = c_stale.at[m].set(cf)
            return adapter.server_loss(server, cb, yb)

        if transport.noise is None:
            # the downlink is identity on a bare wire; routing the stacked
            # losses through it anyway anchors the (1+q,) bottleneck in
            # the jaxpr (the unrolled oracle stays unmarked by design)
            g, _, _ = zoo.zoo_gradient(key, c_loss, client_m, vfl.mu,
                                       vfl.zoo_dist, vfl.zoo_queries,
                                       row_mask=mask,
                                       unrolled=vfl.zoo_unrolled_oracle,
                                       loss_transform=(
                                           None if vfl.zoo_unrolled_oracle
                                           else lambda losses:
                                           transport.downlink(losses, key)))
            return g
        # noised wire: evaluate the (1+q) lanes explicitly so the noise
        # lands on the transmitted losses, not inside the oracle (same
        # direction draws as zoo_gradient's stacked path at a fixed key)
        u_stack, d_eff = zoo.sample_directions(
            key, client_m, vfl.zoo_queries, vfl.zoo_dist, mask)
        phi = zoo.phi_factor(vfl.zoo_dist, d_eff)
        lanes = zoo.stack_lanes(client_m, u_stack, vfl.mu)
        losses = jax.vmap(c_loss)(lanes)
        losses = transport.downlink(losses, key)
        return zoo.grad_from_losses(u_stack, losses[1:], losses[0],
                                    vfl.mu, phi)

    @tags.wire("up", accounted_by="Transport.account", kind="embedding",
               reason="FOO uplink: one clean embedding per round")
    @tags.wire("down", accounted_by="Transport.account",
               kind="partial_derivative",
               reason="VAFL baseline is DECLARED leaky: the server returns "
                      "dL/dc_m and the ledger reports "
                      "transmits_gradients=True for it (paper §V contrast)")
    def client_foo_grad(server, c_stale, m, client_m, x_m, yb):
        """VAFL (privacy-leaky): server sends ∂L/∂c_m; client backprops."""
        def c_loss(cm):
            cb = c_stale.at[m].set(adapter.client_forward(cm, x_m))
            return adapter.server_loss(server, cb, yb)
        # grad_mark: these ARE first-order cotangents crossing client-ward;
        # certifying vafl must fail IF301 (the negative control)
        return marks.grad_mark(jax.grad(c_loss)(client_m))

    return client_zoo_grad, client_foo_grad


def _server_update(adapter: ModelAdapter, method: str, vfl: VFLConfig,
                   server, c_batch, yb, key):
    """One server step on the round's (stale + fresh-block) embeddings.

    Returns (new_server, h). FOO methods backprop locally (Eq. 4);
    zoo-vfl estimates with the same q-point two-point oracle the client
    uses (vfl.zoo_queries — the server is a ZOO party too)."""
    if method in ("cascaded", "vafl"):
        h, g_server = jax.value_and_grad(adapter.server_loss)(
            server, jax.lax.stop_gradient(c_batch), yb)
        # the engine's one sanctioned server-FOO point: mark the
        # cotangents so the certifier (IF301) can prove nothing derived
        # from them reaches a client-bound output except through the
        # scalar-loss bottleneck
        g_server = marks.grad_mark(g_server)
    else:  # zoo-vfl: server trains itself with ZOO too
        def s_loss(s):
            return adapter.server_loss(s, c_batch, yb)
        g_server, h, _ = zoo.zoo_gradient(
            jax.random.fold_in(key, 1), s_loss, server, vfl.mu,
            vfl.zoo_dist, vfl.zoo_queries,
            unrolled=vfl.zoo_unrolled_oracle)
    server = jax.tree.map(
        lambda w, g: (w - vfl.lr_server * g).astype(w.dtype), server,
        g_server)
    return server, h


def _make_async_step(adapter: ModelAdapter, transport, vfl: VFLConfig,
                     use_lanes: bool):
    """One asynchronous round for the activated client block {m_t}."""
    method = transport.method
    client_zoo_grad, client_foo_grad = _make_client_grad_fns(
        adapter, transport, vfl, use_lanes)

    def step(params, table, m_blk, idx, key, x_parts, y):
        clients, server = params["clients"], params["server"]
        yb = y[idx]
        client_blk = jax.tree.map(lambda a: a[m_blk], clients)   # (R, ...)
        x_blk = x_parts[m_blk[:, None], idx[None, :]]            # (R, bs, f)

        # stale embeddings of all clients for this batch; fresh per block
        c_stale = table[:, idx]                                  # (M, bs, e)
        c_fresh = jax.vmap(adapter.client_forward)(client_blk, x_blk)
        c_batch = c_stale.at[m_blk].set(c_fresh)

        # ---- server update (sees every activated client fresh) ----------
        server, h = _server_update(adapter, method, vfl, server, c_batch,
                                   yb, key)

        # ---- client updates (concurrent: each sees others STALE) --------
        keys = _row_keys(key, jnp.arange(m_blk.shape[0]))
        if method == "vafl":
            g_blk = jax.vmap(
                lambda m, cm, xm: client_foo_grad(server, c_stale, m, cm,
                                                  xm, yb)
            )(m_blk, client_blk, x_blk)
        else:
            g_blk = jax.vmap(
                lambda m, cm, xm, k: client_zoo_grad(server, c_stale, m, cm,
                                                     xm, yb, k)
            )(m_blk, client_blk, x_blk, keys)
        new_client_blk = jax.tree.map(
            lambda cm, g: (cm - vfl.lr_client * g).astype(cm.dtype),
            client_blk, g_blk)
        clients = jax.tree.map(
            lambda all_, new: all_.at[m_blk].set(new), clients,
            new_client_blk)

        # refresh the table with the block's (pre-update) fresh embeddings
        table = table.at[m_blk[:, None], idx[None, :]].set(c_fresh)
        return {"clients": clients, "server": server}, table, h

    return step


def _make_sharded_step(adapter: ModelAdapter, transport, vfl: VFLConfig,
                       use_lanes: bool, mesh: Mesh, block: int,
                       table_spec: P):
    """Device-sharded asynchronous round: the block's R activated clients
    split R/D per device, the (M, n, e) table splits M/D rows per device,
    and cross-device traffic happens only at the server-loss boundary
    (all_gather) plus one float-exact psum replicating the sparse client
    updates. See module docstring for the equivalence guarantees."""
    method = transport.method
    client_zoo_grad, client_foo_grad = _make_client_grad_fns(
        adapter, transport, vfl, use_lanes)
    D = mesh.shape[CLIENT_AXIS]
    rows_local = block // D

    def shard_body(clients, server, table_l, m_blk_l, idx, key, x_parts, y):
        shard = jax.lax.axis_index(CLIENT_AXIS)
        rows_table = table_l.shape[0]                    # M / D
        yb = y[idx]
        # local block rows gather from the REPLICATED client param stack
        client_blk = jax.tree.map(lambda a: a[m_blk_l], clients)
        x_blk = x_parts[m_blk_l[:, None], idx[None, :]]  # (R/D, bs, f)

        # ---- server-loss boundary: the only gather of the round ---------
        # each shard contributes its table rows' stale embeddings and its
        # block rows' fresh embeddings; shard order == global row order
        c_stale = jax.lax.all_gather(table_l[:, idx], CLIENT_AXIS,
                                     axis=0, tiled=True)          # (M, bs, e)
        c_fresh = jax.vmap(adapter.client_forward)(client_blk, x_blk)
        c_fresh_all = jax.lax.all_gather(c_fresh, CLIENT_AXIS,
                                         axis=0, tiled=True)      # (R, bs, e)
        m_all = jax.lax.all_gather(m_blk_l, CLIENT_AXIS,
                                   axis=0, tiled=True)            # (R,)
        c_batch = c_stale.at[m_all].set(c_fresh_all)

        # ---- server update: replicated compute, identical per shard -----
        # (tiny vs the q× client fan-outs, which stay fully sharded — the
        # FOO step overlaps the other shards' fan-outs instead of
        # serializing a parameter broadcast behind them)
        server, h = _server_update(adapter, method, vfl, server, c_batch,
                                   yb, key)

        # ---- client updates: each shard fans out ONLY its block rows ----
        keys = _row_keys(key, shard * rows_local + jnp.arange(rows_local))
        if method == "vafl":
            g_blk = jax.vmap(
                lambda m, cm, xm: client_foo_grad(server, c_stale, m, cm,
                                                  xm, yb)
            )(m_blk_l, client_blk, x_blk)
        else:
            g_blk = jax.vmap(
                lambda m, cm, xm, k: client_zoo_grad(server, c_stale, m, cm,
                                                     xm, yb, k)
            )(m_blk_l, client_blk, x_blk, keys)
        new_client_blk = jax.tree.map(
            lambda cm, g: (cm - vfl.lr_client * g).astype(cm.dtype),
            client_blk, g_blk)

        # replicate the sparse update: activated clients are DISTINCT, so
        # each global row is written by exactly one shard and the psum of
        # one value plus zeros is float-exact (bitwise == .at[].set)
        mask = jax.lax.psum(
            jnp.zeros((_stack_rows(clients),), jnp.float32)
            .at[m_blk_l].set(1.0), CLIENT_AXIS)

        def replicate_rows(all_, new):
            buf = jax.lax.psum(
                jnp.zeros_like(all_).at[m_blk_l].set(new), CLIENT_AXIS)
            m = mask.reshape((-1,) + (1,) * (all_.ndim - 1))
            return jnp.where(m > 0, buf, all_)

        clients = jax.tree.map(replicate_rows, clients, new_client_blk)

        # ---- local table refresh: keep only rows this shard owns --------
        # (out-of-range scatter indices are dropped by JAX's default mode)
        local_m = m_all - shard * rows_table
        safe_m = jnp.where((local_m >= 0) & (local_m < rows_table),
                           local_m, rows_table)
        table_l = table_l.at[safe_m[:, None], idx[None, :]].set(c_fresh_all)
        return clients, server, table_l, h

    sharded = shard_map(
        shard_body, mesh,
        in_specs=(P(), P(), table_spec, P(CLIENT_AXIS), P(), P(), P(), P()),
        out_specs=(P(), P(), table_spec, P()),
        check_rep=False)

    def step(params, table, m_blk, idx, key, x_parts, y):
        clients, server, table, h = sharded(
            params["clients"], params["server"], table, m_blk, idx, key,
            x_parts, y)
        return {"clients": clients, "server": server}, table, h

    return step


def _stack_rows(clients) -> int:
    """Leading (M) axis of the stacked client parameter pytree."""
    return jax.tree.leaves(clients)[0].shape[0]


def _make_sync_step(adapter: ModelAdapter, transport, vfl: VFLConfig):
    """Synchronous rounds: Split-Learning (FOO) / Syn-ZOO-VFL."""
    method = transport.method

    def step(params, table, m_blk, idx, key, x_parts, y):
        xb = x_parts[:, idx, :]                          # (M, bs, f)
        yb = y[idx]

        if method == "split":
            h, grads = jax.value_and_grad(adapter.global_loss)(params, xb,
                                                               yb)
            # Split-Learning backprops THROUGH the boundary: its client
            # grads are cotangents (declared leaky; certifying it must
            # fail IF301 — the FOO negative control)
            grads = marks.grad_mark(grads)
        else:  # syn-zoo: every party (server + each client) does ZOO
            # the shared global draw's (1+q,) losses are what every party
            # consumes — route them through the downlink so the sync
            # simulation carries the same jaxpr bottleneck anchor as the
            # async methods (identity: sync methods reject noise)
            grads, h, _ = zoo.zoo_gradient(
                key, lambda p: adapter.global_loss(p, xb, yb), params,
                vfl.mu, vfl.zoo_dist, vfl.zoo_queries,
                unrolled=vfl.zoo_unrolled_oracle,
                loss_transform=(None if vfl.zoo_unrolled_oracle
                                else lambda losses:
                                transport.downlink(losses, key)))
        params = jax.tree.map(
            lambda w, g: (w - vfl.lr_server * g).astype(w.dtype), params,
            grads)
        return params, table, h

    return step


# ===================================================== population plane ====

@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """Population-scale knobs on top of the sampled activation schedule.

    ``admission_ms``: a delivered uplink slower than this virtual budget
    is a straggler — the round proceeds without that client (its stale
    table row serves instead; it retries at its next activation).
    ``staleness_bound``: a registered client whose table rows are older
    than this many rounds is force-activated, replacing sampled block
    members from the end (VAFL's bounded-delay assumption, enforced by
    admission instead of assumed)."""
    admission_ms: Optional[float] = None
    staleness_bound: Optional[int] = None


@dataclasses.dataclass
class AsyncPlaneState:
    """The async engine's FULL mutable state between rounds — everything
    a checkpoint must carry for a killed run to resume bitwise: the
    embedding table, the delay counters, the per-client activity clock
    for bounded-staleness forcing, the virtual wall clock, and the fault
    counters. The RNG needs no state: every stream (schedule, batches,
    directions, noise, faults) is a pure function of (seed, round)."""
    step: int
    table: np.ndarray
    delays: np.ndarray
    last_active: np.ndarray
    clock_ms: float = 0.0
    max_delay_seen: int = 0
    counters: dict = dataclasses.field(default_factory=dict)
    seed: int = 0

    def save(self, path: str) -> None:
        from repro.checkpoint.io import save_checkpoint
        save_checkpoint(path, {"table": np.asarray(self.table),
                               "delays": np.asarray(self.delays),
                               "last_active": np.asarray(self.last_active)},
                        step=self.step,
                        metadata={"clock_ms": float(self.clock_ms),
                                  "max_delay_seen": int(self.max_delay_seen),
                                  "counters": dict(self.counters),
                                  "seed": int(self.seed)})

    @classmethod
    def load(cls, path: str) -> "AsyncPlaneState":
        from repro.checkpoint.io import load_tree
        tree, step, meta = load_tree(path)
        return cls(step=int(step),
                   table=np.asarray(tree["table"]),
                   delays=np.asarray(tree["delays"]),
                   last_active=np.asarray(tree["last_active"]),
                   clock_ms=float(meta["clock_ms"]),
                   max_delay_seen=int(meta["max_delay_seen"]),
                   counters=dict(meta["counters"]),
                   seed=int(meta["seed"]))


@dataclasses.dataclass
class PopulationResult(EngineResult):
    """:class:`EngineResult` plus the wire plane's measurements."""
    state: Optional[AsyncPlaneState] = None
    serialized_bytes: int = 0      # measured frame bytes (§V data plane)
    overhead_bytes: int = 0        # serialization overhead over payloads
    control_bytes: int = 0         # act/skip/collect/params frames
    dp_releases: int = 0
    stats: dict = dataclasses.field(default_factory=dict)


@functools.lru_cache(maxsize=64)
def _population_fns(adapter: ModelAdapter, transport, vfl: VFLConfig):
    """Jitted server-side compute for the population engine, cached per
    protocol (the worker side lives in ``repro.wire.worker``). The math
    is the legacy scan body's, split at the wire: the server consumes
    UPLOADED embedding lanes instead of running ``client_forward``."""
    method = transport.method

    def server_update(server, c_stale, c_fresh, m_adm, yb, key):
        c_batch = c_stale.at[m_adm].set(c_fresh)
        return _server_update(adapter, method, vfl, server, c_batch, yb,
                              key)

    def losses_fn(server, c_stale, m, emb_lanes, yb, key):
        # the lanes arrived as "emb" wire frames — anchor the uplink
        emb_lanes = marks.wire_boundary(emb_lanes, kind="emb",
                                        direction="up")
        losses = jax.vmap(
            lambda cf: adapter.server_loss(server, c_stale.at[m].set(cf),
                                           yb))(emb_lanes)
        return transport.downlink(losses, key)

    return jax.jit(server_update), jax.jit(losses_fn)


def _fresh_counters() -> dict:
    return {"rounds": 0, "activations": 0, "admitted": 0,
            "uplink_drops": 0, "stragglers": 0, "downlink_drops": 0,
            "forced": 0, "degraded_rounds": 0, "retransmit_frames": 0,
            "dead_parties": 0}


def run_population(adapter: ModelAdapter, transport, vfl: VFLConfig,
                   cfg_engine: EngineConfig, params, x_parts, y, *,
                   probs=None, fault_plan=None,
                   population: Optional[PopulationConfig] = None,
                   channels: Optional[dict] = None,
                   state: Optional[AsyncPlaneState] = None,
                   ledger: Optional[Ledger] = None, dp_releases: int = 0,
                   until: Optional[int] = None,
                   stop_workers: bool = True,
                   wire_timeout_s: Optional[float] = None
                   ) -> PopulationResult:
    """The asynchronous protocol over a REAL wire with fault injection.

    Every registered client (M = ``x_parts.shape[0]``) sits behind a
    ``repro.wire`` endpoint — in-proc :class:`LoopbackBackend` workers by
    default; pass ``channels={m: backend}`` to place party m behind an
    already-connected endpoint (e.g. a :class:`SocketBackend` whose
    worker process runs ``ClientWorker.serve``). Per round the sampled
    block is activated over the wire (act -> 1+q embedding frames up ->
    1+q loss frames down), the ledger meters each frame's ACTUAL
    serialized bytes (``Message.wired``; payload formula kept as the
    cross-check), and ``fault_plan`` decides drops/latency/retries in
    deterministic virtual time. Graceful degradation: a dropped or
    straggling client simply misses the round (its stale embeddings
    serve; the server still steps), so a 20% dropout rate slows
    convergence instead of hanging the round.

    ``state``/``until`` make the plane durable: ``until=k`` stops after
    round k and returns the full :class:`AsyncPlaneState`; passing that
    state back (with the SAME configs/seed and the collected params)
    continues bitwise — both halves replay the identical schedule, RNG
    and fault streams. ``ledger``/``dp_releases`` extend a restored
    run's accounting the same way.

    With ``FaultPlan.none()`` and no population knobs the result is
    bitwise-identical to :func:`run` (losses, params, table, delays).

    CRASH SEMANTICS for remote (``channels``-placed) parties: a party
    whose wire dies mid-round — the process was ``kill -9``'d, the frame
    stream corrupted, or ``wire_timeout_s`` elapsed without a frame — is
    DECLARED DEAD after the backend's own retry budget (a
    ``SocketBackend`` connected with ``self_heal=True`` reconnects with
    backoff underneath first). A dead party then degrades gracefully
    exactly like a permanent dropout: it misses every later activation
    (its stale embeddings keep serving), the round never hangs, and at
    collect time its parameter row falls back to the initial params the
    engine holds. ``counters["dead_parties"]`` reports the toll; a
    replacement process can rejoin a LATER run via
    ``ClientWorker.from_checkpoint``. Loopback parties never take this
    path — their failures are real bugs and stay fail-fast.
    """
    from repro.wire import codec
    from repro.wire.backend import (LoopbackBackend, WireClosed,
                                    WireTimeout)
    from repro.wire.codec import FrameCorruption
    from repro.wire.faults import FaultPlan
    from repro.wire.worker import ClientWorker

    method = transport.method
    if method in SYNC_METHODS or method == "vafl":
        raise ValueError(
            f"run_population drives the asynchronous ZOO wire; {method!r} "
            "is synchronous or sends gradients down (use run())")
    if cfg_engine.use_lanes:
        raise ValueError(
            "use_lanes routes the fan-out through a fused server-side "
            "kernel; the wire worker computes its own lanes")
    if cfg_engine.mesh_shards:
        raise ValueError("the population engine shards by PROCESS, not by "
                         "device mesh; set mesh_shards=0")
    if vfl.zoo_unrolled_oracle:
        raise ValueError("the wire protocol speaks the stacked lane path; "
                         "zoo_unrolled_oracle is the in-process test oracle")

    plan = fault_plan if fault_plan is not None else FaultPlan.none()
    pop = population if population is not None else PopulationConfig()
    M, n, _ = x_parts.shape
    T, bs = cfg_engine.steps, cfg_engine.batch_size
    block = cfg_engine.block_size
    q = vfl.zoo_queries

    key = jax.random.key(cfg_engine.seed)
    k_sched, k_idx, k_zoo = jax.random.split(key, 3)
    schedule = make_schedule(k_sched, T, M, probs, block)
    if schedule.ndim == 1:
        schedule = schedule[:, None]
    schedule_h = np.asarray(schedule)                     # (T, block)
    idx_h = np.asarray(jax.random.randint(k_idx, (T, bs), 0, n))
    zoo_keys = jax.random.split(k_zoo, T)

    server = params["server"]
    if state is None:
        table = jax.vmap(adapter.client_forward)(params["clients"],
                                                 x_parts)  # (M, n, e)
        delays = np.zeros((M, n), np.int32)
        last_active = np.zeros((M,), np.int32)
        clock_ms, maxd, start = 0.0, 0, 0
        counters = _fresh_counters()
    else:
        if state.seed != cfg_engine.seed:
            raise ValueError(
                f"resume state was produced under seed {state.seed}, "
                f"engine runs seed {cfg_engine.seed} — the schedule/RNG "
                "streams would diverge from the saved run")
        table = jnp.asarray(state.table)
        delays = np.array(state.delays, np.int32)
        last_active = np.array(state.last_active, np.int32)
        clock_ms, maxd = float(state.clock_ms), int(state.max_delay_seen)
        counters = {**_fresh_counters(), **state.counters}
        start = int(state.step)
    stop_at = T if until is None else min(int(until), T)
    if not start <= stop_at:
        raise ValueError(f"resume step {start} is past until={stop_at}")
    ledger = ledger if ledger is not None else Ledger()
    control_bytes = int(counters.pop("control_bytes", 0))
    noise_on = transport.noise is not None

    # ---- wire up the population: loopback workers for unplaced parties --
    channels = dict(channels or {})
    remote = frozenset(channels)    # parties that can actually die
    dead: set = set()
    local_workers: dict = {}
    for m in range(M):
        if m not in channels:
            eng_end, wk_end = LoopbackBackend.pair()
            local_workers[m] = ClientWorker(
                adapter, vfl,
                jax.tree.map(lambda a: a[m], params["clients"]),
                x_parts[m], m, wk_end)
            channels[m] = eng_end

    # failures a dying REMOTE party can surface through its channel;
    # anything else (protocol bugs, engine errors) stays fail-fast
    _WIRE_DEATH = (WireClosed, WireTimeout, FrameCorruption,
                   ConnectionError, OSError)

    def _mark_dead(m):
        dead.add(m)
        counters["dead_parties"] += 1

    def _pump(m):
        if m in local_workers:
            local_workers[m].pump()

    def _send_control(m, msg):
        nonlocal control_bytes
        control_bytes += channels[m].send(msg)
        _pump(m)

    def _recv(m):
        if m in remote and wire_timeout_s is not None:
            return channels[m].recv(timeout=wire_timeout_s)
        return channels[m].recv()

    server_update, losses_fn = _population_fns(adapter, transport, vfl)
    losses_out = []

    for t in range(start, stop_at):
        m_blk = [int(m) for m in schedule_h[t]]
        idx = idx_h[t]
        kt = zoo_keys[t]
        counters["rounds"] += 1

        # ---- bounded-staleness forcing: overdue clients preempt the ----
        # ---- sampled block (most-stale first, replacing from the end) --
        if pop.staleness_bound is not None:
            in_blk = set(m_blk)
            overdue = sorted(
                ((t - int(last_active[m]), m) for m in range(M)
                 if m not in in_blk
                 and t - int(last_active[m]) > pop.staleness_bound),
                key=lambda sm: (-sm[0], sm[1]))
            for i, (_, m) in enumerate(overdue[:len(m_blk)]):
                m_blk[len(m_blk) - 1 - i] = m
            counters["forced"] += min(len(overdue), len(m_blk))

        keys_r = _row_keys(kt, jnp.arange(len(m_blk)))

        # ---- phase 1: activate the block, collect uplinked lanes --------
        admitted = []               # (r, m, emb_lanes host arrays)
        emb_meter: list = [[] for _ in m_blk]   # (Message, copies)
        loss_meter: list = [[] for _ in m_blk]
        round_ms = 0.0
        for r, m in enumerate(m_blk):
            counters["activations"] += 1
            if m in dead:
                # declared dropout: the party misses the round outright —
                # no frames, no metering, stale embeddings keep serving
                counters["uplink_drops"] += 1
                continue
            kd = np.asarray(jax.random.key_data(keys_r[r]))
            lanes = []
            try:
                _send_control(m, codec.WireMessage(
                    "act", "server", t, {"party": m},
                    {"idx": idx, "key": kd}))
                for _ in range(1 + q):
                    msg, nb = _recv(m)
                    if msg.tag != "emb":  # pragma: no cover - protocol
                        raise ValueError(
                            f"expected emb frame, got {msg.tag!r}")
                    arr = msg.payload["c"]
                    lanes.append(arr)
                    up = plan.delivery(t, m, "up")
                    emb_meter[r].append((Message(
                        "client", "embedding", tuple(arr.shape),
                        str(arr.dtype), wired=nb), up.attempts))
            except _WIRE_DEATH:
                if m not in remote:
                    raise       # loopback failures are bugs, not churn
                _mark_dead(m)
                counters["uplink_drops"] += 1
                emb_meter[r] = []   # nothing usable arrived — meter none
                continue
            counters["retransmit_frames"] += (up.attempts - 1) * (1 + q)
            client_ms = up.elapsed_ms
            if not up.ok:
                counters["uplink_drops"] += 1
                _send_control(m, codec.WireMessage(
                    "skip", "server", t, {"reason": "drop"}))
            elif (pop.admission_ms is not None
                  and up.elapsed_ms > pop.admission_ms):
                counters["stragglers"] += 1
                _send_control(m, codec.WireMessage(
                    "skip", "server", t, {"reason": "straggler"}))
            else:
                admitted.append((r, m, lanes))
            round_ms = max(round_ms, client_ms)

        # ---- phase 2: server step on stale table + admitted fresh -------
        c_stale = table[:, idx]
        e = int(table.shape[-1])
        if admitted:
            m_adm = jnp.asarray([m for _, m, _ in admitted], jnp.int32)
            c_fresh = jnp.stack([jnp.asarray(l[0]) for _, _, l in admitted])
        else:
            counters["degraded_rounds"] += 1
            m_adm = jnp.zeros((0,), jnp.int32)
            c_fresh = jnp.zeros((0, bs, e), table.dtype)
        server, h = server_update(server, c_stale, c_fresh, m_adm,
                                  y[idx], kt)
        losses_out.append(np.asarray(h))

        # ---- phase 3: loss downlinks to admitted clients ----------------
        for r, m, lanes in admitted:
            emb_lanes = jnp.stack([jnp.asarray(a) for a in lanes])
            losses = losses_fn(server, c_stale, m, emb_lanes, y[idx],
                               keys_r[r])
            down = plan.delivery(t, m, "down")
            losses_h = np.asarray(losses)
            try:
                for lane in range(1 + q):
                    nb = channels[m].send(codec.WireMessage(
                        "loss", "server", t,
                        {"lane": lane, "delivered": bool(down.ok)},
                        {"h": losses_h[lane]}))
                    loss_meter[r].append((Message(
                        "server", "loss", (), str(losses_h.dtype),
                        wired=nb), down.attempts))
            except _WIRE_DEATH:
                # died between uplink and downlink: the server already
                # consumed its fresh embeddings (that's fine — they were
                # real), the client just never gets this round's losses
                if m not in remote:
                    raise
                _mark_dead(m)
                counters["downlink_drops"] += 1
                continue
            _pump(m)
            counters["retransmit_frames"] += (down.attempts - 1) * (1 + q)
            if noise_on:
                dp_releases += 1 + q
            if not down.ok:
                counters["downlink_drops"] += 1
            round_ms = max(round_ms, plan.delivery(t, m, "up").elapsed_ms
                           + down.elapsed_ms)

        # ---- ledger: per client in block order, uplinks then downlinks --
        # (matches the legacy per-client round_messages grouping)
        for r in range(len(m_blk)):
            for msg_rec, copies in emb_meter[r] + loss_meter[r]:
                transport.account_wire(msg_rec, copies=copies,
                                       ledger=ledger)
        counters["admitted"] += len(admitted)

        # ---- phase 4: table/delay/clock bookkeeping ---------------------
        delays += 1
        if admitted:
            adm_rows = np.asarray([m for _, m, _ in admitted])
            table = table.at[jnp.asarray(adm_rows)[:, None],
                             jnp.asarray(idx)[None, :]].set(c_fresh)
            delays[adm_rows[:, None], idx[None, :]] = 0
            last_active[adm_rows] = t
        maxd = max(maxd, int(delays.max()))
        clock_ms += round_ms

    # ---- collect the population's parameters back over the wire --------
    rows = []
    for m in range(M):
        fallback = jax.tree.map(lambda a: a[m], params["clients"])
        if m in dead:
            rows.append(fallback)   # best knowledge: the initial row
            continue
        try:
            _send_control(m, codec.WireMessage("collect", "server",
                                               stop_at))
            msg, nb = _recv(m)
            if msg.tag != "params":  # pragma: no cover - protocol error
                raise ValueError(f"expected params frame, got {msg.tag!r}")
            control_bytes += nb
            rows.append(jax.tree.map(jnp.asarray,
                                     codec.unflatten_tree(msg.payload)))
        except _WIRE_DEATH:
            if m not in remote:
                raise
            _mark_dead(m)
            rows.append(fallback)
    clients = jax.tree.map(lambda *rs: jnp.stack(rs), *rows)
    if stop_workers:
        for m in range(M):
            if m in dead:
                continue
            try:
                _send_control(m, codec.WireMessage("stop", "server",
                                                   stop_at))
            except _WIRE_DEATH:
                if m not in remote:
                    raise
                _mark_dead(m)

    counters["control_bytes"] = control_bytes
    out_state = AsyncPlaneState(
        step=stop_at, table=np.asarray(table), delays=delays,
        last_active=last_active, clock_ms=clock_ms, max_delay_seen=maxd,
        counters=counters, seed=cfg_engine.seed)
    eps, delta = transport.privacy_spent(dp_releases)
    executed = stop_at - start
    formula = transport.account(batch=bs, embed=int(table.shape[-1]),
                                zoo_queries=q, n_clients=block,
                                n_rounds=executed)
    stats = {
        "rounds_executed": executed,
        "virtual_ms": clock_ms,
        "formula_bytes": formula.total_bytes,
        "participation": (counters["admitted"]
                          / max(counters["activations"], 1)),
        **{k: counters[k] for k in ("uplink_drops", "stragglers",
                                    "downlink_drops", "forced",
                                    "degraded_rounds",
                                    "retransmit_frames",
                                    "dead_parties")},
    }
    return PopulationResult(
        params={"clients": clients, "server": server},
        losses=np.asarray(losses_out), max_delay_seen=maxd,
        mean_delay=float(delays.mean()), wire_bytes=ledger.total_bytes,
        transmits_gradients=ledger.transmits_gradients, ledger=ledger,
        epsilon=eps, delta=delta, state=out_state,
        serialized_bytes=ledger.serialized_bytes,
        overhead_bytes=ledger.overhead_bytes, control_bytes=control_bytes,
        dp_releases=dp_releases, stats=stats)
