"""Asynchronous VFL engine (paper §III-C / Alg. 1) — host-level protocol
simulation with exact staleness semantics, compiled as one ``lax.scan``.

Per global round t (matching Fig. 2):
  * client m_t is activated (schedule drawn from p_m, assumption IV.6)
  * it picks a sample batch i_t, computes c/ĉ and "uploads" them
  * the server evaluates h/ĥ against its *embedding table* — the latest
    (stale, delay τ_{i,m}) embeddings of all other clients (assumption IV.7)
  * the server does one local FOO step (ours/VAFL) or ZOO step (ZOO-VFL)
  * the client does one ZOO step (ours/ZOO-VFL) or FOO step (VAFL)
  * the table row (m_t, i_t) is refreshed; delay counters update per §III-C

Synchronous baselines (Split-Learning, Syn-ZOO-VFL) activate *all* clients
every round with fresh embeddings (no table staleness).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VFLConfig
from repro.core import zoo
from repro.models import tabular


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "cascaded"   # cascaded | vafl | zoo-vfl | split | syn-zoo
    steps: int = 1000
    batch_size: int = 64
    seed: int = 0


@dataclasses.dataclass
class EngineResult:
    params: dict
    losses: np.ndarray          # (T,)
    max_delay_seen: int
    mean_delay: float


def make_schedule(key, steps: int, n_clients: int,
                  probs: Optional[Tuple[float, ...]] = None):
    """Activation sequence m_t — independent draws (assumption IV.6)."""
    p = (jnp.ones(n_clients) / n_clients if probs is None
         else jnp.asarray(probs))
    return jax.random.choice(key, n_clients, (steps,), p=p)


def run(cfg_engine: EngineConfig, vfl: VFLConfig, params, x_parts, y,
        *, probs=None) -> EngineResult:
    """x_parts: (M, n, f) vertically partitioned features; y: (n,) labels."""
    M, n, f = x_parts.shape
    T, bs = cfg_engine.steps, cfg_engine.batch_size
    key = jax.random.key(cfg_engine.seed)
    k_sched, k_idx, k_zoo = jax.random.split(key, 3)

    schedule = make_schedule(k_sched, T, M, probs)
    sample_idx = jax.random.randint(k_idx, (T, bs), 0, n)
    zoo_keys = jax.random.split(k_zoo, T)

    e = params["clients"]["b"].shape[-1]
    # server-side table of latest client embeddings per sample (Fig. 2)
    table0 = tabular.all_clients_forward(params["clients"],
                                         x_parts)          # (M, n, e)
    delays0 = jnp.zeros((M, n), jnp.int32)

    sync = cfg_engine.method in ("split", "syn-zoo")
    step_fn = _make_async_step(cfg_engine.method, vfl, x_parts, y) \
        if not sync else _make_sync_step(cfg_engine.method, vfl, x_parts, y)

    def body(carry, t_in):
        params, table, delays = carry
        m_t, idx, k = t_in
        params, table, loss = step_fn(params, table, m_t, idx, k)
        # delay bookkeeping (§III-C): activated (m,i) resets, others +1
        delays = delays + 1
        delays = delays.at[m_t, idx].set(0) if not sync else delays * 0
        return (params, table, delays), (loss, jnp.max(delays))

    (params, table, delays), (losses, maxd) = jax.lax.scan(
        body, (params, table0, delays0), (schedule, sample_idx, zoo_keys))

    return EngineResult(params=params, losses=np.asarray(losses),
                        max_delay_seen=int(jnp.max(maxd)),
                        mean_delay=float(jnp.mean(delays)))


# ------------------------------------------------------------------------

def _make_async_step(method: str, vfl: VFLConfig, x_parts, y):
    """One asynchronous round for the activated client m_t."""

    def server_loss_fn(server, c_batch, yb):
        logits = tabular.server_forward(server, c_batch)
        return tabular.xent(logits, yb)

    def step(params, table, m_t, idx, key):
        clients, server = params["clients"], params["server"]
        client_m = jax.tree.map(lambda a: a[m_t], clients)
        x_m = x_parts[m_t][idx]                          # (bs, f)
        yb = y[idx]

        # stale embeddings of all clients for this batch, fresh for m_t
        c_stale = table[:, idx, :]                       # (M, bs, e)
        c_fresh_m = tabular.client_forward(client_m, x_m)
        c_batch = c_stale.at[m_t].set(c_fresh_m)

        # ---- server update ------------------------------------------------
        if method in ("cascaded", "vafl"):
            h, g_server = jax.value_and_grad(server_loss_fn)(
                server, jax.lax.stop_gradient(c_batch), yb)
            server = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, server, g_server)
        else:  # zoo-vfl: server trains itself with ZOO too
            def s_loss(s):
                return server_loss_fn(s, c_batch, yb)
            g_server, h, _ = zoo.zoo_gradient(
                jax.random.fold_in(key, 1), s_loss, server, vfl.mu,
                vfl.zoo_dist)
            server = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, server, g_server)

        # ---- client update ------------------------------------------------
        if method == "vafl":
            # privacy-leaky: server sends ∂L/∂c_m; client backprops locally
            def c_loss(cm):
                cb = c_batch.at[m_t].set(tabular.client_forward(cm, x_m))
                return server_loss_fn(server, cb, yb)
            g_client = jax.grad(c_loss)(client_m)
        else:
            # ZOO (ours / zoo-vfl): only losses cross the wire
            def c_loss(cm):
                cb = c_batch.at[m_t].set(tabular.client_forward(cm, x_m))
                return server_loss_fn(server, cb, yb)
            g_client, _, _ = zoo.zoo_gradient(
                jax.random.fold_in(key, 2), c_loss, client_m, vfl.mu,
                vfl.zoo_dist, vfl.zoo_queries)
        new_client_m = jax.tree.map(
            lambda w, g: w - vfl.lr_client * g, client_m, g_client)
        clients = jax.tree.map(
            lambda all_, one: all_.at[m_t].set(one), clients, new_client_m)

        # refresh the table with m_t's (pre-update) fresh embedding
        table = table.at[m_t, idx].set(c_fresh_m)
        return {"clients": clients, "server": server}, table, h

    return step


def _make_sync_step(method: str, vfl: VFLConfig, x_parts, y):
    """Synchronous rounds: Split-Learning (FOO) / Syn-ZOO-VFL."""

    def step(params, table, m_t, idx, key):
        xb = x_parts[:, idx, :]                          # (M, bs, f)
        yb = y[idx]
        batch = {"x_parts": xb, "y": yb}

        if method == "split":
            (h, _), grads = jax.value_and_grad(
                tabular.global_loss, has_aux=True)(params, batch)
            params = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, params, grads)
        else:  # syn-zoo: every party (server + each client) does ZOO
            def loss_of(p):
                return tabular.global_loss(p, batch)[0]
            grads, h, _ = zoo.zoo_gradient(key, loss_of, params, vfl.mu,
                                           vfl.zoo_dist, vfl.zoo_queries)
            params = jax.tree.map(
                lambda w, g: w - vfl.lr_server * g, params, grads)
        return params, table, h

    return step
