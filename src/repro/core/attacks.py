"""Direct label-inference attack (paper §VI-B, Table I, after Fu et al.).

Threat model: the server is a "model without split" — it *sums* the client
outputs (one logit per class) and answers queries. A curious client crafts
a query to recover ∂L/∂y^c; the true label is the class with negative sign.

* FOO frameworks (Split-Learning / VAFL) transmit that partial derivative
  verbatim → the attack succeeds with certainty.
* ZOO frameworks reply only with two scalar losses (h, ĥ); the curious
  client's best move is the one-query gradient *estimate*
  φ(d)/μ (ĥ−h) u — a rank-one guess whose argmin is barely better than
  chance. An eavesdropper never sees u at all (the client keeps it) and
  must guess its own u' → chance level.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AttackResult:
    curious_client_acc: float
    eavesdropper_acc: float


@dataclasses.dataclass(frozen=True)
class FeatureAttackResult:
    mse_with_model_access: float    # Luo et al.-style inversion (needs F_m)
    mse_black_box: float            # our framework: F_m is a black box
    mse_chance: float               # guess-the-mean floor


def _sum_server_loss(c_sum, labels):
    """The vulnerable server: logits = Σ_m c_m; per-sample CE loss."""
    lse = jax.scipy.special.logsumexp(c_sum, axis=-1)
    gold = jnp.take_along_axis(c_sum, labels[:, None], -1)[:, 0]
    return lse - gold                                     # (B,)


def grad_wrt_output(c_sum, labels):
    """∂L/∂y — what a FOO server sends back (softmax − one-hot)."""
    p = jax.nn.softmax(c_sum, axis=-1)
    C = c_sum.shape[-1]
    return p - jax.nn.one_hot(labels, C)


def run_label_inference(key, n_classes: int, n_samples: int, mu: float = 1e-3,
                        framework: str = "zoo") -> AttackResult:
    """Simulate the attack over ``n_samples`` queries. Returns accuracies.

    framework: "foo" (gradient on the wire) or "zoo" (losses only)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (n_samples,), 0, n_classes)
    # curious client's crafted query: random class-logit vector
    c = jax.random.normal(k2, (n_samples, n_classes))
    u = jax.random.normal(k3, (n_samples, n_classes))     # client's secret u
    u_eaves = jax.random.normal(k4, (n_samples, n_classes))

    if framework == "foo":
        # the wire carries ∂L/∂y itself — both attacker roles read it
        g = grad_wrt_output(c, labels)
        pred_client = jnp.argmin(g, axis=-1)              # negative entry
        pred_eaves = pred_client
    else:
        h = _sum_server_loss(c, labels)
        h_hat = _sum_server_loss(c + mu * u, labels)
        coef = (h_hat - h)[:, None] / mu                  # scalar per query
        g_est = coef * u                                  # client knows u
        pred_client = jnp.argmin(g_est, axis=-1)
        # eavesdropper saw (c, ĉ, h, ĥ) but NOT u — guesses its own
        g_eaves = coef * u_eaves
        pred_eaves = jnp.argmin(g_eaves, axis=-1)

    acc_c = float(jnp.mean((pred_client == labels).astype(jnp.float32)))
    acc_e = float(jnp.mean((pred_eaves == labels).astype(jnp.float32)))
    return AttackResult(curious_client_acc=acc_c, eavesdropper_acc=acc_e)


def run_feature_inference(key, n: int = 512, f: int = 16, e: int = 32
                          ) -> FeatureAttackResult:
    """Feature-inference attack (paper §V-B, after Luo et al. [27]).

    The server observes the client's embeddings c = relu(xW + b) and tries
    to reconstruct the private features x.

    * With MODEL ACCESS (the assumption of [27] — client model known, e.g.
      a colluding party leaked it): invert the relu-affine map by solving
      the least-squares system on the active units — reconstruction
      succeeds (low MSE).
    * BLACK BOX (our framework's protocol: F_m never leaves the client):
      the embeddings carry no usable inverse — the best generic attacker
      guess is the population mean (MSE ≈ feature variance).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, f))
    W = jax.random.normal(k2, (f, e)) / np.sqrt(f)
    b = jax.random.normal(k3, (e,)) * 0.1
    pre = x @ W + b
    c = jax.nn.relu(pre)

    # --- with model access: recover pre-activations on active units and
    # solve x̂ = argmin ||x W - (c - b)||  restricted to active columns
    active = c > 0
    target = jnp.where(active, c - b, 0.0)

    def invert_row(t_row, a_row):
        Wa = W * a_row[None, :]                 # zero out inactive columns
        sol, *_ = jnp.linalg.lstsq(Wa.T, t_row)
        return sol
    x_hat = jax.vmap(invert_row)(target, active.astype(jnp.float32))
    mse_model = float(jnp.mean(jnp.square(x_hat - x)))

    # --- black box: F_m unknown -> attacker predicts the mean
    mse_bb = float(jnp.mean(jnp.square(jnp.mean(x, 0) - x)))
    mse_chance = float(jnp.var(x))
    return FeatureAttackResult(mse_with_model_access=mse_model,
                               mse_black_box=mse_bb,
                               mse_chance=mse_chance)
