"""The paper's contribution: cascaded hybrid optimization (Alg. 1).

One SPMD train step =
  1. client forward, clean + perturbed:  c = F_m(w_m;x),  ĉ = F_m(w_m+μu;x)
  2. server losses  h = L(F_0(w_0, c), y),  ĥ = L(F_0(w_0, ĉ), y)
     (only c/ĉ go up the wire, only h/ĥ come down — the privacy ledger in
     ``repro.core.privacy`` accounts for exactly these)
  3. client ZOO grad   ∇̂_{w_m} = φ(d_m)/μ (ĥ − h) u         (Eq. 3)
  4. server FOO grad   ∇_{w_0} = ∂[L + λg(w_0)]/∂w_0          (Eq. 4, local
     backprop — never transmitted)
  5. SGD updates on both partitions.

The server backward never differentiates through the client partition
(stop_gradient on the boundary embeddings), exactly matching the protocol:
the server cannot form ∂L/∂w_m because it does not know F_m.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import VFLConfig
from repro.core import zoo
from repro.core.methods import canonical_method
from repro.core.partition import merge_params, split_params


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StepOutput:
    loss: jnp.ndarray
    loss_perturbed: jnp.ndarray
    grad_client_norm: jnp.ndarray
    grad_server_norm: jnp.ndarray


def _maybe_row_mask(cfg_vfl: VFLConfig, client, batch, vocab: int):
    """Active-row perturbation mask tree for the embedding table."""
    if not cfg_vfl.active_rows_only:
        return None
    mask_tree = jax.tree.map(
        lambda w: jnp.ones((w.shape[0],), jnp.float32), client)
    if "embed" in client and "tokens" in batch:
        m = zoo.embedding_row_mask(batch["tokens"], vocab)
        mask_tree = dict(mask_tree)
        mask_tree["embed"] = {"table": m}
    return mask_tree


def make_cascaded_step(loss_fn: Callable, client_keys: Tuple[str, ...],
                       vfl: VFLConfig, optimizer,
                       vocab: int = 0, transport=None) -> Callable:
    """Build the jittable cascaded hybrid step.

    loss_fn(params, batch) -> (loss, aux).  optimizer: repro.optim object
    with ``init(params)`` / ``update(grads, state, params)``.
    Returns step(params, opt_state, batch, key) -> (params, opt_state, StepOutput).

    ``transport`` (a ``repro.federation.Transport``) optionally noises the
    scalar losses the CLIENT receives over the downlink before it forms
    its ZOO gradient (Eq. 3); the server's FOO step keeps the exact local
    loss — only the wire is perturbed, matching the async engine.
    """
    if transport is not None and transport.noise is not None \
            and not vfl.fused_dual:
        raise ValueError(
            "the DP loss channel requires the fused lane path "
            "(vfl.fused_dual=True); the unrolled per-query loop is a "
            "noise-free numerical test oracle")

    def step(params, opt_state, batch, key):
        client, server = split_params(params, client_keys)
        row_mask = _maybe_row_mask(vfl, client, batch, vocab)

        if vfl.fused_dual:
            # ---- default path: vectorized fan-out. ALL q directions are
            # drawn as stacked leaves and the server runs ONE vmapped pass
            # over the (1 + q) lanes {clean, perturbed…}. The server
            # weights are unbatched inside the vmap, so FSDP all-gathers
            # them once instead of (1 + q) times, and compile time /
            # dispatch overhead are constant in q. Gradient flows from the
            # clean lane only (zero cotangent on the perturbed lanes) —
            # numerically identical to the unrolled oracle below.
            u_stack, d_eff = zoo.sample_directions(
                key, client, vfl.zoo_queries, vfl.zoo_dist, row_mask)
            phi = zoo.phi_factor(vfl.zoo_dist, d_eff)
            lanes = zoo.stack_lanes(jax.lax.stop_gradient(client),
                                    u_stack, vfl.mu)

            def server_loss(server_p):
                losses = jax.vmap(
                    lambda c: loss_fn(merge_params(c, server_p), batch)[0]
                )(lanes)
                return losses[0], losses

            (loss_clean, losses), g_server = jax.value_and_grad(
                server_loss, has_aux=True)(server)
            # the client builds Eq. 3 from the losses it RECEIVES — under
            # a DP transport those are the clipped+noised downlink values
            recv = (losses if transport is None
                    else transport.downlink(losses, key))
            g_client = zoo.grad_from_losses(u_stack, recv[1:], recv[0],
                                            vfl.mu, phi)
            loss_pert = losses[1]
        else:
            # ---- unrolled oracle (test-only): per-query Python loop,
            # separate server passes. Kept as the numerical reference for
            # the stacked path; never the production configuration.
            keys = jax.random.split(key, vfl.zoo_queries)
            us, d_effs = zip(*[zoo.sample_direction(k, client, vfl.zoo_dist,
                                                    row_mask) for k in keys])
            phis = [zoo.phi_factor(vfl.zoo_dist, d) for d in d_effs]

            # server FOO (Eq. 4): exact backprop on w_0 only
            def server_loss(server_p):
                loss, _ = loss_fn(
                    merge_params(jax.lax.stop_gradient(client), server_p),
                    batch)
                return loss

            loss_clean, g_server = jax.value_and_grad(server_loss)(server)
            lps = [loss_fn(merge_params(zoo.perturb(client, u, vfl.mu),
                                        server), batch)[0]
                   for u in us]

            # client ZOO (Eq. 2/3). The raw-loss feed is sanctioned here:
            # this branch is the noise-free numerical reference and the
            # engine rejects DP transports on it (ValueError above).
            # analysis: ignore[PB105] test-only oracle; DP transports are rejected on this path
            gs = [zoo.two_point_grad(u, lp, loss_clean, vfl.mu, phi)
                  for u, lp, phi in zip(us, lps, phis)]
            g_client = jax.tree.map(lambda *x: sum(x) / float(len(x)), *gs)
            loss_pert = lps[0]

        # ---- updates (separate lrs per party, paper §VI-A-d) -------------
        grads = merge_params(
            jax.tree.map(lambda g: g * (vfl.lr_client / vfl.lr_server),
                         g_client),
            g_server)
        new_params, new_opt_state = optimizer.update(grads, opt_state, params)

        out = StepOutput(
            loss=loss_clean, loss_perturbed=loss_pert,
            grad_client_norm=_norm(g_client), grad_server_norm=_norm(g_server))
        return new_params, new_opt_state, out

    return step


def make_step_for_method(method: str, loss_fn, client_keys, vfl: VFLConfig,
                         optimizer, vocab: int = 0, transport=None):
    """Factory covering the paper's five frameworks at step granularity.

    cascaded      : ZOO client + FOO server   (ours)
    vafl / split  : FOO client + FOO server   (privacy-leaky upper bound)
    zoo-vfl / syn-zoo : ZOO client + ZOO server
    (sync-vs-async semantics live in repro.core.async_engine; spellings
    normalize through repro.core.methods so the three modules agree).

    ``transport`` optionally carries the DP loss channel (cascaded only at
    step granularity; the other ZOO methods noise through the async
    engine)."""
    method = canonical_method(method)
    if transport is not None and transport.method != method:
        raise ValueError(f"transport method {transport.method!r} does not "
                         f"match step method {method!r}")
    if method == "cascaded":
        return make_cascaded_step(loss_fn, client_keys, vfl, optimizer,
                                  vocab, transport)
    if transport is not None and transport.noise is not None:
        raise NotImplementedError(
            f"the DP loss channel is wired into the cascaded step factory "
            f"and the async engine; for {method!r} run through "
            "Federation.run")
    if method in ("vafl", "split"):
        return make_foo_step(loss_fn, optimizer)
    assert method in ("zoo-vfl", "syn-zoo"), method
    return make_full_zoo_step(loss_fn, client_keys, vfl, optimizer, vocab)


def make_foo_step(loss_fn, optimizer):
    """First-order step on all parties (Split-Learning / VAFL)."""
    def step(params, opt_state, batch, key):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                     batch)
        new_params, new_opt_state = optimizer.update(grads, opt_state, params)
        out = StepOutput(loss=loss, loss_perturbed=loss,
                         grad_client_norm=_norm(grads),
                         grad_server_norm=_norm(grads))
        return new_params, new_opt_state, out
    return step


def make_full_zoo_step(loss_fn, client_keys, vfl: VFLConfig, optimizer,
                       vocab: int = 0):
    """ZOO on both partitions (ZOO-VFL baseline [42]): the server also
    estimates its gradient with a two-point query on its own parameters."""
    def step(params, opt_state, batch, key):
        client, server = split_params(params, client_keys)
        k_c, k_s = jax.random.split(key)

        def loss_of_client(c):
            return loss_fn(merge_params(c, server), batch)[0]

        def loss_of_server(s):
            return loss_fn(merge_params(client, s), batch)[0]

        g_client, loss_clean, _ = zoo.zoo_gradient(
            k_c, loss_of_client, client, vfl.mu, vfl.zoo_dist,
            vfl.zoo_queries, unrolled=vfl.zoo_unrolled_oracle)
        g_server, _, _ = zoo.zoo_gradient(
            k_s, loss_of_server, server, vfl.mu, vfl.zoo_dist,
            vfl.zoo_queries, unrolled=vfl.zoo_unrolled_oracle)

        grads = merge_params(
            jax.tree.map(lambda g: g * (vfl.lr_client / vfl.lr_server),
                         g_client),
            g_server)
        new_params, new_opt_state = optimizer.update(grads, opt_state, params)
        out = StepOutput(loss=loss_clean, loss_perturbed=loss_clean,
                         grad_client_norm=_norm(g_client),
                         grad_server_norm=_norm(g_server))
        return new_params, new_opt_state, out
    return step


def _norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
