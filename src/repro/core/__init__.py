"""The paper's contribution: cascaded hybrid optimization for async VFL."""
from repro.core.adapters import ModelAdapter, mlp_adapter, tabular_adapter
from repro.core.cascade import (
    StepOutput,
    make_cascaded_step,
    make_foo_step,
    make_full_zoo_step,
    make_step_for_method,
)
from repro.core.partition import merge_params, split_params, tree_dim
from repro.core.zoo import (
    grad_from_losses,
    phi_factor,
    perturb,
    sample_direction,
    sample_directions,
    stack_lanes,
    two_point_grad,
    zoo_gradient,
)

__all__ = [
    "ModelAdapter",
    "StepOutput",
    "grad_from_losses",
    "make_cascaded_step",
    "make_foo_step",
    "make_full_zoo_step",
    "make_step_for_method",
    "merge_params",
    "mlp_adapter",
    "split_params",
    "tabular_adapter",
    "tree_dim",
    "phi_factor",
    "perturb",
    "sample_direction",
    "sample_directions",
    "stack_lanes",
    "two_point_grad",
    "zoo_gradient",
]
