"""The paper's contribution: cascaded hybrid optimization for async VFL."""
from repro.core.cascade import (
    StepOutput,
    make_cascaded_step,
    make_foo_step,
    make_full_zoo_step,
    make_step_for_method,
)
from repro.core.partition import merge_params, split_params, tree_dim
from repro.core.zoo import (
    phi_factor,
    perturb,
    sample_direction,
    two_point_grad,
    zoo_gradient,
)

__all__ = [
    "StepOutput",
    "make_cascaded_step",
    "make_foo_step",
    "make_full_zoo_step",
    "make_step_for_method",
    "merge_params",
    "split_params",
    "tree_dim",
    "phi_factor",
    "perturb",
    "sample_direction",
    "two_point_grad",
    "zoo_gradient",
]
