"""Zeroth-order optimization primitives (paper §III-B-1, Eq. 2/3).

Two-point stochastic gradient estimator over a parameter pytree:

    ∇̂ f = φ(d)/μ · [f(w + μu) − f(w)] · u,     u ~ p

* p = N(0, I)                    → φ(d) = 1
* p = U(S(0,1)) unit sphere      → φ(d) = d

Beyond-paper extensions:
* ``n_queries`` q-point averaging (variance ∝ 1/q),
* ``active_rows`` — perturb only embedding rows touched by the batch,
  shrinking the effective ZOO dimension from vocab·d to uniq_tokens·d
  (the paper's Thm IV.8 bounds convergence by d_client; this drops d_client
  by orders of magnitude for LM clients),
* vectorized fan-out — all q perturbation queries are drawn as stacked
  leaves (:func:`sample_directions`) and evaluated as vmapped lanes
  (:func:`zoo_gradient`), so compile time and dispatch overhead are
  constant in q instead of linear. The unrolled per-query path survives
  behind ``unrolled=True`` as the numerical test oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.partition import tree_dim


def phi_factor(dist: str, d) -> jnp.ndarray:
    if dist == "normal":
        return jnp.float32(1.0)
    if dist == "sphere":
        return jnp.asarray(d, jnp.float32)
    raise ValueError(f"unknown ZOO distribution {dist!r}")


def sample_direction(key, tree, dist: str = "sphere",
                     row_mask: Optional[dict] = None):
    """Draw u ~ p matching ``tree``'s structure.

    row_mask: optional pytree *matching tree's structure*, each leaf a
    (rows,) 0/1 mask applied to the leaf's first axis (use all-ones for
    leaves that are not row-restricted). Returns (u_tree, effective_dim)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    us = [jax.random.normal(k, x.shape, jnp.float32)
          for k, x in zip(keys, leaves)]
    u = jax.tree.unflatten(treedef, us)

    if row_mask is not None:
        u = jax.tree.map(
            lambda uu, m: uu * m.reshape((-1,) + (1,) * (uu.ndim - 1)),
            u, row_mask)
        d_eff = sum(
            jnp.sum(m) * (uu.size // uu.shape[0])
            for uu, m in zip(jax.tree.leaves(u), jax.tree.leaves(row_mask)))
    else:
        d_eff = jnp.float32(tree_dim(tree))

    if dist == "sphere":
        sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(u))
        inv = jax.lax.rsqrt(jnp.maximum(sq, 1e-30))
        u = jax.tree.map(lambda x: x * inv, u)
    return u, d_eff


def sample_directions(key, tree, n_queries: int, dist: str = "sphere",
                      row_mask: Optional[dict] = None):
    """Draw ALL q directions at once as stacked leaves.

    Returns (u_stack, d_eff): ``u_stack`` matches ``tree``'s structure with
    a leading (q,) lane axis on every leaf; ``d_eff`` is a (q,) vector (all
    entries equal — the mask is shared across queries). Per-lane draws are
    bitwise-identical to ``sample_direction`` over ``split(key, q)``, so
    the stacked and unrolled code paths agree at a fixed key."""
    if n_queries < 1:
        raise ValueError(f"n_queries must be >= 1, got {n_queries} "
                         "(q=0 would silently zero the ZOO gradient)")
    keys = jax.random.split(key, n_queries)
    u_stack, d_eff = jax.vmap(
        lambda k: sample_direction(k, tree, dist, row_mask))(keys)
    d_eff = jnp.broadcast_to(d_eff, (n_queries,))
    return u_stack, d_eff


def stack_lanes(tree, u_stack, mu: float):
    """(1+q)-lane parameter stack: lane 0 clean, lanes 1..q = w + μ·u_i."""
    return jax.tree.map(
        lambda w, u: jnp.concatenate(
            [w[None].astype(jnp.float32),
             w[None].astype(jnp.float32) + mu * u], axis=0).astype(w.dtype),
        tree, u_stack)


def grad_from_losses(u_stack, losses_pert, loss_clean, mu: float, phi):
    """Vectorized Eq. 3 with q-point averaging: the per-lane scalar
    coefficients contract against the stacked directions in one tensordot
    per leaf (no per-query Python loop)."""
    q = losses_pert.shape[0]
    coefs = ((phi / mu) * (losses_pert - loss_clean) / q).astype(jnp.float32)
    return jax.tree.map(lambda u: jnp.tensordot(coefs, u, axes=1), u_stack)


def perturb(tree, u, mu: float):
    return jax.tree.map(
        lambda w, uu: (w.astype(jnp.float32) + mu * uu).astype(w.dtype),
        tree, u)


def two_point_grad(u, h_hat, h, mu: float, phi) -> dict:
    """Eq. 3: ∇̂ = φ/μ (ĥ − h) u — built client-side from the two losses."""
    coef = (phi / mu) * (h_hat - h)
    return jax.tree.map(lambda uu: coef * uu, u)


def zoo_gradient(key, loss_fn, tree, mu: float, dist: str = "sphere",
                 n_queries: int = 1, row_mask=None, unrolled: bool = False,
                 loss_transform=None):
    """Full ZOO gradient of ``loss_fn(tree)`` with q-point averaging.

    Default path vmaps the loss over the clean lane plus all q perturbation
    lanes in one batched evaluation; ``unrolled=True`` keeps the original
    per-query Python loop as a test oracle (identical draws at fixed key).

    ``loss_transform``, when given, is applied to the stacked ``(1+q,)``
    loss vector before the estimator consumes it. This is the hook the
    engine uses to route the losses a ZOO party consumes through
    ``Transport.downlink`` (identity numerics on a bare wire — it only
    anchors the party boundary in the jaxpr for the certifier; under a
    DP channel it is where clip+noise land). Stacked path only: the
    unrolled per-query loop is the noise-free numerical test oracle and
    rejects it.

    Returns (grad_tree, loss_clean, aux). loss_fn must return a scalar
    (or (scalar, aux))."""
    def eval_loss(t):
        out = loss_fn(t)
        return out if isinstance(out, tuple) else (out, None)

    if unrolled:
        if loss_transform is not None:
            raise ValueError(
                "loss_transform requires the stacked lane path "
                "(unrolled=False); the per-query loop is a test oracle")
        loss_clean, aux = eval_loss(tree)

        def one_query(k):
            u, d_eff = sample_direction(k, tree, dist, row_mask)
            phi = phi_factor(dist, d_eff)
            loss_pert, _ = eval_loss(perturb(tree, u, mu))
            return two_point_grad(u, loss_pert, loss_clean, mu, phi)

        keys = jax.random.split(key, n_queries)
        grads = [one_query(k) for k in keys]
        grad = jax.tree.map(lambda *gs: sum(gs) / float(n_queries), *grads)
        return grad, loss_clean, aux

    u_stack, d_eff = sample_directions(key, tree, n_queries, dist, row_mask)
    phi = phi_factor(dist, d_eff)                               # (q,) | scalar
    lanes = stack_lanes(tree, u_stack, mu)
    losses, auxes = jax.vmap(eval_loss)(lanes)                  # (1+q,)
    if loss_transform is not None:
        losses = loss_transform(losses)
    loss_clean = losses[0]
    aux = jax.tree.map(lambda a: a[0], auxes)
    grad = grad_from_losses(u_stack, losses[1:], loss_clean, mu, phi)
    return grad, loss_clean, aux


def embedding_row_mask(tokens, vocab: int):
    """0/1 mask of vocabulary rows present in the batch (active-row mode)."""
    mask = jnp.zeros((vocab,), jnp.float32)
    return mask.at[tokens.reshape(-1)].set(1.0)
