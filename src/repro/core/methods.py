"""Canonical framework/method names — ONE alias table for every module.

``cascade.py`` (step factories), ``async_engine.py`` (protocol
simulation) and ``privacy.py`` (wire ledger) all dispatch on a method
string, and they historically each kept their own accepted spellings
("split" vs "split-learning", "syn-zoo" vs "syn-zoo-vfl"), which let them
drift until ``round_messages("syn-zoo", ...)`` raised on a name the
engine itself produces. Every module now normalizes through
:func:`canonical_method` so a spelling accepted anywhere is accepted
everywhere.

Canonical names (the paper's five frameworks):
  * ``cascaded`` — ZOO client / FOO server (ours, Alg. 1)
  * ``vafl``     — FOO client / FOO server, asynchronous (leaky wire)
  * ``split``    — FOO both, synchronous Split-Learning (leaky wire)
  * ``zoo-vfl``  — ZOO client / ZOO server, asynchronous
  * ``syn-zoo``  — ZOO everywhere, synchronous
"""
from __future__ import annotations

from typing import Tuple

CASCADED = "cascaded"
VAFL = "vafl"
SPLIT = "split"
ZOO_VFL = "zoo-vfl"
SYN_ZOO = "syn-zoo"

METHOD_ALIASES = {
    "cascaded": CASCADED, "ours": CASCADED,
    "vafl": VAFL,
    "split": SPLIT, "split-learning": SPLIT, "foo": SPLIT,
    "zoo-vfl": ZOO_VFL, "zoo": ZOO_VFL,
    "syn-zoo": SYN_ZOO, "syn-zoo-vfl": SYN_ZOO,
}

# every-client-every-round, fresh embeddings (no table staleness)
SYNC_METHODS: Tuple[str, ...] = (SPLIT, SYN_ZOO)

# wire shape per activated client: embeddings up, scalar losses down —
# the structurally safe protocols of the paper's §V argument
ZOO_WIRE_METHODS: Tuple[str, ...] = (CASCADED, ZOO_VFL, SYN_ZOO)

# wire shape: embedding up, partial derivative ∂L/∂c down (leaky)
FOO_WIRE_METHODS: Tuple[str, ...] = (VAFL, SPLIT)


def canonical_method(method: str) -> str:
    """Map any accepted spelling to its canonical name (ValueError else)."""
    try:
        return METHOD_ALIASES[method]
    except KeyError:
        raise ValueError(
            f"unknown method {method!r}; accepted spellings: "
            f"{sorted(METHOD_ALIASES)}") from None
