"""Communication & privacy ledger.

Static, per-round accounting of *what crosses the wire* under each
framework — the paper's security argument (§V) is structural: ZOO modes
transmit embeddings up and scalar losses down, never gradients or model
internals. The ledger makes that checkable in tests and reportable in
benchmarks (per-round bytes for the communication-efficiency comparison).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

GRADIENT_KINDS = frozenset({"partial_derivative", "gradient", "jacobian"})


@dataclasses.dataclass(frozen=True)
class Message:
    sender: str        # "client" | "server"
    kind: str          # "embedding" | "loss" | "partial_derivative"
    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


def round_messages(method: str, batch: int, embed: int) -> List[Message]:
    """Wire contents of ONE asynchronous round (one activated client)."""
    up_clean = Message("client", "embedding", (batch, embed))
    if method in ("cascaded", "zoo-vfl", "syn-zoo-vfl"):
        return [
            up_clean,
            Message("client", "embedding", (batch, embed)),   # ĉ (perturbed)
            Message("server", "loss", (batch,)),              # h
            Message("server", "loss", (batch,)),              # ĥ
        ]
    if method in ("vafl", "split-learning", "split"):
        return [
            up_clean,
            Message("server", "partial_derivative", (batch, embed)),  # ∂L/∂c
        ]
    raise ValueError(method)


@dataclasses.dataclass
class Ledger:
    messages: List[Message] = dataclasses.field(default_factory=list)

    def log_round(self, method: str, batch: int, embed: int):
        self.messages.extend(round_messages(method, batch, embed))

    @property
    def total_bytes(self) -> int:
        return sum(m.nbytes for m in self.messages)

    @property
    def transmits_gradients(self) -> bool:
        """True iff any internal information leaves a party (§V violated)."""
        return any(m.kind in GRADIENT_KINDS for m in self.messages)

    def bytes_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for m in self.messages:
            out[m.kind] = out.get(m.kind, 0) + m.nbytes
        return out
