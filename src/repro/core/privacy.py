"""Communication & privacy ledger, plus the DP loss channel.

Static, per-round accounting of *what crosses the wire* under each
framework — the paper's security argument (§V) is structural: ZOO modes
transmit embeddings up and scalar losses down, never gradients or model
internals. The ledger makes that checkable in tests and reportable in
benchmarks (per-round bytes for the communication-efficiency comparison).

The accounting is q-aware: with ``zoo_queries = q`` the client uploads
the clean embedding plus q perturbed embeddings ĉ_i, and the server
returns the clean loss h plus q perturbed losses ĥ_i — so the perturbed
traffic scales exactly linearly in q while the clean messages do not.
Method spellings are normalized through :mod:`repro.core.methods`, so
every name accepted by ``cascade``/``async_engine`` is accepted here.

:class:`GaussianLossChannel` upgrades the structural argument to a formal
(ε, δ) one (DPZV-style): the only server→client payload under a ZOO wire
is a handful of scalar losses, so clipping each scalar and adding
calibrated Gaussian noise makes every downlink a release of the Gaussian
mechanism. ``repro.federation.Transport`` plugs the channel into the
engines; the channel itself is pure config + math so it hashes into the
compiled-runner cache key.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tags
from repro.core.methods import (FOO_WIRE_METHODS, ZOO_WIRE_METHODS,
                                canonical_method)

GRADIENT_KINDS = frozenset({"partial_derivative", "gradient", "jacobian"})


@dataclasses.dataclass(frozen=True)
class Message:
    sender: str        # "client" | "server"
    kind: str          # "embedding" | "loss" | "partial_derivative"
    shape: Tuple[int, ...]
    dtype: str = "float32"
    # MEASURED bytes on the wire (the serialized frame, length prefix and
    # header included) when this message crossed a real ``repro.wire``
    # backend; None for formula-only accounting. ``nbytes`` stays the
    # payload formula either way, so the formula count survives as a
    # cross-check against the measurement.
    wired: Optional[int] = None

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    @property
    def bytes_on_wire(self) -> int:
        """Measured frame size when available, formula count otherwise."""
        return self.nbytes if self.wired is None else self.wired

    @property
    def overhead(self) -> int:
        """Serialization overhead over the payload formula (0 when the
        message never crossed a measuring backend)."""
        return 0 if self.wired is None else self.wired - self.nbytes


def serve_messages(batch: int, embed: int,
                   with_token: bool = True) -> List[Message]:
    """Wire contents of ONE split-inference step.

    The owning client party embeds the current token and uploads the
    (batch, d_model) embedding; on GENERATION steps (``with_token``) the
    server additionally returns the sampled token ids — during prefill
    the clients already hold the prompt, so nothing crosses back down.
    Logits, caches and every internal activation stay server-side, so the
    serve wire is as structurally safe as the training wire (§V)."""
    up = [Message("client", "embedding", (batch, embed))]
    if with_token:
        up.append(Message("server", "token", (batch,), "int32"))
    return up


def round_messages(method: str, batch: int, embed: int,
                   zoo_queries: int = 1) -> List[Message]:
    """Wire contents of ONE activated client's round.

    ZOO-wire methods carry 1 clean + q perturbed embeddings up and
    1 clean + q perturbed scalar-loss vectors down (q = ``zoo_queries``);
    FOO-wire methods carry one embedding up and one ∂L/∂c down — q never
    enters (there is no query fan-out on a first-order wire)."""
    if zoo_queries < 1:
        raise ValueError(f"zoo_queries must be >= 1, got {zoo_queries}")
    method = canonical_method(method)
    up_clean = Message("client", "embedding", (batch, embed))
    if method in ZOO_WIRE_METHODS:
        q = zoo_queries
        return (
            [up_clean]
            + [Message("client", "embedding", (batch, embed))] * q  # ĉ_i
            + [Message("server", "loss", (batch,))]                 # h
            + [Message("server", "loss", (batch,))] * q             # ĥ_i
        )
    assert method in FOO_WIRE_METHODS, method
    return [
        up_clean,
        Message("server", "partial_derivative", (batch, embed)),    # ∂L/∂c
    ]


@dataclasses.dataclass
class Ledger:
    messages: List[Message] = dataclasses.field(default_factory=list)

    @tags.accounting
    def log_round(self, method: str, batch: int, embed: int, *,
                  zoo_queries: int = 1, n_clients: int = 1,
                  n_rounds: int = 1):
        """Log ``n_rounds`` identical global rounds of ``n_clients``
        concurrently activated clients (the async engine's block, or all
        M for sync methods), each exchanging the q-aware per-client
        message set. Messages are frozen, so the repeated entries share
        the same instances — O(1) constructions however many rounds."""
        self.messages.extend(
            round_messages(method, batch, embed, zoo_queries)
            * (n_clients * n_rounds))

    @property
    def total_bytes(self) -> int:
        return sum(m.nbytes for m in self.messages)

    @property
    def serialized_bytes(self) -> int:
        """Actual bytes on the wire: the measured frame size for messages
        that crossed a ``repro.wire`` backend, the payload formula for the
        rest. ≥ :attr:`total_bytes` whenever every measurement carries its
        framing/header overhead."""
        return sum(m.bytes_on_wire for m in self.messages)

    @property
    def overhead_bytes(self) -> int:
        """Total measured serialization overhead (headers, length
        prefixes) — ``serialized_bytes - total_bytes`` restricted to the
        measured messages."""
        return sum(m.overhead for m in self.messages)

    @property
    def transmits_gradients(self) -> bool:
        """True iff any internal information leaves a party (§V violated)."""
        return any(m.kind in GRADIENT_KINDS for m in self.messages)

    def bytes_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for m in self.messages:
            out[m.kind] = out.get(m.kind, 0) + m.nbytes
        return out

    # ------------------------------------------------- serialization ------
    # Checkpoint/resume needs the ledger totals to survive a process
    # restart. Messages are frozen value objects, so the whole history
    # aggregates losslessly into (message, count) pairs — a resumed run
    # extends the restored ledger and the totals continue exactly.

    def to_counts(self) -> List[list]:
        order: List[Message] = []
        counts: Dict[Message, int] = {}
        for m in self.messages:
            if m not in counts:
                order.append(m)
            counts[m] = counts.get(m, 0) + 1
        return [[m.sender, m.kind, list(m.shape), m.dtype, counts[m]]
                + ([] if m.wired is None else [m.wired])
                for m in order]

    @classmethod
    def from_counts(cls, counts: List[list]) -> "Ledger":
        # rows are [sender, kind, shape, dtype, count] with an optional
        # trailing measured-bytes entry — checkpoints written before the
        # wire plane carry 5-element rows and still load
        led = cls()
        for row in counts:
            sender, kind, shape, dtype, n = row[:5]
            wired = int(row[5]) if len(row) > 5 else None
            led.messages.extend([Message(sender, kind, tuple(shape),
                                         dtype, wired=wired)] * int(n))
        return led


# ==================================================== DP loss channel ======

@dataclasses.dataclass(frozen=True)
class GaussianLossChannel:
    """Calibrated Gaussian noise on the scalar-loss downlink.

    Every scalar loss the server sends down is clamped to ``[0, clip]``
    (CE/hinge losses are non-negative; the clamp bounds one release's
    sensitivity by ``clip``) and perturbed with ``N(0, σ²)``, where σ is
    calibrated so ONE release satisfies (``epsilon``, ``delta``)-DP by the
    classic Gaussian-mechanism bound

        σ = clip · √(2 ln(1.25/δ)) / ε          (Dwork & Roth, Thm A.1).

    :meth:`spent` composes the per-release budget over a run's k releases.
    ``accountant="basic"`` (default) takes the better of basic composition
    (kε, kδ) and advanced composition
    (ε√(2k ln(1/δ)) + kε(eᵉ−1),  (k+1)δ) — exact enough to report an
    honest finite budget without an external DP library.
    ``accountant="rdp"`` tracks the Gaussian mechanism in Rényi-DP
    instead: one release with sensitivity Δ=clip and noise σ satisfies
    (α, αΔ²/(2σ²))-RDP for every order α; RDP composes by plain addition,
    and the composed guarantee converts back with
    ε(δ) = min_α [ k·αΔ²/(2σ²) + ln(1/δ)/(α−1) ] at total δ = ``delta`` —
    the moments-accountant bound, asymptotically √k vs advanced
    composition's √(k·ln) and strictly tighter δ (δ, not (k+1)δ).

    ``subsample`` < 1 adds privacy amplification by subsampling for the
    engine's batch draw: each round only touches a Poisson/uniform
    fraction q of the records, so one release's effective budget shrinks
    to the classic amplified bound

        (ε_q, δ_q) = (ln(1 + q·(e^ε − 1)),  q·δ)

    (≈ (qε, qδ) for small ε), and :meth:`spent` composes the AMPLIFIED
    per-release values. σ is unchanged — amplification is a property of
    the sampling, not the noise. With ``accountant="rdp"`` the exact
    subsampled-Gaussian RDP curve is out of scope (needs the
    Mironov/Wang integral); we take the min of the UNamplified RDP bound
    and the amplified basic/advanced bound — both are valid upper bounds,
    so the min is too.

    The channel is deliberately a frozen value object: the async engine
    hashes it (inside ``federation.Transport``) as part of its compiled
    runner cache key, and ``apply`` is pure so it can live inside the
    jitted scan body.
    """
    clip: float = 10.0
    epsilon: float = 1.0          # per-release ε target
    delta: float = 1e-5           # per-release δ target
    accountant: str = "basic"     # basic (min of basic/advanced) | rdp
    subsample: float = 1.0        # batch-draw sampling rate q (1 = off)

    # RDP orders swept by the moments accountant (standard grid: dense at
    # small α where few-release budgets convert best, log-spaced beyond)
    RDP_ORDERS = (1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 6.0, 8.0, 16.0,
                  32.0, 64.0, 128.0, 256.0, 512.0)

    def __post_init__(self):
        if self.clip <= 0 or self.epsilon <= 0 or not 0 < self.delta < 1:
            raise ValueError(
                f"need clip > 0, epsilon > 0, 0 < delta < 1; got "
                f"clip={self.clip}, epsilon={self.epsilon}, "
                f"delta={self.delta}")
        if self.accountant not in ("basic", "rdp"):
            raise ValueError(
                f"accountant must be 'basic' or 'rdp', "
                f"got {self.accountant!r}")
        if not 0.0 < self.subsample <= 1.0:
            raise ValueError(
                f"subsample must be a sampling rate in (0, 1], got "
                f"{self.subsample}")

    @property
    def sigma(self) -> float:
        """Noise stddev calibrated to the per-release (ε, δ) target."""
        return (self.clip * math.sqrt(2.0 * math.log(1.25 / self.delta))
                / self.epsilon)

    @tags.party("server")
    def apply(self, losses, key):
        """Clip + noise a (vector of) scalar loss(es) crossing the wire."""
        clipped = jnp.clip(losses, 0.0, self.clip)
        return clipped + self.sigma * jax.random.normal(
            key, jnp.shape(losses), jnp.result_type(losses, jnp.float32))

    def per_release(self) -> Tuple[float, float]:
        """One release's effective (ε, δ): the configured target, shrunk
        by subsampling amplification when ``subsample`` < 1."""
        if self.subsample >= 1.0:
            return self.epsilon, self.delta
        q = self.subsample
        return (math.log1p(q * (math.expm1(self.epsilon))),
                q * self.delta)

    @staticmethod
    def _compose_basic(k: int, eps: float, delta: float
                       ) -> Tuple[float, float]:
        """min(basic, advanced) composition of k (eps, delta) releases."""
        basic = (k * eps, k * delta)
        advanced = (
            eps * math.sqrt(2.0 * k * math.log(1.0 / delta))
            + k * eps * (math.exp(eps) - 1.0),
            (k + 1) * delta,
        )
        return min(basic, advanced, key=lambda ed: ed[0])

    def spent(self, n_releases: int) -> Tuple[float, float]:
        """Total (ε, δ) after ``n_releases`` downlink scalars."""
        k = int(n_releases)
        if k <= 0:
            return 0.0, 0.0
        if self.accountant == "rdp":
            rdp = self._spent_rdp(k)
            if self.subsample >= 1.0:
                return rdp
            # no exact subsampled-Gaussian RDP curve here: both the
            # unamplified RDP bound and the amplified basic/advanced
            # bound hold, so report whichever is tighter
            amplified = self._compose_basic(k, *self.per_release())
            return min(rdp, amplified, key=lambda ed: ed[0])
        return self._compose_basic(k, *self.per_release())

    def _spent_rdp(self, k: int) -> Tuple[float, float]:
        """Moments accountant: compose k Gaussian releases in RDP, convert
        back at the fixed total δ = ``self.delta``."""
        # per-release RDP coefficient: ε_RDP(α) = α · Δ²/(2σ²)
        rho = (self.clip / self.sigma) ** 2 / 2.0
        log_inv_delta = math.log(1.0 / self.delta)
        eps = min(k * a * rho + log_inv_delta / (a - 1.0)
                  for a in self.RDP_ORDERS)
        return eps, self.delta
