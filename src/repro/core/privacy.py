"""Communication & privacy ledger.

Static, per-round accounting of *what crosses the wire* under each
framework — the paper's security argument (§V) is structural: ZOO modes
transmit embeddings up and scalar losses down, never gradients or model
internals. The ledger makes that checkable in tests and reportable in
benchmarks (per-round bytes for the communication-efficiency comparison).

The accounting is q-aware: with ``zoo_queries = q`` the client uploads
the clean embedding plus q perturbed embeddings ĉ_i, and the server
returns the clean loss h plus q perturbed losses ĥ_i — so the perturbed
traffic scales exactly linearly in q while the clean messages do not.
Method spellings are normalized through :mod:`repro.core.methods`, so
every name accepted by ``cascade``/``async_engine`` is accepted here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.methods import (FOO_WIRE_METHODS, ZOO_WIRE_METHODS,
                                canonical_method)

GRADIENT_KINDS = frozenset({"partial_derivative", "gradient", "jacobian"})


@dataclasses.dataclass(frozen=True)
class Message:
    sender: str        # "client" | "server"
    kind: str          # "embedding" | "loss" | "partial_derivative"
    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


def round_messages(method: str, batch: int, embed: int,
                   zoo_queries: int = 1) -> List[Message]:
    """Wire contents of ONE activated client's round.

    ZOO-wire methods carry 1 clean + q perturbed embeddings up and
    1 clean + q perturbed scalar-loss vectors down (q = ``zoo_queries``);
    FOO-wire methods carry one embedding up and one ∂L/∂c down — q never
    enters (there is no query fan-out on a first-order wire)."""
    if zoo_queries < 1:
        raise ValueError(f"zoo_queries must be >= 1, got {zoo_queries}")
    method = canonical_method(method)
    up_clean = Message("client", "embedding", (batch, embed))
    if method in ZOO_WIRE_METHODS:
        q = zoo_queries
        return (
            [up_clean]
            + [Message("client", "embedding", (batch, embed))] * q  # ĉ_i
            + [Message("server", "loss", (batch,))]                 # h
            + [Message("server", "loss", (batch,))] * q             # ĥ_i
        )
    assert method in FOO_WIRE_METHODS, method
    return [
        up_clean,
        Message("server", "partial_derivative", (batch, embed)),    # ∂L/∂c
    ]


@dataclasses.dataclass
class Ledger:
    messages: List[Message] = dataclasses.field(default_factory=list)

    def log_round(self, method: str, batch: int, embed: int, *,
                  zoo_queries: int = 1, n_clients: int = 1,
                  n_rounds: int = 1):
        """Log ``n_rounds`` identical global rounds of ``n_clients``
        concurrently activated clients (the async engine's block, or all
        M for sync methods), each exchanging the q-aware per-client
        message set. Messages are frozen, so the repeated entries share
        the same instances — O(1) constructions however many rounds."""
        self.messages.extend(
            round_messages(method, batch, embed, zoo_queries)
            * (n_clients * n_rounds))

    @property
    def total_bytes(self) -> int:
        return sum(m.nbytes for m in self.messages)

    @property
    def transmits_gradients(self) -> bool:
        """True iff any internal information leaves a party (§V violated)."""
        return any(m.kind in GRADIENT_KINDS for m in self.messages)

    def bytes_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for m in self.messages:
            out[m.kind] = out.get(m.kind, 0) + m.nbytes
        return out
