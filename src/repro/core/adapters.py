"""Model adapters: the async engine's protocol for client/server pairs.

The asynchronous protocol simulation (``repro.core.async_engine``) only
needs three things from a model: a per-client feature extractor, a server
loss over the stacked client embeddings, and (optionally) a fused
"lanes" forward that evaluates the clean + q ZOO-perturbed client
forwards in one pass. Packaging those as a :class:`ModelAdapter` lets the
same jitted scan body drive ANY ``repro.models`` client/server pair — the
paper's tabular MLP, a SwiGLU-MLP stack, or anything else that fits the
(embedding up, loss down) wire shape.

Adapters are frozen dataclasses so the engine can hash them as part of
its compiled-runner cache key.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul_stacked
from repro.models import common, mlp, tabular
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class ModelAdapter:
    """Protocol bridging one model family into the async VFL engine.

    * ``client_forward(client_m, x_m)``        -> (bs, e) embedding
    * ``server_loss(server, c_all, y_batch)``  -> scalar loss over the
      (M, bs, e) table slice of all client embeddings
    * ``param_specs()``                        -> {"clients": stacked (M, ...)
      specs, "server": specs} for ``common.materialize``
    * ``client_lanes(client_m, u_stack, mu, x_m)`` (optional) -> (1+q, bs, e):
      lane 0 the clean forward, lanes 1..q the μ-perturbed forwards — the
      hook that routes the stacked ZOO fan-out through a fused kernel.
    * ``table_logical`` — per-dim logical axis names of the server's
      (M, n, e) embedding table; the engine's device-sharded path resolves
      its partitioning from these via ``repro.sharding.rules`` (the
      leading "clients" axis shards rows across the mesh "data" axis).
    """
    name: str
    client_forward: Callable
    server_loss: Callable
    param_specs: Callable
    client_lanes: Optional[Callable] = None
    table_logical: Tuple[Optional[str], ...] = ("clients", None, None)

    def init_params(self, key):
        return common.materialize(self.param_specs(), key)

    def global_loss(self, params, x_parts, y_batch):
        """Synchronous view: every client fresh, one loss (Split-Learning)."""
        c = jax.vmap(self.client_forward)(params["clients"], x_parts)
        return self.server_loss(params["server"], c, y_batch)


# ========================================================== paper tabular ==

# NOTE: both factories are lru-cached so repeated calls with the same
# config return the SAME adapter object (same closure identities) — the
# engine's compiled-runner cache keys on the adapter, so without this every
# `run()` using a default adapter would retrace and recompile its scan.

@functools.lru_cache(maxsize=None)
def tabular_adapter(cfg: Optional[PaperMLPConfig] = None,
                    *, use_pallas_lanes: bool = False) -> ModelAdapter:
    """The paper's §VI-A-b MLP (single-FC clients, two-FC server).

    ``use_pallas_lanes=True`` computes the clean + q perturbed client
    forwards through the fused ``zoo_dual_matmul_stacked`` Pallas kernel
    (one read of x/W per output tile, HBM traffic constant in q); the
    default composes the same lanes with plain XLA ops.
    """
    cfg = cfg or PaperMLPConfig()

    def server_loss(server, c_all, y_batch):
        return tabular.xent(tabular.server_forward(server, c_all), y_batch)

    def client_lanes(client_m, u_stack, mu, x_m):
        w, b = client_m["w"], client_m["b"]
        if use_pallas_lanes:
            y, y_hat = zoo_dual_matmul_stacked(x_m, w, u_stack["w"], mu)
        else:
            y = x_m @ w
            y_hat = y[None] + mu * jnp.einsum("bf,qfe->qbe", x_m,
                                              u_stack["w"])
        clean = jax.nn.relu(y + b)
        pert = jax.nn.relu(y_hat + (b[None] + mu * u_stack["b"])[:, None, :])
        return jnp.concatenate([clean[None], pert], axis=0)

    return ModelAdapter(
        name="tabular-pallas" if use_pallas_lanes else "tabular",
        client_forward=tabular.client_forward,
        server_loss=server_loss,
        param_specs=lambda: tabular.param_specs(cfg),
        client_lanes=client_lanes,
        table_logical=("clients", None, None),
    )


# ======================================================== SwiGLU-MLP pair ==

@functools.lru_cache(maxsize=None)
def mlp_adapter(*, n_clients: int = 4, features: int = 32,
                client_embed: int = 32, d_ff: int = 64,
                server_embed: int = 64, n_classes: int = 4,
                act: str = "swiglu") -> ModelAdapter:
    """Non-tabular client/server pair built from ``repro.models.mlp``
    blocks: each client projects its feature slice and applies a residual
    SwiGLU MLP; the server does the same over the concatenated embeddings
    before a linear head. Exercises the engine with a model whose client
    partition is a multi-layer pytree (not one FC layer)."""
    acfg = ModelConfig(act=act, dtype="float32", param_dtype="float32")
    f_per = features // n_clients
    e, se = client_embed, server_embed

    def param_specs():
        client = {
            "w_in": ParamSpec((f_per, e), "float32", (None, None), "scaled"),
            "mlp": mlp.mlp_specs(acfg, e, d_ff),
        }
        return {
            "clients": common.stack_layer_specs(client, n_clients,
                                                axis_name="clients"),
            "server": {
                "w_in": ParamSpec((n_clients * e, se), "float32",
                                  (None, None), "scaled"),
                "mlp": mlp.mlp_specs(acfg, se, 2 * d_ff),
                "head": ParamSpec((se, n_classes), "float32", (None, None),
                                  "scaled"),
            },
        }

    def _rms(h):
        # parameter-free rms norm keeps the residual stack well-conditioned
        # regardless of feature scale (ZOO loses to exploding logits fast)
        return h * jax.lax.rsqrt(jnp.mean(jnp.square(h), -1,
                                          keepdims=True) + 1e-6)

    def client_forward(client_m, x_m):
        h = _rms(x_m @ client_m["w_in"])
        return _rms(h + mlp.mlp_apply(acfg, client_m["mlp"], h[:, None, :])[:, 0])

    def server_loss(server, c_all, y_batch):
        M, B, _ = c_all.shape
        h = _rms(c_all.transpose(1, 0, 2).reshape(B, M * e) @ server["w_in"])
        h = _rms(h + mlp.mlp_apply(acfg, server["mlp"], h[:, None, :])[:, 0])
        return tabular.xent(h @ server["head"], y_batch)

    return ModelAdapter(name=f"mlp-{act}", client_forward=client_forward,
                        server_loss=server_loss, param_specs=param_specs,
                        table_logical=("clients", None, None))
