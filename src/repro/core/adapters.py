"""Model adapters: the async engine's protocol for client/server pairs.

The asynchronous protocol simulation (``repro.core.async_engine``) only
needs three things from a model: a per-client feature extractor, a server
loss over the stacked client embeddings, and (optionally) a fused
"lanes" forward that evaluates the clean + q ZOO-perturbed client
forwards in one pass. Packaging those as a :class:`ModelAdapter` lets the
same jitted scan body drive ANY ``repro.models`` client/server pair — the
paper's tabular MLP, a SwiGLU-MLP stack, or (via
:func:`from_model_config`) any registered LM-scale ``ModelConfig``: the
clients own the embedding/bottom layers and the server owns the
transformer/MoE/SSM backbone plus head.

Adapters are frozen dataclasses so the engine can hash them as part of
its compiled-runner cache key.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import marks, tags
from repro.configs.base import ModelConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import zoo
from repro.core.partition import split_params
from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul_stacked
from repro.models import common, mlp, tabular
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class ModelAdapter:
    """Protocol bridging one model family into the async VFL engine.

    * ``client_forward(client_m, x_m)``        -> (bs, e) embedding
    * ``server_loss(server, c_all, y_batch)``  -> scalar loss over the
      (M, bs, e) table slice of all client embeddings
    * ``param_specs()``                        -> {"clients": stacked (M, ...)
      specs, "server": specs} for ``common.materialize``
    * ``client_lanes(client_m, u_stack, mu, x_m)`` (optional) -> (1+q, bs, e):
      lane 0 the clean forward, lanes 1..q the μ-perturbed forwards — the
      hook that routes the stacked ZOO fan-out through a fused kernel.
    * ``row_mask(client_m, x_m)`` (optional) -> 0/1 row-mask pytree
      matching ``client_m``: restricts the ZOO perturbation to the rows a
      batch actually touches (active-row mode — shrinks the effective ZOO
      dimension from vocab·d to uniq_tokens·d for embedding clients).
    * ``table_logical`` — per-dim logical axis names of the server's
      (M, n, e) embedding table; the engine's device-sharded path resolves
      its partitioning from these via ``repro.sharding.rules`` (the
      leading "clients" axis shards rows across the mesh "data" axis).

    Serve plane (optional — set by :func:`from_model_config`; tabular
    adapters have no decode concept and leave them ``None``):

    * ``client_embed(client_m, tokens)``  -> (bs, S, d): the owning party
      embeds its tokens — one call covers a single decode token (S=1) or
      a whole prompt span (chunked prefill), its only serve-time uplink.
    * ``server_decode(server, x, caches, cur_pos)`` -> (logits, caches):
      backbone + head over the uploaded embedding; KV/SSM caches and
      logits never leave the server.
    * ``server_prefill(server, x, caches, t0)`` -> (logits, caches):
      consume a whole (bs, chunk, d) span upload in ONE compiled pass
      (positions t0 .. t0+chunk) — the chunked-prefill hook. Optional:
      the serve engine falls back to the per-token step loop for
      adapters that leave it ``None``.
    * ``cache_specs(batch, max_seq)``     -> decode-state spec tree.
    * ``server_decode_paged(server, x, caches, tables, cur_pos, active,
      page_size)`` -> (logits, caches): the continuous scheduler's
      batched paged decode step — x is (n_slots, 1, d) uploads, caches
      carry sequence leaves as shared page pools, ``tables`` (n_slots,
      pages_per_seq) block tables, ``cur_pos``/``active`` per-slot
      vectors. Optional; without it the scheduler cannot page.
    """
    name: str
    client_forward: Callable
    server_loss: Callable
    param_specs: Callable
    client_lanes: Optional[Callable] = None
    table_logical: Tuple[Optional[str], ...] = ("clients", None, None)
    row_mask: Optional[Callable] = None
    client_embed: Optional[Callable] = None
    server_decode: Optional[Callable] = None
    server_prefill: Optional[Callable] = None
    cache_specs: Optional[Callable] = None
    server_decode_paged: Optional[Callable] = None

    def init_params(self, key):
        return common.materialize(self.param_specs(), key)

    @tags.wire("up", accounted_by="Transport.account", kind="embedding",
               reason="Split-Learning oracle: fresh client embeddings "
                      "uploaded every step; the sync cascade meters it "
                      "per round")
    def global_loss(self, params, x_parts, y_batch):
        """Synchronous view: every client fresh, one loss (Split-Learning)."""
        c = marks.wire_boundary(
            jax.vmap(self.client_forward)(params["clients"], x_parts),
            kind="emb", direction="up")
        return self.server_loss(params["server"], c, y_batch)


# ========================================================== paper tabular ==

# NOTE: both factories are lru-cached so repeated calls with the same
# config return the SAME adapter object (same closure identities) — the
# engine's compiled-runner cache keys on the adapter, so without this every
# `run()` using a default adapter would retrace and recompile its scan.

@functools.lru_cache(maxsize=None)
def tabular_adapter(cfg: Optional[PaperMLPConfig] = None,
                    *, use_pallas_lanes: bool = False) -> ModelAdapter:
    """The paper's §VI-A-b MLP (single-FC clients, two-FC server).

    ``use_pallas_lanes=True`` computes the clean + q perturbed client
    forwards through the fused ``zoo_dual_matmul_stacked`` Pallas kernel
    with the bias+ReLU epilogue fused into the same pass (one read of
    x/W per output tile, HBM traffic constant in q, activated outputs
    written once); the default composes the same lanes with plain XLA ops.
    """
    cfg = cfg or PaperMLPConfig()

    @tags.party("server")
    def server_loss(server, c_all, y_batch):
        return tabular.xent(tabular.server_forward(server, c_all), y_batch)

    @tags.party("client")
    def client_lanes(client_m, u_stack, mu, x_m):
        w, b = client_m["w"], client_m["b"]
        if use_pallas_lanes:
            clean, pert = zoo_dual_matmul_stacked(x_m, w, u_stack["w"], mu,
                                                  b=b, ub=u_stack["b"])
        else:
            y = x_m @ w
            y_hat = y[None] + mu * jnp.einsum("bf,qfe->qbe", x_m,
                                              u_stack["w"])
            clean = jax.nn.relu(y + b)
            pert = jax.nn.relu(
                y_hat + (b[None] + mu * u_stack["b"])[:, None, :])
        return jnp.concatenate([clean[None], pert], axis=0)

    return ModelAdapter(
        name="tabular-pallas" if use_pallas_lanes else "tabular",
        client_forward=tabular.client_forward,
        server_loss=server_loss,
        param_specs=lambda: tabular.param_specs(cfg),
        client_lanes=client_lanes,
        table_logical=("clients", None, None),
    )


def example_engine_args(adapter: ModelAdapter, cfg: PaperMLPConfig, *,
                        n_rows: int = 16, batch: int = 4, block: int = 1,
                        seed: int = 0):
    """Small concrete engine-step arguments for jaxpr tracing.

    Builds the ``(params, table, m_blk, idx, key, x_parts, y)`` tuple a
    train-step closure takes (``Federation.traceable_train_step``), sized
    off the tabular protocol config — the certifier
    (``repro.analysis.certify``) traces the step over these with
    ``jax.make_jaxpr``; nothing is executed beyond zero-filled
    materialization, so no data or hardware is needed. ``params`` keeps
    its ``{"clients": ..., "server": ...}`` key paths: that is how the
    certifier labels which inputs are server-held."""
    specs = adapter.param_specs()
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), specs,
        is_leaf=common.is_spec)
    M = cfg.n_clients
    table = jnp.zeros((M, n_rows, cfg.client_embed), jnp.float32)
    m_blk = jnp.arange(block, dtype=jnp.int32)
    idx = jnp.zeros((batch,), jnp.int32)
    key = jax.random.key(seed)
    x_parts = jnp.zeros((M, n_rows, cfg.features_per_client), jnp.float32)
    y = jnp.zeros((n_rows,), jnp.int32)
    return params, table, m_blk, idx, key, x_parts, y


# ======================================================== SwiGLU-MLP pair ==

@functools.lru_cache(maxsize=None)
def mlp_adapter(*, n_clients: int = 4, features: int = 32,
                client_embed: int = 32, d_ff: int = 64,
                server_embed: int = 64, n_classes: int = 4,
                act: str = "swiglu") -> ModelAdapter:
    """Non-tabular client/server pair built from ``repro.models.mlp``
    blocks: each client projects its feature slice and applies a residual
    SwiGLU MLP; the server does the same over the concatenated embeddings
    before a linear head. Exercises the engine with a model whose client
    partition is a multi-layer pytree (not one FC layer)."""
    acfg = ModelConfig(act=act, dtype="float32", param_dtype="float32")
    f_per = features // n_clients
    e, se = client_embed, server_embed

    def param_specs():
        client = {
            "w_in": ParamSpec((f_per, e), "float32", (None, None), "scaled"),
            "mlp": mlp.mlp_specs(acfg, e, d_ff),
        }
        return {
            "clients": common.stack_layer_specs(client, n_clients,
                                                axis_name="clients"),
            "server": {
                "w_in": ParamSpec((n_clients * e, se), "float32",
                                  (None, None), "scaled"),
                "mlp": mlp.mlp_specs(acfg, se, 2 * d_ff),
                "head": ParamSpec((se, n_classes), "float32", (None, None),
                                  "scaled"),
            },
        }

    def _rms(h):
        # parameter-free rms norm keeps the residual stack well-conditioned
        # regardless of feature scale (ZOO loses to exploding logits fast)
        return h * jax.lax.rsqrt(jnp.mean(jnp.square(h), -1,
                                          keepdims=True) + 1e-6)

    @tags.party("client")
    def client_forward(client_m, x_m):
        h = _rms(x_m @ client_m["w_in"])
        return _rms(h + mlp.mlp_apply(acfg, client_m["mlp"], h[:, None, :])[:, 0])

    @tags.party("server")
    def server_loss(server, c_all, y_batch):
        M, B, _ = c_all.shape
        h = _rms(c_all.transpose(1, 0, 2).reshape(B, M * e) @ server["w_in"])
        h = _rms(h + mlp.mlp_apply(acfg, server["mlp"], h[:, None, :])[:, 0])
        return tabular.xent(h @ server["head"], y_batch)

    return ModelAdapter(name=f"mlp-{act}", client_forward=client_forward,
                        server_loss=server_loss, param_specs=param_specs,
                        table_logical=("clients", None, None))


# ================================================= ModelConfig bridge =====

# top-level param keys forming the ZOO client partition of an LM config
# (matches model_api.Model.client_keys for the supported families)
LM_CLIENT_KEYS = ("embed",)


@functools.lru_cache(maxsize=None)
def from_model_config(cfg: ModelConfig, *, n_clients: int = 2,
                      seq_len: int = 32,
                      active_rows: bool = True) -> ModelAdapter:
    """Derive a :class:`ModelAdapter` for ANY decoder ``ModelConfig``.

    The vertical split follows the paper's LM experiments: each of the M
    client parties owns a disjoint span of ``seq_len / M`` token positions
    plus its own copy of the embedding table (the bottom layer), and the
    server owns the full transformer/MoE/SSM backbone, final norm and LM
    head. A client's uplink "embedding" is its span's token embeddings
    flattened to one ``(batch, span·d_model)`` vector, so the engine's
    (M, n, e) table, staleness bookkeeping and wire accounting all apply
    unchanged; the server loss folds the M spans back into a (batch, S,
    d_model) sequence and runs the exact post-embedding half of
    ``model_api.build_model(cfg).loss_fn``.

    ``active_rows=True`` (default) attaches a :attr:`ModelAdapter.row_mask`
    hook restricting each client's ZOO perturbation to the embedding rows
    its batch actually touches — the ``active_rows``-style dimension
    reduction of ``repro.core.zoo`` at engine scale.

    ``x_parts`` for the engine are int32 token spans,
    ``data.vertical_partition(tokens, M)``; ``y`` is the full (n, S) label
    array. Use :func:`lm_engine_params` to map a global ``build_model``
    parameter tree into the engine's {"clients", "server"} layout.

    Limitations: encoder-decoder and VLM configs need a modality frontend
    on the wire and are rejected; the DeepSeek MTP head consumes raw
    tokens (which never reach the server under this protocol) and is
    dropped from the server partition.
    """
    from repro.models import model_api, transformer
    from repro.models.layers import apply_norm, embed_lookup, unembed
    from repro.sharding.rules import shard_constraint

    if cfg.is_encoder_decoder or cfg.family == "vlm":
        raise ValueError(
            f"from_model_config supports decoder-only families; "
            f"{cfg.arch_id!r} (family={cfg.family!r}, "
            f"encoder_decoder={cfg.is_encoder_decoder}) needs a modality "
            "frontend that never crosses the VFL wire")
    if n_clients < 1 or seq_len % n_clients:
        raise ValueError(
            f"seq_len={seq_len} must split evenly over "
            f"n_clients={n_clients} token spans")

    model = model_api.build_model(cfg, max_seq=seq_len)
    client_spec, server_spec = split_params(model.param_specs,
                                            LM_CLIENT_KEYS)
    server_spec = {k: v for k, v in server_spec.items() if k != "mtp"}
    span = seq_len // n_clients
    d = cfg.d_model

    @tags.party("client")
    def client_forward(client_m, x_m):
        """x_m: (bs, span) int32 token slice -> (bs, span·d) embedding."""
        e = embed_lookup(client_m["embed"], x_m, iota=cfg.iota_embed)
        return e.reshape(x_m.shape[0], span * d)

    @tags.party("client")
    def client_lanes(client_m, u_stack, mu, x_m):
        """Fused clean + q perturbed fan-out. Embedding lookup is linear
        in the table, so the q perturbed forwards are one gather into the
        stacked direction tables instead of q re-embeddings of a perturbed
        copy — bitwise equal to perturb-then-lookup (gather commutes with
        the elementwise w + μu and the dtype round-trip)."""
        clean = client_forward(client_m, x_m)                   # (bs, e)
        u_rows = jax.vmap(
            lambda u: embed_lookup(u["embed"], x_m))(u_stack)   # (q,bs,span,d)
        pert = (clean[None].astype(jnp.float32)
                + mu * u_rows.reshape(u_rows.shape[0], x_m.shape[0],
                                      span * d)).astype(clean.dtype)
        return jnp.concatenate([clean[None], pert], axis=0)

    @tags.party("server")
    def server_loss(server, c_all, y_batch):
        """c_all: (M, bs, span·d) client spans -> scalar LM loss.

        Mirrors the post-embedding half of ``transformer.lm_loss`` (same
        ops, same order) so ``global_loss`` matches ``model.loss_fn``
        exactly when every client holds the same embedding table."""
        M, bs, _ = c_all.shape
        x = (c_all.reshape(M, bs, span, d)
             .transpose(1, 0, 2, 3).reshape(bs, seq_len, d))
        positions = jnp.arange(seq_len)
        if "pos_embed" in server:
            pos_table = server["pos_embed"]
            pe = jnp.take(pos_table,
                          jnp.clip(positions, 0, pos_table.shape[0] - 1),
                          axis=0)
            x = x + pe.astype(x.dtype)
        x = shard_constraint(x, ("batch", None, "embed_act"))
        h, _, aux = transformer.backbone_apply(cfg, server, x,
                                               positions=positions)
        h = apply_norm(cfg, server["final_norm"], h)
        logits = unembed(server["lm_head"], h)
        logits = shard_constraint(logits, ("batch", None, "vocab_act"))
        ce = transformer.softmax_xent(logits[:, :-1], y_batch[:, 1:],
                                      cfg.padded_vocab)
        return jnp.mean(ce) + aux

    def param_specs():
        return {"clients": common.stack_layer_specs(client_spec, n_clients,
                                                    axis_name="clients"),
                "server": server_spec}

    def row_mask(client_m, x_m):
        return {"embed": {"table": zoo.embedding_row_mask(
            x_m, client_m["embed"]["table"].shape[0])}}

    # ---- serve plane: split inference with the training party split ----
    # The owning client embeds the current token (its span of positions);
    # the server runs pos-embed + backbone + head against its caches —
    # the exact post-embedding half of ``transformer.forward``'s decode
    # path, so split decode is bitwise-equal to global decode.

    @tags.party("client")
    def client_embed(client_m, tokens):
        """tokens (bs, S) int32 -> (bs, S, d) — the serve-time uplink.
        S=1 per decode step; S=chunk for a whole prompt span (chunked
        prefill uploads the span in one batched embed call)."""
        return embed_lookup(client_m["embed"], tokens, iota=cfg.iota_embed)

    def _decode_tail(server, x, caches, cur_pos, positions):
        if "pos_embed" in server:
            pos_table = server["pos_embed"]
            pe = jnp.take(pos_table,
                          jnp.clip(positions, 0, pos_table.shape[0] - 1),
                          axis=0)
            x = x + pe.astype(x.dtype)
        x = shard_constraint(x, ("batch", None, "embed_act"))
        h, new_caches, _ = transformer.backbone_apply(
            cfg, server, x, positions=positions, caches=caches,
            cur_pos=cur_pos)
        h = apply_norm(cfg, server["final_norm"], h)
        logits = unembed(server["lm_head"], h)
        logits = shard_constraint(logits, ("batch", None, "vocab_act"))
        return logits, new_caches

    @tags.party("server")
    def server_decode(server, x, caches, cur_pos):
        return _decode_tail(server, x, caches, cur_pos,
                            jnp.asarray(cur_pos)[None])

    @tags.party("server")
    def server_prefill(server, x, caches, t0):
        """x (bs, chunk, d): one party's whole span upload, consumed in a
        single compiled pass — same post-embedding ops as ``server_decode``
        per position, so chunked and per-token prefill agree token-for-
        token (float reassociation only on the recurrent-state families)."""
        positions = jnp.asarray(t0) + jnp.arange(x.shape[1])
        return _decode_tail(server, x, caches, t0, positions)

    @tags.party("server")
    def server_decode_paged(server, x, caches, tables, cur_pos, active,
                            page_size):
        """Batched paged decode: x (n_slots, 1, d) — every slot advances
        one token at its OWN position. Sequence cache leaves are shared
        page pools addressed through ``tables``; per-row positions drive
        RoPE/pos-embed and the attention mask, so each active row
        computes exactly what the B=1 ``server_decode`` would."""
        positions = cur_pos[:, None]                       # (n_slots, 1)
        paging_ctx = common.PageContext(tables=tables, active=active,
                                        page_size=page_size)
        if "pos_embed" in server:
            pos_table = server["pos_embed"]
            pe = jnp.take(pos_table,
                          jnp.clip(positions, 0, pos_table.shape[0] - 1),
                          axis=0)
            x = x + pe.astype(x.dtype)
        x = shard_constraint(x, ("batch", None, "embed_act"))
        h, new_caches, _ = transformer.backbone_apply(
            cfg, server, x, positions=positions, caches=caches,
            cur_pos=cur_pos, paging=paging_ctx)
        h = apply_norm(cfg, server["final_norm"], h)
        logits = unembed(server["lm_head"], h)
        logits = shard_constraint(logits, ("batch", None, "vocab_act"))
        return logits, new_caches

    def cache_specs(batch, max_seq):
        return model_api.build_cache_specs(cfg, batch, max_seq)

    return ModelAdapter(
        name=f"lm-{cfg.arch_id}-m{n_clients}-s{seq_len}",
        client_forward=client_forward,
        server_loss=server_loss,
        param_specs=param_specs,
        client_lanes=client_lanes,
        table_logical=("clients", None, None),
        row_mask=row_mask if active_rows else None,
        client_embed=client_embed,
        server_decode=server_decode,
        server_prefill=server_prefill,
        cache_specs=cache_specs,
        server_decode_paged=server_decode_paged,
    )


def lm_engine_params(global_params, n_clients: int):
    """Map a global ``build_model`` parameter tree into the engine layout.

    Every client party receives the same copy of the embedding table (the
    replicated bottom layer), stacked along a leading (M,) clients axis;
    the server keeps everything else (minus the token-consuming MTP head).
    With this layout ``from_model_config(...).global_loss`` equals the
    global model's ``loss_fn`` — the bridge's equivalence anchor.
    """
    client, server = split_params(global_params, LM_CLIENT_KEYS)
    clients = jax.tree.map(
        lambda w: jnp.repeat(w[None], n_clients, axis=0), client)
    server = {k: v for k, v in server.items() if k != "mtp"}
    return {"clients": clients, "server": server}
