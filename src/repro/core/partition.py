"""Party-plane parameter partition.

The cascade's party boundary is a functional split of the parameter pytree:
``client`` subtree(s) are updated with ZOO, the ``server`` subtree with FOO.
For the LM-scale configs the client holds the embedding (+ modality
projector); for the paper's tabular experiments the clients are a stacked
(M, ...) pytree of per-client feature extractors.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def split_params(params: Dict, client_keys: Tuple[str, ...]) -> Tuple[Dict, Dict]:
    client = {k: v for k, v in params.items() if k in client_keys}
    server = {k: v for k, v in params.items() if k not in client_keys}
    return client, server


def merge_params(client: Dict, server: Dict) -> Dict:
    out = dict(server)
    out.update(client)
    return out


def tree_dim(tree) -> int:
    """Total parameter dimension d of a partition (ZOO's d_m)."""
    return int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree)))


def tree_flat_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)
