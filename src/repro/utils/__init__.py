from repro.utils.hlo import collective_bytes, parse_collectives

__all__ = ["collective_bytes", "parse_collectives"]
