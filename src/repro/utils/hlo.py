"""HLO text analysis: collective-byte accounting for the roofline.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (or lowered) HLO text and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Shapes in HLO look like ``bf16[16,512,128]{2,1,0}``; we parse dtype + dims.
Per-op byte conventions (per participating device):
  all-gather        : output_bytes (data received)
  all-reduce        : 2 × operand_bytes (ring: reduce-scatter + all-gather)
  reduce-scatter    : operand_bytes
  all-to-all        : operand_bytes
  collective-permute: operand_bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# op name at the start of an HLO instruction: `%x = bf16[..] all-gather(...)`
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9\[\],{}\s*\.]*?)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """Returns [(op_kind, bytes)] for every collective in the module."""
    out: List[Tuple[str, int]] = []
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        if "-done(" in line:        # avoid double counting start/done pairs
            continue
        nbytes = _shape_bytes(m.group(1))
        if nbytes == 0:
            # fall back: use the full line's first shape
            sm = _SHAPE_RE.search(line)
            nbytes = _shape_bytes(line[:line.find("(")]) if sm else 0
        out.append((kind, nbytes))
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Aggregate per-device collective traffic by kind + 'total' (with the
    all-reduce 2× convention applied)."""
    agg: Dict[str, int] = {}
    total = 0
    for kind, nbytes in parse_collectives(hlo_text):
        mult = 2 if kind == "all-reduce" else 1
        agg[kind] = agg.get(kind, 0) + nbytes * mult
        total += nbytes * mult
    agg["total"] = total
    return agg
