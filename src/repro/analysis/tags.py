"""Annotation registry for the party-boundary and trace-hygiene analyzers.

The decorators here are runtime-inert: they attach metadata attributes to
the decorated function and return it unchanged. The static passes in
``analysis.boundary`` and ``analysis.jitlint`` read the *decorator syntax*
from the AST (they never import the analyzed modules), so the single source
of truth for what a decorator means lives in this module, next to the
name-based registries the passes fall back on for adapter hooks that are
built dynamically (closures stored on ``ModelAdapter`` fields).

Annotation contract
-------------------
``@tags.party("client"|"server")``
    The function body executes on that party. Client-tagged code may touch
    raw features and client leaves; server-tagged code may not.

``@tags.wire(direction, accounted_by=..., kind=..., reason=...)``
    The function intentionally moves a value across the party boundary
    ("up" = client->server, "down" = server->client). ``accounted_by`` must
    name a ``Transport`` accounting method (``Transport.account_serve``,
    ...) — rule PB104 verifies the target exists and is itself tagged
    ``@tags.accounting``. ``kind`` describes the payload (e.g. "embedding",
    "loss", "partial_derivative") and is what makes deliberately-leaky
    baselines (VAFL's FOO downlink) *declared* rather than silent.

``@tags.accounting``
    A ``Transport``/``Ledger`` method that meters a wire crossing. Only
    methods carrying this tag are legal ``accounted_by`` targets.

``@tags.hot_loop``
    The function is a steady-state serve-plane step: host syncs and
    host->device uploads are flagged *anywhere* in its body, not just
    inside ``for``/``while`` statements.

``@tags.host_boundary(reason)``
    The function is a sanctioned host<->device crossing point (e.g. the
    once-per-wave retirement fetch). Host-sync rules skip its body; the
    mandatory reason documents why the crossing is amortized.

Suppressions
------------
A finding on line N is suppressed by ``# analysis: ignore[RULE] reason``
on line N or N-1. An empty reason is itself an error (BA001): every
suppression must say *why* the flow/sync is acceptable.
"""

from __future__ import annotations

import typing

_F = typing.TypeVar("_F", bound=typing.Callable[..., typing.Any])

PARTIES = ("client", "server")
WIRE_DIRECTIONS = ("up", "down")


def party(name: str) -> typing.Callable[[_F], _F]:
    """Mark a function as executing on one party ("client" or "server")."""
    if name not in PARTIES:
        raise ValueError(f"unknown party {name!r}; expected one of {PARTIES}")

    def deco(fn: _F) -> _F:
        fn.__vfl_party__ = name  # type: ignore[attr-defined]
        return fn

    return deco


def wire(
    direction: str,
    *,
    accounted_by: str,
    kind: str = "embedding",
    reason: str = "",
) -> typing.Callable[[_F], _F]:
    """Declare a legal cross-party value flow inside the decorated function."""
    if direction not in WIRE_DIRECTIONS:
        raise ValueError(
            f"unknown wire direction {direction!r}; expected one of {WIRE_DIRECTIONS}"
        )

    def deco(fn: _F) -> _F:
        # stacked @wire decorators accumulate (a function may declare both
        # an "up" and a "down" channel, e.g. the VAFL partial-derivative
        # baseline) — mirror the AST pass, which reads every decorator
        wires = list(getattr(fn, "__vfl_wire__", []))
        wires.append(
            {
                "direction": direction,
                "accounted_by": accounted_by,
                "kind": kind,
                "reason": reason,
            }
        )
        fn.__vfl_wire__ = wires  # type: ignore[attr-defined]
        return fn

    return deco


def accounting(fn: _F) -> _F:
    """Mark a Transport/Ledger method as a wire-accounting point."""
    fn.__vfl_accounting__ = True  # type: ignore[attr-defined]
    return fn


def hot_loop(fn: _F) -> _F:
    """Mark a function as a steady-state serve step (strict host-sync rules)."""
    fn.__vfl_hot_loop__ = True  # type: ignore[attr-defined]
    return fn


def host_boundary(reason: str) -> typing.Callable[[_F], _F]:
    """Mark a function as a sanctioned, amortized host<->device crossing."""
    if not reason:
        raise ValueError("host_boundary requires a non-empty reason")

    def deco(fn: _F) -> _F:
        fn.__vfl_host_boundary__ = reason  # type: ignore[attr-defined]
        return fn

    return deco


# ---------------------------------------------------------------------------
# Name-based registries. Adapter hooks are plain closures stored on
# ``ModelAdapter`` dataclass fields, so call sites look like
# ``adapter.client_embed(...)``; the static pass resolves party ownership
# from the *attribute name* via these tables. Keep them in sync with
# ``core/adapters.py``.
# ---------------------------------------------------------------------------

# Attribute names whose call RESULT is client-owned data (embeddings/raw
# feature projections computed from client leaves).
CLIENT_SOURCE_ATTRS: frozenset[str] = frozenset(
    {"client_forward", "client_embed", "client_lanes"}
)

# Attribute names that execute on the server: passing client-sourced values
# into them is a boundary crossing (PB101) unless wire-declared.
SERVER_SINK_ATTRS: frozenset[str] = frozenset(
    {"server_loss", "server_decode", "server_prefill", "server_decode_paged"}
)

# Subscript keys that select party-owned parameter subtrees:
# ``params["clients"]`` / ``params["server"]``.
CLIENT_PARAM_KEYS: frozenset[str] = frozenset({"clients"})
SERVER_PARAM_KEYS: frozenset[str] = frozenset({"server"})

# jax transforms whose result is gradient-typed (PB102 sources).
GRADIENT_SOURCES: frozenset[str] = frozenset(
    {"grad", "value_and_grad", "vjp", "jacrev", "jacfwd", "jacobian"}
)

# Attribute/function names that sanitize a server->client loss downlink
# (DP noise + ledger metering happen inside).
DOWNLINK_SANITIZERS: frozenset[str] = frozenset({"downlink"})

# ZOO consumers of downlinked losses: feeding them *raw* server losses
# (bypassing Transport.downlink) is PB105.
DOWNLINK_CONSUMERS: frozenset[str] = frozenset({"grad_from_losses", "two_point_grad"})

# Names that denote server-side loss evaluation; values derived from them
# are "losses computed on the server" for PB105 purposes.
SERVER_LOSS_NAMES: frozenset[str] = frozenset({"server_loss"})

# Parameter names that denote raw (pre-embedding) client features. Their
# appearance inside server-tagged code is PB103.
RAW_FEATURE_PARAMS: frozenset[str] = frozenset({"x_parts", "x_m", "x_blk", "x_raw"})

# Modules whose *every* function is treated as serve-plane hot code: host
# syncs inside for/while loops are flagged even without @tags.hot_loop.
HOT_MODULES: tuple[str, ...] = (
    "federation/scheduler.py",
    "federation/serving.py",
    "launch/serve.py",
    # the wire plane's steady-state loops: the worker's serve loop and the
    # transport backends it drains frames through
    "wire/worker.py",
    "wire/backend.py",
)

# Modules (relative to the ``repro`` package root) that define the
# ``@tags.accounting`` targets wire declarations may name. The CLI seeds
# its accounting set from these even on a PARTIAL scan (e.g.
# ``python -m repro.analysis src/repro/wire``) — otherwise every
# ``accounted_by="Transport.account_wire"`` in an out-of-scan module would
# be a spurious PB104.
ACCOUNTING_MODULES: tuple[str, ...] = (
    "federation/transport.py",
    "core/privacy.py",
)

# Host-sync call forms (device->host) recognized by TH201.
HOST_SYNC_FUNCS: frozenset[str] = frozenset(
    {"np.asarray", "np.array", "numpy.asarray", "numpy.array", "jax.device_get"}
)
HOST_SYNC_METHODS: frozenset[str] = frozenset({"item", "tolist", "block_until_ready"})
HOST_SYNC_BUILTINS: frozenset[str] = frozenset({"float", "int", "bool"})

# Device-upload call forms (host->device) — flagged by TH201 only inside
# @tags.hot_loop bodies, where a per-step upload defeats the device-resident
# scheduler design.
DEVICE_PUT_FUNCS: frozenset[str] = frozenset(
    {"jnp.asarray", "jnp.array", "jax.device_put"}
)
