"""Boundary certification driver (``python -m repro.analysis certify``).

Builds a real :class:`~repro.federation.session.Federation` for every
shipped method configuration, traces the EXACT step closure its engine
jits (``Federation.traceable_train_step`` / the population server pair /
the serve plane's decode scan), runs the :mod:`repro.analysis.ifc` taint
pass over the jaxpr, and evaluates:

* **IF301–IF303** — :func:`ifc.check_flows` on each report;
* **IF304** — the traced crossing inventory must match what the wire
  plane actually serializes: payload kinds against
  :data:`repro.wire.codec.DATA_TAGS` (+ the serve plane's token frame),
  per-round element counts against the :func:`privacy.round_messages` /
  :func:`privacy.serve_messages` ledger formulas, no
  :data:`privacy.GRADIENT_KINDS` message on a certified wire, and — for
  the device-sharded engine — every HLO collective restricted to
  intra-server kinds (``all-gather``/``all-reduce``; collectives move
  data between *server* shards, never across the party boundary).

``vafl`` and ``split`` are certified as NEGATIVE CONTROLS: their wire is
declared leaky (FOO downlink), so the certifier must trip IF301 on them
— if it does not, the gradient anchor is broken and certification of the
safe methods is vacuous, which is itself reported as a finding.

The result is ``CERT_boundary.json``: machine-readable per-method
crossing inventories + the rule verdicts, regenerated (never trusted
stale) on every run. Exit status is non-zero iff any finding survives.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import ifc
from repro.analysis.findings import Finding
from repro.configs import get_config
from repro.configs.base import VFLConfig, reduced
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import adapters, async_engine, privacy
from repro.core.methods import CASCADED, SPLIT, SYN_ZOO, VAFL, ZOO_VFL
from repro.core.privacy import GaussianLossChannel
from repro.federation import serving
from repro.federation.session import Federation
from repro.utils import hlo
from repro.wire import codec

DEFAULT_OUT = "CERT_boundary.json"

#: crossing kind -> the privacy-ledger Message.kind it serializes as
KIND_TO_MESSAGE = {"emb": "embedding", "loss": "loss", "token": "token"}

#: collective kinds the sharded server step may emit (server-internal
#: resharding; anything else would be a new cross-device channel)
SERVER_COLLECTIVES = frozenset({"all-gather", "all-reduce"})

# ---- toy trace geometry (shapes only matter for the jaxpr) ---------------
_Q = 2           # zoo_queries: 1 clean + 2 perturbed lanes
_BLOCK = 2       # async block rows per round
_BATCH = 4
_ROWS = 16
_TOY = PaperMLPConfig(n_features=8, n_classes=3, n_clients=2,
                      client_embed=4, server_embed=6)


def _cert_path(name: str) -> str:
    return f"<certify:{name}>"


# ======================================================== IF304 checks ====

def _crossing_kind_findings(name: str, report: ifc.IFCReport,
                            allowed_tags: Sequence[str]) -> List[Finding]:
    path = _cert_path(name)
    out: List[Finding] = []
    for c in report.crossings:
        if c.kind not in allowed_tags:
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: traced boundary crossing kind {c.kind!r} has no "
                f"wire serialization (allowed frame tags: "
                f"{sorted(allowed_tags)})"))
    return out


def _train_if304(name: str, report: ifc.IFCReport, meta: Dict[str, Any],
                 *, rounds_per_trace: int) -> List[Finding]:
    """Crossing inventory vs the wire plane for one training method."""
    path = _cert_path(name)
    out: List[Finding] = []
    lanes = 1 + meta["zoo_queries"]
    embed = _TOY.client_embed

    # (a) every crossing kind must be a codec DATA_TAG — the training
    # wire only serializes "emb" and "loss" frames
    out += _crossing_kind_findings(name, report, codec.DATA_TAGS)

    # (b) the ledger formula for one activated client's round
    msgs = privacy.round_messages(meta["method"], meta["batch"], embed,
                                  zoo_queries=meta["zoo_queries"])
    grad_msgs = [m.kind for m in msgs if m.kind in privacy.GRADIENT_KINDS]
    if grad_msgs:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: the privacy ledger says this method wires "
            f"{sorted(set(grad_msgs))} frames — a gradient on the wire "
            "cannot be certified"))
        return out
    n_loss = sum(1 for m in msgs if m.kind == "loss")
    n_emb = sum(1 for m in msgs if m.kind == "embedding")

    # (c) downlink: total scalars per trace == ledger losses * rounds
    down = report.down("loss")
    if not down:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: the ledger bills {n_loss} loss frames per round but "
            "the traced step has NO loss downlink crossing — the wire "
            "accounting and the program disagree"))
    got = sum(c.size for c in down)
    want = n_loss * lanes_scalars_per_msg() * rounds_per_trace
    if down and got != want:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: traced loss downlink carries {got} scalars per "
            f"trace; the ledger formula bills {n_loss} loss frames x 1 "
            f"scalar x {rounds_per_trace} activated client(s) = {want}"))
    for c in down:
        if not jnp.issubdtype(jnp.dtype(c.dtype), jnp.floating):
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: loss downlink dtype {c.dtype} is not a float "
                "loss scalar"))

    # (d) uplink: the lane fan-out axis must match the ledger's 1 clean +
    # q perturbed embedding frames
    ups = [c for c in report.up() if c.kind == "emb"]
    if not ups:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: the ledger bills {n_emb} embedding frames per round "
            "but the traced step has NO embedding uplink crossing"))
    for c in ups:
        if c.shape[-1] != embed:
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: embedding uplink trailing dim {c.shape[-1]} != "
                f"client embed width {embed}"))
        if n_emb > 1 and n_emb not in c.shape[:-2]:
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: embedding uplink shape {list(c.shape)} has no "
                f"lane axis of size {n_emb} (= 1 clean + q={lanes - 1} "
                "perturbed frames the ledger bills)"))
    return out


def lanes_scalars_per_msg() -> int:
    """One ledger loss Message is one scalar (shape ``()`` per lane —
    ``round_messages`` emits 1+q separate scalar messages)."""
    return 1


def _serve_if304(name: str, report: ifc.IFCReport, *, batch: int,
                 d_model: int, gen_len: int) -> List[Finding]:
    path = _cert_path(name)
    out: List[Finding] = []
    msgs = privacy.serve_messages(batch, d_model, with_token=True)
    allowed = sorted({k for k, v in KIND_TO_MESSAGE.items()
                      if v in {m.kind for m in msgs}})
    out += _crossing_kind_findings(name, report, allowed)

    toks = report.down("token")
    if not toks:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: serve ledger bills a token frame per generation "
            "step but the decode scan traced NO token downlink"))
    for c in toks:
        if not jnp.issubdtype(jnp.dtype(c.dtype), jnp.integer):
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: token downlink dtype {c.dtype} is not an "
                "integer id — the serve wire must carry token ids, "
                "never logits"))
        if c.size != batch:
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: token downlink carries {c.size} elements per "
                f"step; the ledger bills one id per sequence ({batch})"))
    ups = [c for c in report.up() if c.kind == "emb"]
    if not ups:
        out.append(Finding(
            "IF304", path, 0,
            f"{name}: decode scan traced no embedding uplink"))
    for c in ups:
        if c.shape[-1] != d_model or c.shape[0] != batch:
            out.append(Finding(
                "IF304", path, 0,
                f"{name}: serve uplink shape {list(c.shape)} does not "
                f"match the (batch={batch}, 1, d_model={d_model}) "
                "one-token embedding the ledger bills"))
    return out


# ================================================== per-method drivers ====

def _toy_session(method: str, *, block: int = 1, use_lanes: bool = False,
                 dp: bool = False, mesh_shards: int = 0,
                 q: int = _Q) -> Federation:
    noise = GaussianLossChannel() if dp else None
    return Federation.build(
        _TOY, VFLConfig(n_clients=_TOY.n_clients, zoo_queries=q),
        async_engine.EngineConfig(method=method, batch_size=_BATCH,
                                  block_size=block, use_lanes=use_lanes,
                                  mesh_shards=mesh_shards),
        noise=noise)


def _trace_train(fed: Federation) -> Tuple[ifc.IFCReport, Dict[str, Any]]:
    """Trace the session's step closure; client-bound outputs only."""
    meta = fed.boundary_meta()
    args = adapters.example_engine_args(fed.adapter, _TOY, n_rows=_ROWS,
                                        batch=meta["batch"],
                                        block=meta["block"])
    table_shape = tuple(args[1].shape)
    step = fed.traceable_train_step(table_shape=table_shape)

    def client_view(params: Any, table: Any, m_blk: Any, idx: Any,
                    key: Any, x_parts: Any, y: Any) -> Any:
        new_params, _table, _h = step(params, table, m_blk, idx, key,
                                      x_parts, y)
        return new_params["clients"]

    report = ifc.trace_and_analyze(client_view, args)
    return report, meta


def _trace_population(fed: Federation) -> Tuple[ifc.IFCReport,
                                                Dict[str, Any]]:
    """Trace ``losses_fn`` — the population engine's whole downlink.

    Args are a bare tuple ``(server, c_stale, m, emb_lanes, yb, key)``;
    the server party owns positions 0 (its parameters) and 1 (the stale
    embedding table it caches), so the SERVER seed is by position, not
    by pytree key name."""
    meta = fed.boundary_meta()
    _update, losses_fn = fed.traceable_population_fns()
    q = meta["zoo_queries"]
    server = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        fed.adapter.param_specs(),
        is_leaf=lambda x: hasattr(x, "logical"))["server"]
    c_stale = jnp.zeros((_TOY.n_clients, _BATCH, _TOY.client_embed),
                        jnp.float32)
    emb_lanes = jnp.zeros((1 + q, _BATCH, _TOY.client_embed), jnp.float32)
    yb = jnp.zeros((_BATCH,), jnp.int32)
    args = (server, c_stale, jnp.int32(0), emb_lanes, yb,
            jax.random.key(0))

    def is_server(path: str) -> bool:
        return path.startswith("[0]") or path.startswith("[1]")

    report = ifc.trace_and_analyze(lambda *a: losses_fn(*a), args,
                                   is_server=is_server)
    return report, meta


def _trace_serve(batch: int, prompt_len: int, gen_len: int
                 ) -> Tuple[ifc.IFCReport, Dict[str, Any]]:
    """Trace the decode scan — the serve plane's only server->client
    channel. Carried server state (logits, KV caches) seeds SERVER; the
    traced outputs are the sampled tokens the clients receive."""
    cfg = reduced(get_config("phi3-mini-3.8b"), d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab_size=64)
    fed = Federation.build(cfg, VFLConfig(), async_engine.EngineConfig(),
                           n_clients=2, seq_len=16)
    adapter = fed.adapter
    run = serving.make_decode_scan(adapter, fed.n_clients, fed.seq_len,
                                   prompt_len, gen_len, 0.7,
                                   cfg.vocab_size)
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        adapter.param_specs(), is_leaf=lambda x: hasattr(x, "logical"))
    caches = serving.zero_caches(adapter, batch, prompt_len + gen_len)
    # the carried logits' aval (shape, padded vocab, dtype) is the serve
    # step's business — read it off a shape-only trace
    step = serving.make_serve_step(adapter, fed.n_clients, fed.seq_len)
    logits_sd, _ = jax.eval_shape(step, params,
                                  jnp.zeros((batch, 1), jnp.int32),
                                  caches, 0)
    logits0 = jnp.zeros(logits_sd.shape, logits_sd.dtype)
    args = (params, logits0, caches, jax.random.key(0))

    def is_server(path: str) -> bool:
        # params["server"], the carried logits [1] and KV caches [2]
        return "server" in path.lower() or path.startswith(("[1]", "[2]"))

    report = ifc.trace_and_analyze(
        lambda p, lg, c, k: run(p, lg, c, k)[0], args,
        is_server=is_server)
    meta = {"method": SPLIT, "plane": "serve", "batch": batch,
            "d_model": cfg.d_model, "prompt_len": prompt_len,
            "gen_len": gen_len, "n_clients": fed.n_clients}
    return report, meta


def _report_json(report: ifc.IFCReport) -> Dict[str, Any]:
    return {
        "out_taints": [sorted(t) for t in report.out_taints],
        "crossings": [c.to_json() for c in report.crossings],
        "n_dp_eqns": report.n_dp_eqns,
    }


def _down_limits(meta: Dict[str, Any]) -> Dict[str, int]:
    lanes = 1 + meta["zoo_queries"]
    return {"loss": lanes * meta["block"]}


# ============================================================== driver ====

def build_certificate() -> Tuple[List[Finding], Dict[str, Any]]:
    """Certify every shipped configuration; returns (findings, cert)."""
    findings: List[Finding] = []
    methods: Dict[str, Any] = {}

    train_variants = [
        ("cascaded", dict(method=CASCADED, block=_BLOCK)),
        ("cascaded-lanes", dict(method=CASCADED, block=_BLOCK,
                                use_lanes=True)),
        ("cascaded-dp", dict(method=CASCADED, block=_BLOCK, dp=True)),
        ("cascaded-sharded", dict(method=CASCADED, block=_BLOCK,
                                  mesh_shards=1)),
        ("zoo-vfl", dict(method=ZOO_VFL, block=_BLOCK)),
        ("syn-zoo", dict(method=SYN_ZOO)),
    ]
    for name, kw in train_variants:
        fed = _toy_session(**kw)
        report, meta = _trace_train(fed)
        f = ifc.check_flows(report, name=name, dp_configured=meta["dp"],
                            down_limits=_down_limits(meta),
                            path=_cert_path(name))
        f += _train_if304(name, report, meta,
                          rounds_per_trace=meta["block"])
        if meta["dp"] and report.n_dp_eqns < 1:
            f.append(Finding(
                "IF303", _cert_path(name), 0,
                f"{name}: DP channel configured but the traced step "
                "contains no noise application"))
        entry: Dict[str, Any] = {
            "status": "violated" if f else "certified",
            "meta": meta, "report": _report_json(report),
            "findings": [fi.rule for fi in f],
        }
        if kw.get("mesh_shards"):
            entry["collectives"] = _sharded_collectives(name, fed, findings)
        methods[name] = entry
        findings += f

    # -- population engine (the real-wire server pair) ---------------------
    for name, dp in (("population", False), ("population-dp", True)):
        fed = _toy_session(CASCADED, dp=dp)
        report, meta = _trace_population(fed)
        limits = {"loss": 1 + meta["zoo_queries"]}   # per-client call
        f = ifc.check_flows(report, name=name, dp_configured=dp,
                            down_limits=limits, path=_cert_path(name))
        f += _train_if304(name, report, meta, rounds_per_trace=1)
        methods[name] = {
            "status": "violated" if f else "certified",
            "meta": dict(meta, plane="wire"),
            "report": _report_json(report),
            "findings": [fi.rule for fi in f],
        }
        findings += f

    # -- serve plane -------------------------------------------------------
    name = "split-serve"
    batch, prompt_len, gen_len = 2, 8, 4
    report, meta = _trace_serve(batch, prompt_len, gen_len)
    f = ifc.check_flows(report, name=name, dp_configured=False,
                        down_limits={"token": batch},
                        path=_cert_path(name))
    f += _serve_if304(name, report, batch=batch, d_model=meta["d_model"],
                      gen_len=gen_len)
    methods[name] = {
        "status": "violated" if f else "certified",
        "meta": meta, "report": _report_json(report),
        "findings": [fi.rule for fi in f],
    }
    findings += f

    # -- negative controls: the leaky FOO wires MUST trip IF301 ------------
    for name, method in (("vafl", VAFL), ("split", SPLIT)):
        fed = _toy_session(method)
        report, meta = _trace_train(fed)
        f = ifc.check_flows(report, name=name, dp_configured=False,
                            down_limits=_down_limits(meta),
                            path=_cert_path(name))
        tripped = any(fi.rule == "IF301" for fi in f)
        methods[name] = {
            "status": "declared-leaky",
            "expected_failure": "IF301",
            "tripped": tripped,
            "meta": meta, "report": _report_json(report),
            "findings": sorted({fi.rule for fi in f}),
        }
        if not tripped:
            findings.append(Finding(
                "IF301", _cert_path(name), 0,
                f"{name}: negative control did NOT trip IF301 — the "
                "certifier has lost its gradient anchor (grad_mark no "
                "longer reaches the client outputs), so certifying the "
                "safe methods proves nothing"))

    cert = {
        "version": 1,
        "tool": "repro.analysis.certify",
        "claim": ("every server->client flow in the shipped methods "
                  "factors through the (1+q)-scalar loss bottleneck "
                  "(training) or the sampled-token ids (serving); no "
                  "server-parameter cotangent reaches a client"),
        "rules": ["IF301", "IF302", "IF303", "IF304"],
        "wire": {"codec_data_tags": list(codec.DATA_TAGS),
                 "wire_version": codec.WIRE_VERSION},
        "methods": methods,
        "clean": not findings,
    }
    return findings, cert


def _sharded_collectives(name: str, fed: Federation,
                         findings: List[Finding]) -> Dict[str, int]:
    """Lower + compile the sharded step and audit its collectives."""
    meta = fed.boundary_meta()
    args = adapters.example_engine_args(fed.adapter, _TOY, n_rows=_ROWS,
                                        batch=meta["batch"],
                                        block=meta["block"])
    step = fed.traceable_train_step(table_shape=tuple(args[1].shape))
    txt = jax.jit(step).lower(*args).compile().as_text()
    coll = hlo.collective_bytes(txt)
    bad = sorted(set(coll) - SERVER_COLLECTIVES - {"total"})
    if bad:
        findings.append(Finding(
            "IF304", _cert_path(name), 0,
            f"{name}: sharded step emits collective kinds {bad} beyond "
            "the server-internal all-gather/all-reduce resharding — a "
            "new cross-device channel must be re-certified"))
    return coll


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis certify",
        description="prove the party boundary on the traced jaxprs")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode (identical verdict; documents the gate)")
    ap.add_argument("--json", action="store_true",
                    help="print the certificate JSON to stdout")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"certificate path (default {DEFAULT_OUT})")
    ns = ap.parse_args(argv)

    findings, cert = build_certificate()

    with open(ns.out, "w") as fh:
        json.dump(cert, fh, indent=2, sort_keys=True)
        fh.write("\n")

    if ns.json:
        print(json.dumps(cert, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f.render())
        certified = sum(1 for m in cert["methods"].values()
                        if m["status"] == "certified")
        controls = sum(1 for m in cert["methods"].values()
                       if m["status"] == "declared-leaky"
                       and m.get("tripped"))
        print(f"{certified} configuration(s) certified, {controls} "
              f"negative control(s) tripped as declared, "
              f"{len(findings)} finding(s) -> {ns.out}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
