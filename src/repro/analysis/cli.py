"""``python -m repro.analysis`` — run the boundary + trace-hygiene passes.

Exit status: 0 when no (unbaselined) findings, 1 otherwise. ``--strict``
ignores any baseline so only a clean tree passes; without it, findings
already recorded in ``--baseline`` are tolerated and only *new* ones fail
the run.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from repro.analysis import boundary, jitlint
from repro.analysis.findings import Finding, apply_suppressions, scan_suppressions

RULES = {
    "PB101": "undeclared client->server value flow",
    "PB102": "gradient-typed value flowing client-ward without a declared wire",
    "PB103": "raw client features inside server-party code",
    "PB104": "wire declaration with unknown/unmetered accounted_by target",
    "PB105": "server losses reach a ZOO estimator bypassing Transport.downlink",
    "TH201": "host sync / per-step upload in serve-plane hot code",
    "TH202": "Python branch on a traced value",
    "TH203": "dtype-unstable scan carry (literal astype)",
    "TH204": "leftover debug instrumentation",
    "BA001": "suppression comment without justification",
    "BA002": "unparseable file (syntax error)",
}


def iter_python_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(root, f))
    return out


def analyze_paths(paths: list[str]) -> list[Finding]:
    """Parse every .py under ``paths`` and run both passes."""
    files = iter_python_files(paths)
    trees: dict[str, ast.Module] = {}
    sources: dict[str, str] = {}
    findings: list[Finding] = []
    for path in files:
        with open(path, "r", encoding="utf-8") as fh:
            src = fh.read()
        try:
            trees[path] = ast.parse(src, filename=path)
            sources[path] = src
        except SyntaxError as exc:
            findings.append(
                Finding("BA002", path, exc.lineno or 1, f"syntax error: {exc.msg}")
            )
    accounting = boundary.collect_accounting(trees)
    for path, tree in trees.items():
        raw = boundary.check_module(path, tree, accounting)
        raw += jitlint.check_module(path, tree)
        findings += apply_suppressions(raw, scan_suppressions(sources[path]), path)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def load_baseline(path: str) -> set[str]:
    with open(path, "r", encoding="utf-8") as fh:
        return set(json.load(fh))


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__
    )
    parser.add_argument("paths", nargs="*", default=["src/repro"])
    parser.add_argument(
        "--strict",
        action="store_true",
        help="ignore the baseline: any finding fails the run",
    )
    parser.add_argument("--baseline", help="JSON baseline of tolerated finding keys")
    parser.add_argument(
        "--write-baseline",
        help="write current findings to this path as the new baseline and exit 0",
    )
    parser.add_argument("--json", action="store_true", help="machine-readable output")
    args = parser.parse_args(argv)

    findings = analyze_paths(args.paths or ["src/repro"])

    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as fh:
            json.dump(sorted(f.key() for f in findings), fh, indent=2)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    if args.baseline and not args.strict:
        tolerated = load_baseline(args.baseline)
        findings = [f for f in findings if f.key() not in tolerated]

    if args.json:
        print(
            json.dumps(
                [dataclass_dict(f) for f in findings], indent=2, sort_keys=True
            )
        )
    else:
        for f in findings:
            print(f.render())
        if findings:
            counts: dict[str, int] = {}
            for f in findings:
                counts[f.rule] = counts.get(f.rule, 0) + 1
            summary = ", ".join(f"{r} x{n}" for r, n in sorted(counts.items()))
            print(f"\n{len(findings)} finding(s): {summary}", file=sys.stderr)
        else:
            print("analysis clean: no findings", file=sys.stderr)
    return 1 if findings else 0


def dataclass_dict(f: Finding) -> dict[str, object]:
    return {"rule": f.rule, "path": f.path, "line": f.line, "message": f.message}


if __name__ == "__main__":
    sys.exit(main())
