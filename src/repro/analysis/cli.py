"""``python -m repro.analysis`` — run the boundary + trace-hygiene passes.

Exit status: 0 when no (unbaselined) findings, 1 otherwise. ``--strict``
ignores any baseline so only a clean tree passes; without it, findings
already recorded in ``--baseline`` are tolerated and only *new* ones fail
the run. ``--select FAMILIES`` (e.g. ``--select IF,PB``) restricts the
report to the named rule families.

``python -m repro.analysis certify [--strict|--json]`` runs the
jaxpr-level information-flow certifier (IF301–IF304) instead of the AST
passes — see :mod:`repro.analysis.certify`.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from repro.analysis import boundary, jitlint, tags
from repro.analysis.findings import Finding, apply_suppressions, scan_suppressions

RULES = {
    "PB101": "undeclared client->server value flow",
    "PB102": "gradient-typed value flowing client-ward without a declared wire",
    "PB103": "raw client features inside server-party code",
    "PB104": "wire declaration with unknown/unmetered accounted_by target",
    "PB105": "server losses reach a ZOO estimator bypassing Transport.downlink",
    "TH201": "host sync / per-step upload in serve-plane hot code",
    "TH202": "Python branch on a traced value",
    "TH203": "dtype-unstable scan carry (literal astype)",
    "TH204": "leftover debug instrumentation",
    "BA001": "suppression comment without justification",
    "BA002": "unparseable file (syntax error)",
    "BA003": "suppression comment names an unknown rule id",
    # jaxpr-level information-flow rules (emitted by `certify`, listed
    # here so --select and suppressions know the full id space)
    "IF301": "traced: server-parameter cotangent reaches a client-bound output",
    "IF302": "traced: server->client flow bypasses the scalar wire bottleneck",
    "IF303": "traced: DP channel configured but downlink not noise-dominated",
    "IF304": "traced boundary inventory disagrees with the wire serialization",
}

KNOWN_RULES = frozenset(RULES)


def iter_python_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(root, f))
    return out


def registry_accounting() -> set[str]:
    """``@tags.accounting`` qualnames from the ``ACCOUNTING_MODULES``
    registry, parsed straight from the package tree. Seeds the
    accounting set on PARTIAL scans (``python -m repro.analysis
    src/repro/wire``): the modules that define ``Transport.account_wire``
    are outside such a scan, and without the seed every wire declaration
    naming them would be a spurious PB104."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out: set[str] = set()
    for rel in tags.ACCOUNTING_MODULES:
        path = os.path.join(pkg, rel)
        if not os.path.isfile(path):
            continue
        with open(path, "r", encoding="utf-8") as fh:
            try:
                tree = ast.parse(fh.read(), filename=path)
            except SyntaxError:
                continue
        out |= boundary.collect_accounting({path: tree})
    return out


def analyze_paths(paths: list[str]) -> list[Finding]:
    """Parse every .py under ``paths`` and run both passes."""
    files = iter_python_files(paths)
    trees: dict[str, ast.Module] = {}
    sources: dict[str, str] = {}
    findings: list[Finding] = []
    for path in files:
        with open(path, "r", encoding="utf-8") as fh:
            src = fh.read()
        try:
            trees[path] = ast.parse(src, filename=path)
            sources[path] = src
        except SyntaxError as exc:
            findings.append(
                Finding("BA002", path, exc.lineno or 1, f"syntax error: {exc.msg}")
            )
    accounting = boundary.collect_accounting(trees) | registry_accounting()
    for path, tree in trees.items():
        raw = boundary.check_module(path, tree, accounting)
        raw += jitlint.check_module(path, tree)
        findings += apply_suppressions(
            raw, scan_suppressions(sources[path]), path, known_rules=KNOWN_RULES
        )
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def select_families(findings: list[Finding], select: str) -> list[Finding]:
    """Restrict findings to the named rule families (``"IF,PB"``).

    Raises ``SystemExit(2)`` on a family with no known rule — a typo'd
    ``--select`` must not silently report nothing."""
    known = {r.rstrip("0123456789") for r in RULES}
    wanted = [s.strip().upper() for s in select.split(",") if s.strip()]
    unknown = sorted(set(wanted) - known)
    if not wanted or unknown:
        print(
            f"--select: unknown rule family {unknown or [select]!r}; "
            f"known families: {sorted(known)}",
            file=sys.stderr,
        )
        raise SystemExit(2)
    return [f for f in findings if f.rule.rstrip("0123456789") in wanted]


def load_baseline(path: str) -> set[str]:
    with open(path, "r", encoding="utf-8") as fh:
        return set(json.load(fh))


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "certify":
        # the jaxpr-level certifier is a subcommand so the CI gate and
        # humans share one entry point; imported lazily (it pulls in jax)
        from repro.analysis import certify

        return certify.main(argv[1:])

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__,
        epilog="rules: " + ", ".join(sorted(RULES)),
    )
    parser.add_argument("paths", nargs="*", default=["src/repro"])
    parser.add_argument(
        "--select",
        help="comma-separated rule families to report (e.g. IF,PB,TH); "
        "an unknown family exits 2",
    )
    parser.add_argument(
        "--strict",
        action="store_true",
        help="ignore the baseline: any finding fails the run",
    )
    parser.add_argument("--baseline", help="JSON baseline of tolerated finding keys")
    parser.add_argument(
        "--write-baseline",
        help="write current findings to this path as the new baseline and exit 0",
    )
    parser.add_argument("--json", action="store_true", help="machine-readable output")
    args = parser.parse_args(argv)

    findings = analyze_paths(args.paths or ["src/repro"])
    if args.select:
        findings = select_families(findings, args.select)

    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as fh:
            json.dump(sorted(f.key() for f in findings), fh, indent=2)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    if args.baseline and not args.strict:
        tolerated = load_baseline(args.baseline)
        findings = [f for f in findings if f.key() not in tolerated]

    if args.json:
        print(
            json.dumps(
                [dataclass_dict(f) for f in findings], indent=2, sort_keys=True
            )
        )
    else:
        for f in findings:
            print(f.render())
        if findings:
            counts: dict[str, int] = {}
            for f in findings:
                counts[f.rule] = counts.get(f.rule, 0) + 1
            summary = ", ".join(f"{r} x{n}" for r, n in sorted(counts.items()))
            print(f"\n{len(findings)} finding(s): {summary}", file=sys.stderr)
        else:
            print("analysis clean: no findings", file=sys.stderr)
    return 1 if findings else 0


def dataclass_dict(f: Finding) -> dict[str, object]:
    return {"rule": f.rule, "path": f.path, "line": f.line, "message": f.message}


if __name__ == "__main__":
    sys.exit(main())
