"""Small AST helpers shared by the boundary and trace-hygiene passes."""

from __future__ import annotations

import ast
import dataclasses
import typing


def dotted(node: ast.AST) -> str | None:
    """Render ``a.b.c`` / ``name`` call targets; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted(node.func)


def attr_of_call(node: ast.Call) -> str | None:
    """Final attribute name of the callee (``adapter.client_embed`` -> ``client_embed``)."""
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return None


@dataclasses.dataclass
class TagInfo:
    """Tags parsed off a function's decorator list."""

    party: str | None = None
    wires: list[dict[str, str]] = dataclasses.field(default_factory=list)
    accounting: bool = False
    hot_loop: bool = False
    host_boundary: str | None = None


def _deco_tag_name(deco: ast.expr) -> tuple[str | None, ast.Call | None]:
    """Return (tag name, call node) if the decorator resolves into tags.*."""
    call = deco if isinstance(deco, ast.Call) else None
    target = deco.func if isinstance(deco, ast.Call) else deco
    name = dotted(target)
    if name is None:
        return None, None
    leaf = name.rsplit(".", 1)[-1]
    known = {"party", "wire", "accounting", "hot_loop", "host_boundary"}
    if leaf not in known:
        return None, None
    # Accept `tags.wire`, `analysis.tags.wire`, and bare `wire` (fixtures
    # import the decorators directly).
    if "." in name and ".tags." not in f".{name}":
        return None, None
    return leaf, call


def parse_tags(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> TagInfo:
    info = TagInfo()
    for deco in fn.decorator_list:
        leaf, call = _deco_tag_name(deco)
        if leaf is None:
            continue
        if leaf == "accounting":
            info.accounting = True
        elif leaf == "hot_loop":
            info.hot_loop = True
        elif leaf == "party" and call is not None and call.args:
            arg = call.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                info.party = arg.value
        elif leaf == "host_boundary" and call is not None and call.args:
            arg = call.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                info.host_boundary = arg.value
        elif leaf == "wire" and call is not None:
            spec: dict[str, str] = {}
            if call.args and isinstance(call.args[0], ast.Constant):
                spec["direction"] = str(call.args[0].value)
            for kw in call.keywords:
                if kw.arg and isinstance(kw.value, ast.Constant):
                    spec[kw.arg] = str(kw.value.value)
            info.wires.append(spec)
    return info


@dataclasses.dataclass
class FuncInfo:
    """A function definition plus its enclosing-def chain and parsed tags."""

    node: ast.FunctionDef | ast.AsyncFunctionDef
    qualname: str
    chain: tuple[ast.FunctionDef | ast.AsyncFunctionDef, ...]  # outermost first
    tags: TagInfo

    def chain_tags(self) -> list[TagInfo]:
        return [parse_tags(f) for f in self.chain] + [self.tags]

    def wire_spec(self, direction: str) -> dict[str, str] | None:
        """The innermost matching wire declaration covering this function."""
        for t in reversed(self.chain_tags()):
            for spec in t.wires:
                if spec.get("direction") == direction:
                    return spec
        return None

    def party(self) -> str | None:
        for t in reversed(self.chain_tags()):
            if t.party is not None:
                return t.party
        return None


def index_functions(tree: ast.Module) -> list[FuncInfo]:
    """All function defs (any nesting depth) with enclosing chains."""
    out: list[FuncInfo] = []

    def visit(node: ast.AST, chain: tuple, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                out.append(FuncInfo(child, qual, chain, parse_tags(child)))
                visit(child, chain + (child,), f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, chain, f"{prefix}{child.name}.")
            else:
                visit(child, chain, prefix)

    visit(tree, (), "")
    return out


def walk_body(
    fn: ast.FunctionDef | ast.AsyncFunctionDef,
    *,
    into_nested: bool = False,
) -> typing.Iterator[ast.AST]:
    """Walk a function body, optionally stopping at nested function defs."""
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if not into_nested and isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            continue
        stack.extend(ast.iter_child_nodes(node))


def names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def assigned_names(target: ast.expr) -> set[str]:
    """Names bound by an assignment target (tuple unpacking included)."""
    out: set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out
