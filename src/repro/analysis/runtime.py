"""Runtime sanitizers: device->host transfer sentinel + recompile sentinel.

The static passes prove what the source *says*; these prove what a run
*does*. ``strict()`` wraps a steady-state region (e.g. the paged-decode
block loop) and asserts zero device->host transfers and zero fresh XLA
compiles inside it — turning the scheduler's self-reported
``host_transfers`` counter into an externally enforced property.

Why not JAX's transfer guard alone: on the CPU backend (this repo's test
substrate) ``jax.transfer_guard_device_to_host("disallow")`` does not
intercept host reads — ``np.asarray``/``.item()``/``float()`` on a
committed CPU array are treated as intra-device copies and sail through.
So the sentinel instruments ``ArrayImpl``'s Python-level host-read entry
points directly (``__array__``, ``_value``, ``item``, ...), counting only
reads that actually materialize a fresh host copy (``_npy_value is None``
— cached reads are free). The transfer guard is still engaged when the
backend honors it, so on TPU/GPU the same context manager gets the
native enforcement for free.

The recompile sentinel listens to ``jax_log_compiles`` logging records
("Compiling <name> ..." from the dispatch layer) — any fresh lowering
inside the guarded region is a retrace that the AOT warmup should have
absorbed.
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import sys
import threading
import typing

import jax

_PATCH_NAMES = (
    "__array__",
    "__bool__",
    "__float__",
    "__int__",
    "__index__",
    "__iter__",
    "item",
    "tolist",
    "_value",
)

_state = threading.local()


def _caller_site() -> str:
    """First stack frame outside jax internals and this module."""
    frame = sys._getframe(2)
    while frame is not None:
        fname = frame.f_code.co_filename
        if "/jax/" not in fname and "/jaxlib/" not in fname and not fname.endswith(
            "analysis/runtime.py"
        ):
            return f"{fname}:{frame.f_lineno}"
        frame = frame.f_back
    return "<unknown>"


@dataclasses.dataclass
class SanitizerReport:
    """Mutable tally filled in while a ``strict()`` region runs."""

    d2h: int = 0
    compiles: int = 0
    d2h_sites: dict[str, int] = dataclasses.field(default_factory=dict)
    compiled_names: list[str] = dataclasses.field(default_factory=list)

    def record_d2h(self, site: str) -> None:
        self.d2h += 1
        self.d2h_sites[site] = self.d2h_sites.get(site, 0) + 1

    def record_compile(self, name: str) -> None:
        self.compiles += 1
        self.compiled_names.append(name)

    def violations(self, *, max_d2h: int = 0, max_compiles: int = 0) -> list[str]:
        out = []
        if self.d2h > max_d2h:
            sites = ", ".join(
                f"{site} x{n}" for site, n in sorted(self.d2h_sites.items())
            )
            out.append(
                f"{self.d2h} device->host transfer(s) (allowed {max_d2h}): {sites}"
            )
        if self.compiles > max_compiles:
            names = ", ".join(self.compiled_names)
            out.append(
                f"{self.compiles} fresh compile(s) (allowed {max_compiles}): {names}"
            )
        return out


class StrictModeViolation(AssertionError):
    """Raised when a strict() region broke its transfer/recompile budget."""


# ---------------------------------------------------------------------------
# device->host sentinel
# ---------------------------------------------------------------------------


# numpy converters that reach a device array through the C-level buffer
# protocol, invisible to any ArrayImpl method patch — intercepted at the
# module-attribute level instead (callers look them up at call time).
_NP_CONVERTERS = ("asarray", "array", "asanyarray", "ascontiguousarray")


@contextlib.contextmanager
def host_transfer_sentinel(
    report: SanitizerReport,
) -> typing.Iterator[SanitizerReport]:
    """Count host-materializing reads of device arrays inside the block."""
    import numpy as np
    from jax._src import array as _jarray

    cls = _jarray.ArrayImpl
    originals: dict[str, object] = {}
    np_originals: dict[str, object] = {}

    def _needs_copy(arr: object) -> bool:
        return isinstance(arr, cls) and getattr(arr, "_npy_value", True) is None

    def wrap_method(name: str, orig: typing.Any) -> typing.Any:
        def patched(self: object, *args: object, **kwargs: object) -> object:
            depth = getattr(_state, "depth", 0)
            if depth == 0 and _needs_copy(self):
                report.record_d2h(_caller_site())
            _state.depth = depth + 1
            try:
                return orig(self, *args, **kwargs)
            finally:
                _state.depth = depth

        patched.__name__ = name
        return patched

    def wrap_property(orig_prop: property) -> property:
        return property(wrap_method("_value", orig_prop.fget))

    def wrap_np(name: str, orig: typing.Any) -> typing.Any:
        def patched(a: object, *args: object, **kwargs: object) -> object:
            depth = getattr(_state, "depth", 0)
            if depth == 0 and _needs_copy(a):
                report.record_d2h(_caller_site())
            _state.depth = depth + 1
            try:
                return orig(a, *args, **kwargs)
            finally:
                _state.depth = depth

        patched.__name__ = name
        return patched

    for name in _PATCH_NAMES:
        if name not in cls.__dict__:
            continue
        orig = cls.__dict__[name]
        originals[name] = orig
        if isinstance(orig, property):
            setattr(cls, name, wrap_property(orig))
        else:
            setattr(cls, name, wrap_method(name, orig))
    for name in _NP_CONVERTERS:
        orig = getattr(np, name, None)
        if orig is not None:
            np_originals[name] = orig
            setattr(np, name, wrap_np(name, orig))
    try:
        yield report
    finally:
        for name, orig in originals.items():
            setattr(cls, name, orig)
        for name, orig in np_originals.items():
            setattr(np, name, orig)


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------


class _CompileHandler(logging.Handler):
    def __init__(self, report: SanitizerReport) -> None:
        super().__init__(level=logging.DEBUG)
        self.report = report

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.report.record_compile(msg.split()[1])


@contextlib.contextmanager
def recompile_sentinel(
    report: SanitizerReport,
) -> typing.Iterator[SanitizerReport]:
    """Count fresh XLA lowerings inside the block via jax_log_compiles."""
    handler = _CompileHandler(report)
    logger = logging.getLogger("jax._src.interpreters.pxla")
    prev_level = logger.level
    logger.addHandler(handler)
    if logger.getEffectiveLevel() > logging.DEBUG:
        logger.setLevel(logging.DEBUG)
    with jax.log_compiles(True):
        try:
            yield report
        finally:
            logger.removeHandler(handler)
            logger.setLevel(prev_level)


# ---------------------------------------------------------------------------
# strict mode
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def strict(
    *,
    max_host_transfers: int = 0,
    max_compiles: int = 0,
    check: bool = True,
    transfer_guard: str | None = None,
) -> typing.Iterator[SanitizerReport]:
    """Assert a region performs no host transfers and no fresh compiles.

    Yields a :class:`SanitizerReport`; on exit raises
    :class:`StrictModeViolation` listing offending call sites if any
    budget was exceeded (set ``check=False`` to only count). Pass
    ``transfer_guard="disallow"`` to additionally engage JAX's native
    guard on backends that honor it (TPU/GPU) — it raises at the first
    transfer instead of tallying, so only combine it with a zero budget.
    """
    report = SanitizerReport()
    with contextlib.ExitStack() as stack:
        if transfer_guard is not None:
            stack.enter_context(jax.transfer_guard_device_to_host(transfer_guard))
        stack.enter_context(host_transfer_sentinel(report))
        stack.enter_context(recompile_sentinel(report))
        yield report
    if check:
        problems = report.violations(
            max_d2h=max_host_transfers, max_compiles=max_compiles
        )
        if problems:
            raise StrictModeViolation("; ".join(problems))
