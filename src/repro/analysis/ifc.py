"""Information-flow certifier over jaxprs (IF301–IF303).

The AST taint pass (``boundary.py``) checks the party boundary on the
*source text*: it trusts ``@tags`` annotations and cannot see through
closures, ``jit`` or adapter indirection. This pass proves the claim on
the *traced program*: ``jax.make_jaxpr`` on a real step closure, then a
forward taint/dataflow analysis over the jaxpr's equations — the same
equations XLA compiles — anchored on the identity primitives from
``marks.py``:

* ``vfl_wire_boundary[kind, direction]`` — the one legal crossing point
  (emitted by ``Transport.downlink``, the engine's uplink fan-outs, the
  serve plane's embed/token hops);
* ``vfl_dp_noise`` — a configured ``GaussianLossChannel`` just noised
  the operand;
* ``vfl_grad_mark`` — the operand derives from first-order cotangents
  of server parameters (the engine's one sanctioned server-FOO point).

Taint lattice: each var carries a set of labels from {``server``,
``grad``, ``dp``}. Inputs labelled ``server`` seed the analysis (the
caller maps pytree paths to parties); ``grad_mark`` adds ``grad``;
``dp_noise`` *replaces* taint with ``dp`` (the noised value is what DP
releases); ``wire_boundary`` records the crossing — payload kind,
direction, shape and dtype read off the jaxpr, plus the incoming taint —
and clears taint (whatever legally crossed is the sanctioned release).
Sub-jaxprs (``pjit``/``scan``/``while``/``cond``/``custom_jvp_call``/…)
are walked recursively, loop carries to a fixed point; an unknown
higher-order primitive falls back to all-inputs-to-all-outputs, a sound
overapproximation.

Rules (evaluated by :func:`check_flows` on the analysis report):

* **IF301** — no client-bound output may carry ``grad`` taint: nothing
  derived from server-parameter cotangents reaches a client except
  through the wire bottleneck (which launders taint by construction).
* **IF302** — every server→client flow must factor through a
  ``wire_boundary`` crossing, and every *downlink* crossing must be the
  scalar bottleneck the paper claims: at most ``(1+q)·block`` loss
  scalars (or ``batch`` token ids for the serve plane) per round, shape
  read off the jaxpr, not asserted.
* **IF303** — when a DP channel is configured, every loss downlink
  crossing must be noise-dominated: its operand carries ``dp`` taint
  and no raw ``server`` taint (noise added *before* the wire).

IF304 (wire-plane cross-checks) lives in ``certify.py`` — it compares
the crossing inventory against what the wire plane serializes.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, FrozenSet, List, Mapping, Optional,
                    Sequence, Tuple)

import jax
from jax import core as jax_core

from repro.analysis.findings import Finding

SERVER = "server"
GRAD = "grad"
DP = "dp"

Taint = FrozenSet[str]
_EMPTY: Taint = frozenset()


@dataclasses.dataclass(frozen=True)
class Crossing:
    """One ``wire_boundary`` equation encountered in the traced program."""
    kind: str              # "emb" | "loss" | "token"
    direction: str         # "up" | "down"
    shape: Tuple[int, ...]
    dtype: str
    taint: Taint           # taint of the operand AT the crossing

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "direction": self.direction,
                "shape": list(self.shape), "dtype": self.dtype,
                "elements": self.size, "taint": sorted(self.taint)}


@dataclasses.dataclass
class IFCReport:
    """Result of the taint pass over one traced closure."""
    out_taints: List[Taint]
    crossings: List[Crossing]
    n_dp_eqns: int

    def down(self, kind: Optional[str] = None) -> List[Crossing]:
        return [c for c in self.crossings if c.direction == "down"
                and (kind is None or c.kind == kind)]

    def up(self) -> List[Crossing]:
        return [c for c in self.crossings if c.direction == "up"]


# ------------------------------------------------------------ taint pass --

def _is_jaxpr(x: Any) -> bool:
    return isinstance(x, (jax_core.Jaxpr, jax_core.ClosedJaxpr))


def _as_open(j: Any) -> Tuple[jax_core.Jaxpr, int]:
    """(open jaxpr, number of consts) for either representation."""
    if isinstance(j, jax_core.ClosedJaxpr):
        return j.jaxpr, len(j.consts)
    return j, 0


class _Analyzer:
    """Forward taint propagation; one instance per top-level analysis."""

    def __init__(self) -> None:
        self.crossings: List[Crossing] = []
        self.n_dp_eqns = 0

    # -- var environment helpers ------------------------------------------
    @staticmethod
    def _read(env: Dict[Any, Taint], atom: Any) -> Taint:
        if isinstance(atom, jax_core.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    def run(self, jaxpr: jax_core.Jaxpr, in_taints: Sequence[Taint],
            record: bool = True) -> List[Taint]:
        """Propagate taint through ``jaxpr``; returns outvar taints.

        ``record=False`` runs a taint-only pass (used for loop fixpoint
        iterations so crossings are recorded exactly once)."""
        env: Dict[Any, Taint] = {}
        for v in jaxpr.constvars:
            env[v] = _EMPTY
        if len(jaxpr.invars) != len(in_taints):
            raise ValueError(
                f"jaxpr has {len(jaxpr.invars)} inputs, got "
                f"{len(in_taints)} taints")
        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = t

        for eqn in jaxpr.eqns:
            self._eqn(env, eqn, record)

        return [self._read(env, v) for v in jaxpr.outvars]

    # -- one equation ------------------------------------------------------
    def _eqn(self, env: Dict[Any, Taint], eqn: Any, record: bool) -> None:
        name = eqn.primitive.name
        ins = [self._read(env, a) for a in eqn.invars]
        joined: Taint = frozenset().union(*ins) if ins else _EMPTY

        if name == "vfl_wire_boundary":
            if record:
                aval = eqn.invars[0].aval
                self.crossings.append(Crossing(
                    kind=eqn.params["kind"],
                    direction=eqn.params["direction"],
                    shape=tuple(int(d) for d in aval.shape),
                    dtype=str(aval.dtype),
                    taint=ins[0]))
            # the crossing IS the sanctioned release: taint is laundered
            env[eqn.outvars[0]] = _EMPTY
            return
        if name == "vfl_dp_noise":
            if record:
                self.n_dp_eqns += 1
            env[eqn.outvars[0]] = frozenset({DP})
            return
        if name == "vfl_grad_mark":
            env[eqn.outvars[0]] = ins[0] | frozenset({GRAD, SERVER})
            return

        handler = getattr(self, f"_h_{name}", None)
        if handler is not None:
            outs = handler(eqn, ins, record)
        else:
            outs = self._generic(eqn, ins, joined, record)
        for v, t in zip(eqn.outvars, outs):
            env[v] = t

    # -- structured higher-order primitives --------------------------------
    def _h_pjit(self, eqn: Any, ins: List[Taint],
                record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["jaxpr"])
        return self.run(inner, ins, record)

    def _h_closed_call(self, eqn: Any, ins: List[Taint],
                       record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["call_jaxpr"])
        return self.run(inner, ins, record)

    def _h_remat2(self, eqn: Any, ins: List[Taint],
                  record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["jaxpr"])
        return self.run(inner, ins, record)

    def _h_custom_jvp_call(self, eqn: Any, ins: List[Taint],
                           record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["call_jaxpr"])
        if len(inner.invars) == len(ins):
            return self.run(inner, ins, record)
        return self._generic(eqn, ins, frozenset().union(*ins) if ins
                             else _EMPTY, record)

    def _h_custom_vjp_call(self, eqn: Any, ins: List[Taint],
                           record: bool) -> List[Taint]:
        return self._h_custom_jvp_call(eqn, ins, record)

    def _h_custom_vjp_call_jaxpr(self, eqn: Any, ins: List[Taint],
                                 record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["fun_jaxpr"])
        if len(inner.invars) == len(ins):
            return self.run(inner, ins, record)
        return self._generic(eqn, ins, frozenset().union(*ins) if ins
                             else _EMPTY, record)

    def _h_shard_map(self, eqn: Any, ins: List[Taint],
                     record: bool) -> List[Taint]:
        # per-shard body, invars 1:1; collectives inside are ordinary
        # elementwise-joining equations for taint purposes
        inner, _ = _as_open(eqn.params["jaxpr"])
        return self.run(inner, ins, record)

    def _h_scan(self, eqn: Any, ins: List[Taint],
                record: bool) -> List[Taint]:
        inner, _ = _as_open(eqn.params["jaxpr"])
        n_const = eqn.params["num_consts"]
        n_carry = eqn.params["num_carry"]
        consts = list(ins[:n_const])
        carry = list(ins[n_const:n_const + n_carry])
        xs = list(ins[n_const + n_carry:])
        # fixed point over the carried taints (lattice is finite)
        while True:
            outs = self.run(inner, consts + carry + xs, record=False)
            new_carry = [carry[i] | outs[i] for i in range(n_carry)]
            if new_carry == carry:
                break
            carry = new_carry
        outs = self.run(inner, consts + carry + xs, record=record)
        return [carry[i] | outs[i] for i in range(n_carry)] + outs[n_carry:]

    def _h_while(self, eqn: Any, ins: List[Taint],
                 record: bool) -> List[Taint]:
        cond_j, _ = _as_open(eqn.params["cond_jaxpr"])
        body_j, _ = _as_open(eqn.params["body_jaxpr"])
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        cond_c = list(ins[:cn])
        body_c = list(ins[cn:cn + bn])
        carry = list(ins[cn + bn:])
        while True:
            outs = self.run(body_j, body_c + carry, record=False)
            new_carry = [carry[i] | outs[i] for i in range(len(carry))]
            if new_carry == carry:
                break
            carry = new_carry
        self.run(body_j, body_c + carry, record=record)
        # control dependence: the loop predicate gates every output
        pred = self.run(cond_j, cond_c + carry, record=record)
        pred_t = pred[0] if pred else _EMPTY
        return [c | pred_t for c in carry]

    def _h_cond(self, eqn: Any, ins: List[Taint],
                record: bool) -> List[Taint]:
        pred_t = ins[0]
        ops = ins[1:]
        branch_outs = []
        for br in eqn.params["branches"]:
            inner, _ = _as_open(br)
            branch_outs.append(self.run(inner, ops, record))
        n_out = len(eqn.outvars)
        outs = []
        for i in range(n_out):
            t: Taint = pred_t
            for bo in branch_outs:
                t = t | bo[i]
            outs.append(t)
        return outs

    # -- fallback ----------------------------------------------------------
    def _generic(self, eqn: Any, ins: List[Taint], joined: Taint,
                 record: bool) -> List[Taint]:
        """Unknown primitive: all inputs flow to all outputs (sound). If
        it carries sub-jaxprs we still walk them — with every inner input
        given the joined outer taint — so crossings inside are seen."""
        sub = []
        for v in eqn.params.values():
            if _is_jaxpr(v):
                sub.append(v)
            elif isinstance(v, (tuple, list)):
                sub.extend(x for x in v if _is_jaxpr(x))
        out_t = joined
        for j in sub:
            inner, _ = _as_open(j)
            inner_outs = self.run(inner, [joined] * len(inner.invars),
                                  record)
            for t in inner_outs:
                out_t = out_t | t
        return [out_t] * len(eqn.outvars)


# ----------------------------------------------------------- entry points --

def analyze(closed: jax_core.ClosedJaxpr,
            in_taints: Sequence[Taint]) -> IFCReport:
    """Run the taint pass over a ClosedJaxpr with labelled inputs."""
    a = _Analyzer()
    outs = a.run(closed.jaxpr, list(in_taints), record=True)
    return IFCReport(out_taints=outs, crossings=a.crossings,
                     n_dp_eqns=a.n_dp_eqns)


def label_args(example_args: Sequence[Any],
               is_server: Optional[Callable[[str], bool]] = None
               ) -> List[Taint]:
    """Per-flat-leaf taints for ``example_args``, matching the invar
    order of ``jax.make_jaxpr(fn)(*example_args)``. A leaf whose pytree
    key-path contains ``server`` (default predicate) seeds SERVER."""
    pred = is_server if is_server is not None else (
        lambda p: "server" in p.lower())
    leaves = jax.tree_util.tree_flatten_with_path(tuple(example_args))[0]
    out = []
    for path, _leaf in leaves:
        p = jax.tree_util.keystr(path)
        out.append(frozenset({SERVER}) if pred(p) else _EMPTY)
    return out


def trace_and_analyze(fn: Callable[..., Any], example_args: Sequence[Any],
                      is_server: Optional[Callable[[str], bool]] = None
                      ) -> IFCReport:
    """``make_jaxpr`` + :func:`analyze`: certify ``fn``'s client-bound
    outputs (the closure must return ONLY client-held values)."""
    closed = jax.make_jaxpr(fn)(*example_args)
    return analyze(closed, label_args(example_args, is_server))


# ------------------------------------------------------------- the rules --

def check_flows(report: IFCReport, *, name: str, dp_configured: bool,
                down_limits: Mapping[str, int],
                path: str = "<certify>") -> List[Finding]:
    """Evaluate IF301–IF303 on one analysis report.

    ``down_limits`` maps downlink payload kinds to the maximum number of
    elements one crossing may carry per round (e.g. ``{"loss":
    (1+q)*block}``); a downlink crossing of any other kind is an IF302
    violation outright.

    Per-output precedence: an output carrying ``grad`` taint is IF301;
    one carrying only ``server`` taint is IF302 (flow bypassed the
    bottleneck) — so each seeded leak trips exactly one rule.
    """
    findings: List[Finding] = []

    grad_outs = [i for i, t in enumerate(report.out_taints) if GRAD in t]
    srv_outs = [i for i, t in enumerate(report.out_taints)
                if SERVER in t and GRAD not in t]
    if grad_outs:
        findings.append(Finding(
            "IF301", path, 0,
            f"{name}: client-bound output(s) {grad_outs} derive from "
            "server-parameter cotangents without passing the wire "
            "bottleneck (first-order gradient reaches a client)"))
    if srv_outs:
        findings.append(Finding(
            "IF302", path, 0,
            f"{name}: server->client flow bypasses the wire bottleneck "
            f"(server taint reaches client-bound output(s) {srv_outs} "
            "with no wire_boundary on the path)"))

    for c in report.down():
        limit = down_limits.get(c.kind)
        if limit is None:
            findings.append(Finding(
                "IF302", path, 0,
                f"{name}: unexpected downlink payload kind {c.kind!r} "
                f"(shape {list(c.shape)}); the protocol downlinks only "
                f"{sorted(down_limits)}"))
        elif c.size > limit:
            findings.append(Finding(
                "IF302", path, 0,
                f"{name}: downlink bottleneck is not scalar-shaped — "
                f"kind={c.kind} shape={list(c.shape)} carries {c.size} "
                f"elements > {limit} allowed ((1+q) scalars per "
                "activated client)"))

    if dp_configured:
        down_loss = report.down("loss")
        if not down_loss:
            findings.append(Finding(
                "IF303", path, 0,
                f"{name}: DP channel configured but no loss downlink "
                "crossing was traced (noise never reaches the wire)"))
        for c in down_loss:
            if DP not in c.taint or SERVER in c.taint:
                findings.append(Finding(
                    "IF303", path, 0,
                    f"{name}: DP channel configured but the downlink "
                    f"crossing is not noise-dominated (operand taint "
                    f"{sorted(c.taint)}; noise must be added BEFORE the "
                    "wire, as Transport.downlink does)"))

    return findings
