"""Static + runtime analysis plane for the federated repro.

- ``analysis.tags`` — annotation registry (party / wire / accounting /
  hot_loop / host_boundary decorators) the static passes read off the AST.
- ``analysis.boundary`` — party-boundary leak rules (PB1xx).
- ``analysis.jitlint`` — trace-hygiene rules (TH2xx).
- ``analysis.runtime`` — device-transfer + recompile sentinels and the
  ``strict()`` context manager (imports jax; everything else is pure AST).
- ``python -m repro.analysis src/repro --strict`` — the CI gate.
"""

from repro.analysis import tags
from repro.analysis.cli import analyze_paths
from repro.analysis.findings import Finding

__all__ = ["Finding", "analyze_paths", "tags"]
