"""Finding record + inline-suppression handling shared by both passes."""

from __future__ import annotations

import dataclasses
import re

# ``# analysis: ignore[PB101] reason...`` — reason is mandatory (BA001).
_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*ignore\[(?P<rules>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]"
    r"(?P<reason>.*)$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def key(self) -> str:
        """Stable identity for baseline matching (line numbers drift)."""
        return f"{self.rule}:{self.path}:{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]
    reason: str


def scan_suppressions(source: str) -> list[Suppression]:
    out = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is not None:
            rules = tuple(r.strip() for r in m.group("rules").split(","))
            out.append(Suppression(i, rules, m.group("reason").strip()))
    return out


def apply_suppressions(
    findings: list[Finding], suppressions: list[Suppression], path: str
) -> list[Finding]:
    """Drop findings covered by a justified inline suppression.

    A suppression on line N covers findings on lines N and N+1 (comment
    above the offending statement or trailing on the same line). An
    unjustified suppression (empty reason) is converted into a BA001
    finding instead of taking effect.
    """
    kept: list[Finding] = []
    for sup in suppressions:
        if not sup.reason:
            kept.append(
                Finding(
                    "BA001",
                    path,
                    sup.line,
                    "suppression without justification: every "
                    "`# analysis: ignore[...]` must carry a reason",
                )
            )
    covered = {
        (line, rule)
        for sup in suppressions
        if sup.reason
        for rule in sup.rules
        for line in (sup.line, sup.line + 1)
    }
    for f in findings:
        if (f.line, f.rule) not in covered:
            kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))
