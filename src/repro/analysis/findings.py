"""Finding record + inline-suppression handling shared by both passes."""

from __future__ import annotations

import dataclasses
import re

# ``# analysis: ignore[PB101] reason...`` — reason is mandatory (BA001).
_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*ignore\[(?P<rules>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]"
    r"(?P<reason>.*)$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def key(self) -> str:
        """Stable identity for baseline matching (line numbers drift)."""
        return f"{self.rule}:{self.path}:{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]
    reason: str


def scan_suppressions(source: str) -> list[Suppression]:
    out = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is not None:
            rules = tuple(r.strip() for r in m.group("rules").split(","))
            out.append(Suppression(i, rules, m.group("reason").strip()))
    return out


def apply_suppressions(
    findings: list[Finding],
    suppressions: list[Suppression],
    path: str,
    known_rules: frozenset[str] | None = None,
) -> list[Finding]:
    """Drop findings covered by a justified inline suppression.

    A suppression on line N covers findings on lines N and N+1 (comment
    above the offending statement or trailing on the same line). An
    unjustified suppression (empty reason) is converted into a BA001
    finding instead of taking effect. When ``known_rules`` is given, a
    suppression naming a rule id outside it is a BA003 finding and that
    id suppresses nothing (a typo like ``ignore[PB110]`` would otherwise
    silently rot while the finding it meant to cover keeps firing under
    a different id).
    """
    kept: list[Finding] = []
    for sup in suppressions:
        if not sup.reason:
            kept.append(
                Finding(
                    "BA001",
                    path,
                    sup.line,
                    "suppression without justification: every "
                    "`# analysis: ignore[...]` must carry a reason",
                )
            )
        if known_rules is not None:
            for rule in sup.rules:
                if rule not in known_rules:
                    kept.append(
                        Finding(
                            "BA003",
                            path,
                            sup.line,
                            f"suppression names unknown rule id {rule!r}; "
                            "it suppresses nothing (known rules: see "
                            "`python -m repro.analysis --help`)",
                        )
                    )
    covered = {
        (line, rule)
        for sup in suppressions
        if sup.reason
        for rule in sup.rules
        if known_rules is None or rule in known_rules
        for line in (sup.line, sup.line + 1)
    }
    for f in findings:
        if (f.line, f.rule) not in covered:
            kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))
