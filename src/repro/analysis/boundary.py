"""Party-boundary leak rules (PB1xx).

An intraprocedural, order-sensitive taint pass per function definition.
Party ownership and legal wire channels come from ``analysis.tags``: the
decorators applied in source (read off the AST — analyzed modules are never
imported) plus the attribute-name registries for adapter hooks that exist
only as closures on ``ModelAdapter`` fields.

Rule catalogue
--------------
PB101  client-sourced value reaches a server-side call without a
       ``@tags.wire("up", ...)`` declaration on the enclosing function.
PB102  gradient-typed value (result of jax.grad / value_and_grad / vjp /
       jac*) flows client-ward — passed to a client hook or returned from
       client-party code — without a ``@tags.wire("down", ...)``.
PB103  raw client features referenced inside server-party code.
PB104  wire declaration whose ``accounted_by`` does not name an existing
       ``@tags.accounting`` method (the channel would be unmetered).
PB105  server-evaluated losses fed to a ZOO gradient estimator without
       passing through ``Transport.downlink`` (bypasses DP noise + ledger).
"""

from __future__ import annotations

import ast
import typing

from repro.analysis import tags
from repro.analysis.astutil import (
    FuncInfo,
    attr_of_call,
    dotted,
    index_functions,
)
from repro.analysis.findings import Finding


def collect_accounting(trees: dict[str, ast.Module]) -> set[str]:
    """Project-wide ``Class.method`` qualnames tagged ``@tags.accounting``."""
    out: set[str] = set()
    for tree in trees.values():
        for fi in index_functions(tree):
            if fi.tags.accounting:
                out.add(fi.qualname)
    return out


def _is_client_source_call(node: ast.Call) -> bool:
    attr = attr_of_call(node)
    return attr in tags.CLIENT_SOURCE_ATTRS


def _is_server_sink_call(node: ast.Call) -> bool:
    attr = attr_of_call(node)
    return attr in tags.SERVER_SINK_ATTRS


def _is_client_param_read(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Subscript)
        and isinstance(node.slice, ast.Constant)
        and node.slice.value in tags.CLIENT_PARAM_KEYS
    )


def _is_gradient_source(node: ast.AST) -> bool:
    """``jax.grad`` / ``jax.value_and_grad`` / ... referenced anywhere."""
    if isinstance(node, ast.Attribute) and node.attr in tags.GRADIENT_SOURCES:
        base = dotted(node.value)
        return base is not None and base.split(".")[0] in ("jax", "jnp")
    return False


def _is_loss_source(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr in tags.SERVER_LOSS_NAMES:
        return True
    return isinstance(node, ast.Name) and node.id in tags.SERVER_LOSS_NAMES


def _contains(node: ast.AST, pred: typing.Callable[[ast.AST], bool]) -> bool:
    return any(pred(n) for n in ast.walk(node))


def _contains_tainted(node: ast.AST, tainted: set[str]) -> bool:
    return any(
        isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) and n.id in tainted
        for n in ast.walk(node)
    )


def _is_downlink_call(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and attr_of_call(node) in tags.DOWNLINK_SANITIZERS
    )


def _store_names(stmt: ast.stmt) -> set[str]:
    out: set[str] = set()
    targets: list[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) and stmt.target is not None:
        targets = [stmt.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                out.add(n.id)
    return out


def _iter_statements(body: list[ast.stmt]) -> typing.Iterator[ast.stmt]:
    """Statements in source order, not descending into nested defs."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        yield stmt
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                yield from _iter_statements(sub)
        for handler in getattr(stmt, "handlers", []) or []:
            yield from _iter_statements(handler.body)


class _FunctionTaint:
    """Order-sensitive taint state for one function body."""

    def __init__(self, fi: FuncInfo, path: str, accounting: set[str]) -> None:
        self.fi = fi
        self.path = path
        self.accounting = accounting
        self.client: set[str] = set()
        self.grad: set[str] = set()
        self.loss: set[str] = set()
        self.findings: list[Finding] = []

    # -- sources -----------------------------------------------------------
    def _expr_client(self, node: ast.AST) -> bool:
        return (
            _contains(node, lambda n: isinstance(n, ast.Call) and _is_client_source_call(n))
            or _contains(node, _is_client_param_read)
            or _contains_tainted(node, self.client)
        )

    def _expr_grad(self, node: ast.AST) -> bool:
        return _contains(node, _is_gradient_source) or _contains_tainted(node, self.grad)

    def _expr_loss(self, node: ast.AST) -> bool:
        return _contains(node, _is_loss_source) or _contains_tainted(node, self.loss)

    # -- declarations ------------------------------------------------------
    def _wire(self, direction: str) -> dict[str, str] | None:
        return self.fi.wire_spec(direction)

    def _flag(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(Finding(rule, self.path, node.lineno, msg))

    # -- sink checks -------------------------------------------------------
    def _check_call(self, call: ast.Call) -> None:
        attr = attr_of_call(call)
        args = list(call.args) + [kw.value for kw in call.keywords]
        if _is_server_sink_call(call):
            crossing = any(self._expr_client(a) for a in args)
            if crossing and self._wire("up") is None:
                self._flag(
                    call,
                    "PB101",
                    f"client-sourced value flows into server-side `{attr}` "
                    "without a @tags.wire(\"up\", ...) declaration on the "
                    "enclosing function",
                )
        if attr in tags.CLIENT_SOURCE_ATTRS or (
            attr is not None and attr.startswith("client_") and attr not in tags.DOWNLINK_CONSUMERS
        ):
            if any(self._expr_grad(a) for a in args) and self._wire("down") is None:
                self._flag(
                    call,
                    "PB102",
                    f"gradient-typed value passed into client-side `{attr}` "
                    "without a @tags.wire(\"down\", ...) declaration",
                )
        if attr in tags.DOWNLINK_CONSUMERS:
            dirty = [
                a
                for a in args
                if self._expr_loss(a) and not _contains(a, _is_downlink_call)
            ]
            if dirty:
                self._flag(
                    call,
                    "PB105",
                    f"server-evaluated losses reach `{attr}` without passing "
                    "through Transport.downlink (DP noise + ledger bypassed)",
                )

    def _check_return(self, stmt: ast.Return) -> None:
        if stmt.value is None:
            return
        party = self.fi.party()
        clientward = party == "client" or self.fi.node.name.startswith("client_")
        if clientward and self._expr_grad(stmt.value) and self._wire("down") is None:
            self._flag(
                stmt,
                "PB102",
                "gradient-typed value returned from client-party code "
                "without a @tags.wire(\"down\", ...) declaration",
            )

    def _check_raw_features(self, stmt: ast.stmt) -> None:
        party = self.fi.party()
        serverside = party == "server" or self.fi.node.name.startswith("server_")
        if not serverside:
            return
        for n in ast.walk(stmt):
            if (
                isinstance(n, ast.Name)
                and isinstance(n.ctx, ast.Load)
                and n.id in tags.RAW_FEATURE_PARAMS
            ):
                self._flag(
                    n,
                    "PB103",
                    f"raw client feature `{n.id}` referenced inside "
                    "server-party code",
                )

    # -- driver ------------------------------------------------------------
    def run(self) -> list[Finding]:
        self._check_wire_accounting()
        for stmt in _iter_statements(self.fi.node.body):
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    self._check_call(n)
            if isinstance(stmt, ast.Return):
                self._check_return(stmt)
            self._check_raw_features(stmt)
            self._apply_assignment(stmt)
        return self.findings

    def _apply_assignment(self, stmt: ast.stmt) -> None:
        value = getattr(stmt, "value", None)
        names = _store_names(stmt)
        if value is None or not names:
            return
        if _contains(value, _is_downlink_call):
            # Rebinding through Transport.downlink launders loss taint:
            # the channel adds DP noise and meters the release.
            self.loss -= names
        elif self._expr_loss(value):
            self.loss |= names
        if self._expr_client(value):
            self.client |= names
        if self._expr_grad(value):
            self.grad |= names

    def _check_wire_accounting(self) -> None:
        for spec in self.fi.tags.wires:
            target = spec.get("accounted_by", "")
            if target not in self.accounting:
                self._flag(
                    self.fi.node,
                    "PB104",
                    f"wire declaration names accounted_by={target!r}, which "
                    "is not an existing @tags.accounting method — the "
                    "channel would be unmetered",
                )


def check_module(
    path: str, tree: ast.Module, accounting: set[str]
) -> list[Finding]:
    findings: list[Finding] = []
    for fi in index_functions(tree):
        findings.extend(_FunctionTaint(fi, path, accounting).run())
    return findings
