"""Jaxpr boundary anchors: identity primitives the certifier keys on.

The AST taint pass (``repro.analysis.boundary``) trusts source-level
``@tags`` annotations; the jaxpr certifier (``repro.analysis.ifc``)
instead proves the party boundary on the program JAX actually traces.
For that it needs *anchors in the jaxpr* — equations that mark where a
value legally crosses the wire, where DP noise is applied, and which
values are first-order cotangents of server parameters.

These marks are custom JAX primitives that are **identities at
runtime**: their MLIR lowering forwards the operand unchanged, so the
compiled HLO — and therefore every bitwise-equality guarantee the repo
makes (split == global decode, kill/resume == straight-through, wire
worker == in-proc) — is untouched. Each primitive carries batching,
JVP and transpose rules so it composes with ``vmap`` (the engine vmaps
client grad closures over block rows), ``scan``, ``jit`` and autodiff.

Anchors
-------
* :func:`wire_boundary` — the value crosses the party boundary here.
  ``kind`` names the payload (``"emb"``/``"loss"``/``"token"``, matching
  the wire plane's frame tags), ``direction`` is ``"up"`` (client →
  server) or ``"down"`` (server → client). Emitted by
  ``Transport.downlink`` (the ONE legal loss downlink), the engine's
  client-lane fan-outs, and the serve plane's embed/token hops.
* :func:`dp_noise` — the operand has just been Gaussian-noised by a
  configured ``GaussianLossChannel``. Emitted inside
  ``Transport.downlink`` between the noise add and the wire mark, so
  the certifier can check DP happens *before* the wire (IF303).
* :func:`grad_mark` — the operand is (derived from) a first-order
  cotangent of server parameters. Emitted at the engine's one
  sanctioned server-FOO point (``async_engine._server_update``); IF301
  proves this taint never reaches a client-bound output. The AST rule
  PB102 covers *textual* ``jax.grad`` calls outside the engine.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

from jax.interpreters import ad, batching, mlir

try:  # jax >= 0.4.27 exposes Primitive via jax.extend
    from jax.extend.core import Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive  # type: ignore[attr-defined,no-redef]

import jax

# Payload kinds a wire_boundary mark may carry. "emb" and "loss" mirror
# repro.wire.codec.DATA_TAGS (training-plane frames); "token" is the
# serve plane's per-step token downlink (metered by Transport.account_serve,
# not framed by the wire codec).
WIRE_KINDS: Tuple[str, ...] = ("emb", "loss", "token")
DIRECTIONS: Tuple[str, ...] = ("up", "down")


def _identity_primitive(name: str) -> Primitive:
    """A unary primitive that is the identity at runtime.

    impl/abstract_eval return the operand; the MLIR lowering forwards
    the SSA value itself (no op is emitted, compiled bytes identical);
    batching maps straight through; the primitive is linear, so JVP and
    transpose are identities too.
    """
    prim = Primitive(name)

    def _impl(x: Any, **_: Any) -> Any:
        return x

    def _abstract(x: Any, **_: Any) -> Any:
        return x

    def _lowering(ctx: Any, x: Any, **_: Any) -> Sequence[Any]:
        return [x]

    def _batch(args: Sequence[Any], dims: Sequence[Any],
               **params: Any) -> Tuple[Any, Any]:
        (x,), (d,) = args, dims
        return prim.bind(x, **params), d

    def _transpose(ct: Any, x: Any, **params: Any) -> Sequence[Any]:
        return [ct]

    prim.def_impl(_impl)
    prim.def_abstract_eval(_abstract)
    mlir.register_lowering(prim, _lowering)
    batching.primitive_batchers[prim] = _batch
    ad.deflinear2(prim, _transpose)
    return prim


wire_boundary_p = _identity_primitive("vfl_wire_boundary")
dp_noise_p = _identity_primitive("vfl_dp_noise")
grad_mark_p = _identity_primitive("vfl_grad_mark")


def wire_boundary(x: Any, *, kind: str, direction: str) -> Any:
    """Mark ``x`` (array or pytree) as crossing the party boundary."""
    if kind not in WIRE_KINDS:
        raise ValueError(f"unknown wire kind {kind!r}; expected {WIRE_KINDS}")
    if direction not in DIRECTIONS:
        raise ValueError(
            f"unknown direction {direction!r}; expected {DIRECTIONS}")
    return jax.tree_util.tree_map(
        lambda leaf: wire_boundary_p.bind(leaf, kind=kind,
                                          direction=direction), x)


def dp_noise(x: Any) -> Any:
    """Mark ``x`` as the output of a configured DP noise channel."""
    return jax.tree_util.tree_map(dp_noise_p.bind, x)


def grad_mark(x: Any) -> Any:
    """Mark ``x`` as derived from server-parameter cotangents."""
    return jax.tree_util.tree_map(grad_mark_p.bind, x)
