"""Trace-hygiene rules (TH2xx).

TH201  host sync / device upload in serve-plane hot code: ``np.asarray``,
       ``.item()``, ``.tolist()``, ``block_until_ready`` inside
       for/while loops of the hot modules (scheduler.py, serving.py,
       launch/serve.py), and — in ``@tags.hot_loop`` bodies — anywhere,
       plus ``float()/int()/bool()`` coercions and per-step
       ``jnp.asarray``/``device_put`` uploads.
TH202  Python branch (``if``/``while``/ternary) on a traced value inside
       a jit/scan/vmap-traced function. Shape/dtype/None checks are
       static and stay legal.
TH203  dtype-unstable scan carry: ``.astype(<literal dtype>)`` inside a
       ``lax.scan`` body. Anchor to a runtime dtype (``x.dtype``) instead —
       a literal flips the carry dtype when inputs arrive in another
       precision and forces a silent retrace every call (PR 5's
       ``_causal_conv`` bug).
TH204  leftover debug instrumentation: ``jax.debug.*`` anywhere,
       ``print``/``breakpoint`` inside traced functions.
"""

from __future__ import annotations

import ast
import typing

from repro.analysis import tags
from repro.analysis.astutil import (
    FuncInfo,
    attr_of_call,
    call_name,
    dotted,
    index_functions,
)
from repro.analysis.findings import Finding

_TRACING_TRANSFORMS = frozenset(
    {"scan", "jit", "vmap", "pmap", "cond", "while_loop", "fori_loop", "shard_map"}
)
_SCAN_LIKE = frozenset({"scan"})
_STATIC_ATTRS = frozenset(
    {"shape", "ndim", "dtype", "size", "itemsize", "sharding", "aval", "weak_type"}
)
_STATIC_CALLS = frozenset({"isinstance", "len", "hasattr", "callable", "getattr", "type"})


def _callee_function_names(call: ast.Call) -> list[str]:
    """Local function names a tracing transform is applied to.

    Handles ``lax.scan(body, ...)``, ``jax.jit(step)``, and
    ``scan(functools.partial(body, x), ...)``.
    """
    if not call.args:
        return []
    target = call.args[0]
    if isinstance(target, ast.Call) and (call_name(target) or "").endswith("partial"):
        target = target.args[0] if target.args else target
    name = dotted(target)
    return [name] if name else []


def _static_argnames(call: ast.Call) -> set[str]:
    out: set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
    return out


class _TracedInfo(typing.NamedTuple):
    kinds: set[str]
    static_names: set[str]


def find_traced(tree: ast.Module, funcs: list[FuncInfo]) -> dict[str, _TracedInfo]:
    """Map local function name -> tracing context it is lowered under."""
    traced: dict[str, _TracedInfo] = {}

    def mark(name: str, kind: str, call: ast.Call | None) -> None:
        info = traced.setdefault(name, _TracedInfo(set(), set()))
        info.kinds.add(kind)
        if call is not None:
            info.static_names.update(_static_argnames(call))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            leaf = attr_of_call(node)
            if leaf in _TRACING_TRANSFORMS:
                for name in _callee_function_names(node):
                    mark(name.rsplit(".", 1)[-1], leaf, node)
    for fi in funcs:
        for deco in fi.node.decorator_list:
            target = deco.func if isinstance(deco, ast.Call) else deco
            name = dotted(target) or ""
            if name.rsplit(".", 1)[-1] == "jit" or name.endswith("jit"):
                mark(fi.node.name, "jit", deco if isinstance(deco, ast.Call) else None)
    return traced


def _body_statements(
    fn: ast.FunctionDef | ast.AsyncFunctionDef,
) -> typing.Iterator[ast.stmt]:
    stack: list[ast.stmt] = list(reversed(fn.body))
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        yield stmt
        children: list[ast.stmt] = []
        for field in ("body", "orelse", "finalbody"):
            children.extend(getattr(stmt, field, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            children.extend(handler.body)
        stack.extend(reversed(children))


def _walk_no_nested_defs(stmts: typing.Iterable[ast.stmt]) -> typing.Iterator[ast.AST]:
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        stack: list[ast.AST] = [stmt]
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# TH201 — host syncs / uploads in hot code
# ---------------------------------------------------------------------------


def _host_sync_kind(node: ast.Call, *, in_hot_loop: bool) -> str | None:
    name = call_name(node)
    leaf = attr_of_call(node)
    if name in tags.HOST_SYNC_FUNCS:
        return f"device->host `{name}`"
    if isinstance(node.func, ast.Attribute) and leaf in tags.HOST_SYNC_METHODS:
        return f"device->host `.{leaf}()`"
    if in_hot_loop:
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in tags.HOST_SYNC_BUILTINS
            and node.args
            and not isinstance(node.args[0], ast.Constant)
        ):
            return f"device->host `{node.func.id}()` coercion"
        if name in tags.DEVICE_PUT_FUNCS:
            return f"per-step host->device upload `{name}`"
    return None


def _check_host_syncs(
    fi: FuncInfo, path: str, hot_module: bool, findings: list[Finding]
) -> None:
    hot_tags = [t for t in fi.chain_tags()]
    if any(t.host_boundary for t in hot_tags):
        return
    is_hot_loop = any(t.hot_loop for t in hot_tags)

    def flag(call: ast.Call, kind: str, where: str) -> None:
        findings.append(
            Finding(
                "TH201",
                path,
                call.lineno,
                f"{kind} {where} — steady-state decode must stay on device "
                "(hoist out of the loop, batch per wave, or mark a "
                "@tags.host_boundary with justification)",
            )
        )

    if is_hot_loop:
        for node in _walk_no_nested_defs(fi.node.body):
            if isinstance(node, ast.Call):
                kind = _host_sync_kind(node, in_hot_loop=True)
                if kind:
                    flag(node, kind, "in a @tags.hot_loop body")
        return
    if hot_module:
        for stmt in _body_statements(fi.node):
            if isinstance(stmt, (ast.For, ast.While)):
                for node in _walk_no_nested_defs(stmt.body + stmt.orelse):
                    if isinstance(node, ast.Call):
                        kind = _host_sync_kind(node, in_hot_loop=False)
                        if kind:
                            flag(node, kind, "inside a serve-plane loop")


# ---------------------------------------------------------------------------
# TH202 — Python branching on traced values
# ---------------------------------------------------------------------------


def _static_occurrence_ids(cond: ast.AST) -> set[int]:
    ok: set[int] = set()
    for n in ast.walk(cond):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            ok.update(id(x) for x in ast.walk(n))
        elif isinstance(n, ast.Call):
            leaf = attr_of_call(n)
            if leaf in _STATIC_CALLS:
                ok.update(id(x) for x in ast.walk(n))
        elif isinstance(n, ast.Compare) and any(
            isinstance(c, ast.Constant) and c.value is None for c in n.comparators
        ):
            ok.update(id(x) for x in ast.walk(n))
    return ok


def _tainted_occurrence(node: ast.AST, tainted: set[str]) -> ast.Name | None:
    static = _static_occurrence_ids(node)
    for n in ast.walk(node):
        if (
            isinstance(n, ast.Name)
            and isinstance(n.ctx, ast.Load)
            and n.id in tainted
            and id(n) not in static
        ):
            return n
    return None


def _check_traced_branches(
    fi: FuncInfo, path: str, info: _TracedInfo, findings: list[Finding]
) -> None:
    args = fi.node.args
    params = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    tainted = {p for p in params if p not in info.static_names and p != "self"}

    for stmt in _body_statements(fi.node):
        value = getattr(stmt, "value", None)
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)) and value is not None:
            if _tainted_occurrence(value, tainted) is not None:
                for t in ast.walk(stmt):
                    if isinstance(t, ast.Name) and isinstance(t.ctx, ast.Store):
                        tainted.add(t.id)
        conds: list[ast.expr] = []
        if isinstance(stmt, (ast.If, ast.While)):
            conds.append(stmt.test)
        if isinstance(stmt, ast.Assert):
            conds.append(stmt.test)
        for node in _walk_no_nested_defs([stmt]):
            if isinstance(node, ast.IfExp):
                conds.append(node.test)
        for cond in conds:
            hit = _tainted_occurrence(cond, tainted)
            if hit is not None:
                findings.append(
                    Finding(
                        "TH202",
                        path,
                        cond.lineno,
                        f"Python branch on traced value `{hit.id}` inside a "
                        f"{'/'.join(sorted(info.kinds))}-traced function — "
                        "use lax.cond/jnp.where or hoist to a static argument",
                    )
                )


# ---------------------------------------------------------------------------
# TH203 — dtype-unstable scan carries
# ---------------------------------------------------------------------------


def _literal_astypes(node: ast.AST) -> typing.Iterator[ast.Call]:
    """``.astype(X)`` calls where X is not anchored to a runtime ``.dtype``."""
    for n in ast.walk(node):
        if (
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr == "astype"
            and n.args
        ):
            arg = n.args[0]
            if not (isinstance(arg, ast.Attribute) and arg.attr == "dtype"):
                yield n


def _check_scan_carry_dtype(
    fi: FuncInfo, path: str, info: _TracedInfo, findings: list[Finding]
) -> None:
    """Literal casts are fine on xs/outputs (f32 accumulation); they are a
    retrace hazard only when they (re)define a carry element, whose dtype
    must be invariant across iterations."""
    if not (info.kinds & _SCAN_LIKE):
        return
    args = fi.node.args
    params = [a.arg for a in args.posonlyargs + args.args]
    carry_names: set[str] = {params[0]} if params else set()
    for stmt in _body_statements(fi.node):
        if (
            isinstance(stmt, ast.Assign)
            and isinstance(stmt.value, ast.Name)
            and stmt.value.id in carry_names
        ):
            for t in stmt.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                        carry_names.add(n.id)

    def flag(call: ast.Call) -> None:
        findings.append(
            Finding(
                "TH203",
                path,
                call.lineno,
                "literal-dtype `.astype(...)` feeding a scan carry — anchor "
                "to the carry's runtime dtype (`.astype(x.dtype)`) so the "
                "carry dtype cannot flip between trace and steady state "
                "and force a silent retrace",
            )
        )

    for stmt in _body_statements(fi.node):
        targets: set[str] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                        targets.add(n.id)
        elif isinstance(stmt, ast.AugAssign) and isinstance(stmt.target, ast.Name):
            targets.add(stmt.target.id)
        value = getattr(stmt, "value", None)
        if targets & carry_names and value is not None:
            for call in _literal_astypes(value):
                flag(call)
        if (
            isinstance(stmt, ast.Return)
            and isinstance(stmt.value, ast.Tuple)
            and len(stmt.value.elts) >= 2
        ):
            for call in _literal_astypes(stmt.value.elts[0]):
                flag(call)


# ---------------------------------------------------------------------------
# TH204 — leftover debug instrumentation
# ---------------------------------------------------------------------------


def _check_debug_leftovers(
    tree: ast.Module, path: str, traced: dict[str, _TracedInfo],
    funcs: list[FuncInfo], findings: list[Finding],
) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name.startswith("jax.debug.") or name.startswith("debug.print"):
                findings.append(
                    Finding(
                        "TH204", path, node.lineno,
                        f"leftover `{name}` call — remove debug "
                        "instrumentation before shipping",
                    )
                )
            elif isinstance(node.func, ast.Name) and node.func.id == "breakpoint":
                findings.append(
                    Finding("TH204", path, node.lineno, "leftover `breakpoint()` call")
                )
    for fi in funcs:
        if fi.node.name not in traced:
            continue
        for node in _walk_no_nested_defs(fi.node.body):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"
            ):
                findings.append(
                    Finding(
                        "TH204", path, node.lineno,
                        "`print()` inside a traced function — prints once per "
                        "trace, not per step; use jax.debug.print during "
                        "development and remove before shipping",
                    )
                )


def check_module(path: str, tree: ast.Module) -> list[Finding]:
    findings: list[Finding] = []
    funcs = index_functions(tree)
    traced = find_traced(tree, funcs)
    hot_module = any(path.endswith(m) for m in tags.HOT_MODULES)
    for fi in funcs:
        _check_host_syncs(fi, path, hot_module, findings)
        info = traced.get(fi.node.name)
        if info is not None:
            _check_traced_branches(fi, path, info, findings)
            _check_scan_carry_dtype(fi, path, info, findings)
    _check_debug_leftovers(tree, path, traced, funcs, findings)
    return findings
