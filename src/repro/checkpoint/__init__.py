from repro.checkpoint.io import load_checkpoint, load_tree, save_checkpoint

__all__ = ["load_checkpoint", "load_tree", "save_checkpoint"]
