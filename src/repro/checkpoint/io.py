"""Checkpointing: flat-key npz + json manifest, sharding-aware restore.

Arrays are gathered to host (fully-addressable) on save; on restore each
leaf is device_put with the requested sharding (or left on default device).

Writes are ATOMIC per file: every npz/manifest is written to a temp file
in the target directory, fsync'd, then ``os.replace``'d into place — a
process killed mid-``save_checkpoint`` (or mid-``fed.save``) leaves
either the previous complete checkpoint or the new complete one on disk,
never a torn npz or a half-written ``session.json``. The manifest is
replaced LAST, so its presence always certifies arrays it can decode.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Callable, Optional

import jax
import numpy as np

_SEP = "::"

# npz cannot round-trip numpy extension dtypes (bfloat16/float8 have void
# descrs): such leaves are stored as a same-width uint view and viewed
# back on load from the manifest's true dtype
_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def encode_array(arr: np.ndarray) -> np.ndarray:
    """Lossless storage view of ``arr``: extension dtypes (bfloat16,
    float8 — void descrs npz/raw buffers cannot carry) become a same-width
    uint view; everything else passes through unchanged. The true dtype
    must travel out of band (manifest / wire header) for
    :func:`decode_array` to restore it. Shared by the checkpoint plane and
    the ``repro.wire`` message codec, so a serialized byte is the same
    byte in both."""
    if arr.dtype.kind == "V":
        return arr.view(_UINT_FOR_SIZE[arr.dtype.itemsize])
    return arr


def decode_array(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """Inverse of :func:`encode_array` given the recorded true dtype."""
    dt = np.dtype(dtype_str)
    return arr.view(dt) if (dt.kind == "V" and arr.dtype != dt) else arr


# internal spellings kept for the save/load paths below
_encode = encode_array
_decode = decode_array


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def atomic_write(path: str, write_fn: Callable[[Any], None],
                 mode: str = "wb") -> None:
    """Write ``path`` atomically: ``write_fn(file)`` runs against a temp
    file in the same directory, which is fsync'd and ``os.replace``'d
    over ``path`` only after the write completed. A crash at any point
    leaves the previous ``path`` (or nothing) — never a torn file."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_checkpoint(path: str, params, *, step: int = 0,
                    metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    # arrays first, manifest last: a manifest on disk always describes a
    # complete arrays file (each file individually atomic)
    atomic_write(os.path.join(path, "arrays.npz"),
                 lambda f: np.savez(f, **{k: _encode(v)
                                          for k, v in flat.items()}))
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    atomic_write(os.path.join(path, "manifest.json"),
                 lambda f: json.dump(manifest, f, indent=2), mode="w")


def load_tree(path: str):
    """Self-describing restore: rebuild the nested-dict pytree purely from
    the manifest's flat keys (no ``like`` structure needed — what the
    party-scoped ``Federation.restore`` uses, where the reader may not be
    able to construct the writer's structure up front).

    Only string-keyed dict nesting round-trips this way; trees with
    list/tuple internal nodes must go through :func:`load_checkpoint`.
    Returns (tree, step, metadata)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    tree: dict = {}
    for key in manifest["keys"]:
        parts = key.split(_SEP) if key else []
        if any(p.startswith("[") for p in parts):
            raise ValueError(
                f"load_tree only rebuilds dict-nested trees; key {key!r} "
                "has a sequence index — restore via load_checkpoint(like)")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jax.numpy.asarray(
            _decode(data[key], manifest["dtypes"][key]))
    return tree, manifest["step"], manifest.get("metadata", {})


def load_checkpoint(path: str, like, *, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (a params pytree or spec tree).
    Returns (params, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like[0]:
        key = _SEP.join(_path_str(p) for p in path_k)
        arr = _decode(data[key], manifest["dtypes"][key])
        leaves.append(arr)
    params = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, shardings)
    else:
        params = jax.tree.map(jax.numpy.asarray, params)
    return params, manifest["step"]
