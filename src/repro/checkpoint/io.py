"""Checkpointing: flat-key npz + json manifest, sharding-aware restore.

Arrays are gathered to host (fully-addressable) on save; on restore each
leaf is device_put with the requested sharding (or left on default device).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, params, *, step: int = 0,
                    metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, like, *, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (a params pytree or spec tree).
    Returns (params, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like[0]:
        key = _SEP.join(_path_str(p) for p in path_k)
        arr = data[key]
        leaves.append(arr)
    params = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, shardings)
    else:
        params = jax.tree.map(jax.numpy.asarray, params)
    return params, manifest["step"]
