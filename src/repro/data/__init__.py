from repro.data.pipeline import BatchIterator
from repro.data.synthetic import (
    lm_token_batches,
    make_classification,
    vertical_partition,
)

__all__ = ["lm_token_batches", "make_classification", "vertical_partition",
           "BatchIterator"]
