from repro.data.synthetic import (
    lm_token_batches,
    make_classification,
    vertical_partition,
)
from repro.data.pipeline import BatchIterator

__all__ = ["lm_token_batches", "make_classification", "vertical_partition",
           "BatchIterator"]
