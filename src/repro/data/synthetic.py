"""Synthetic data generators (the container is offline — see DESIGN.md §3).

* ``make_classification`` — class-prototype Gaussians with distractor
  dimensions; shape/statistics-matched stand-in for flattened MNIST in the
  paper's base experiments (n_features=784, 10 classes).
* ``vertical_partition`` — the VFL feature split: each of M clients gets an
  equal, disjoint feature slice of every sample (paper §VI-A-a).
* ``lm_token_batches`` — Zipf-distributed token streams with local n-gram
  structure for the LM-scale configs (so CE actually decreases when the
  model learns).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def make_classification(seed: int, n: int, n_features: int, n_classes: int,
                        *, sep: float = 2.0, noise: float = 1.0,
                        informative_frac: float = 0.5
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (n, n_features) float32, y (n,) int32)."""
    rng = np.random.default_rng(seed)
    n_inf = max(int(n_features * informative_frac), n_classes)
    protos = rng.normal(0, sep, (n_classes, n_inf)).astype(np.float32)
    y = rng.integers(0, n_classes, n).astype(np.int32)
    X_inf = protos[y] + rng.normal(0, noise, (n, n_inf)).astype(np.float32)
    X_noise = rng.normal(0, noise, (n, n_features - n_inf)).astype(np.float32)
    X = np.concatenate([X_inf, X_noise], axis=1)
    perm = rng.permutation(n_features)          # spread info across clients
    return X[:, perm], y


def vertical_partition(X: np.ndarray, n_clients: int) -> np.ndarray:
    """X (n, f) -> (M, n, f//M): disjoint per-client feature slices."""
    n, f = X.shape
    per = f // n_clients
    return np.stack([X[:, m * per:(m + 1) * per] for m in range(n_clients)])


def lm_token_batches(seed: int, vocab: int, batch: int, seq: int,
                     *, n_batches: int = 0) -> Iterator[dict]:
    """Zipfian unigram + first-order chain structure — learnable synthetic
    text. Yields {"tokens", "labels"} int32 (labels == tokens; the loss
    shifts)."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition structure over a Zipf unigram base
    base = 1.0 / np.arange(1, vocab + 1) ** 1.1
    base /= base.sum()
    n_modes = min(64, vocab)
    jump = rng.integers(0, vocab, n_modes)

    i = 0
    while n_batches == 0 or i < n_batches:
        toks = rng.choice(vocab, size=(batch, seq), p=base).astype(np.int32)
        # inject deterministic bigrams: after token t, with p=.5, emit
        # jump[t % n_modes] — gives the model something to learn
        mask = rng.random((batch, seq - 1)) < 0.5
        nxt = jump[toks[:, :-1] % n_modes]
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        yield {"tokens": toks, "labels": toks.copy()}
        i += 1
