"""Sharding-aware batch feeding."""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np


class BatchIterator:
    """Wraps a numpy batch iterator; device_puts each batch with the given
    shardings (global arrays under a mesh, single-device otherwise)."""

    def __init__(self, it: Iterator[dict], shardings: Optional[dict] = None):
        self._it = it
        self._shardings = shardings

    def __iter__(self):
        return self

    def __next__(self):
        batch = next(self._it)
        if self._shardings is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s),
            batch, self._shardings)


def epoch_minibatches(rng: np.random.Generator, n: int, batch_size: int):
    """Shuffled index minibatches covering one epoch."""
    idx = rng.permutation(n)
    for s in range(0, n - batch_size + 1, batch_size):
        yield idx[s:s + batch_size]
