"""Tagged, versioned wire messages and their byte codec.

A :class:`WireMessage` is the unit every ``repro.wire`` backend moves: a
protocol ``tag`` (data plane: ``emb``/``loss`` — the §V wire; control
plane: ``act``/``skip``/``collect``/``params``/``stop``), the sending
party, the global round, a small JSON ``meta`` dict and a named payload
of arrays.

The encoding is deliberately boring and exact:

    [!4sHI  magic | version | header_len] [header JSON] [raw leaf bytes]

Every payload leaf is serialized through
:func:`repro.checkpoint.io.encode_array` — the checkpoint plane's
uint-view codec — so extension dtypes (bfloat16 client embeddings)
round-trip losslessly and a byte on the wire is the same byte a
checkpoint would store. The header records each leaf's true dtype for
:func:`decode_array` on the far side. Frames carried by a stream
transport get a fixed 8-byte length prefix (:func:`frame`); the prefix is
part of the measured wire cost, so ``LoopbackBackend`` and
``SocketBackend`` report identical per-message byte counts.

Version 2 adds a CRC32 of the payload body to the header, so a frame
bitten by a faulty transport (bit flip, truncation) raises a typed
:class:`FrameCorruption` instead of decoding garbage arrays. Version 1
frames (no checksum) stay readable — the bump is backward-compatible on
the read side. Any OTHER version is still a hard protocol break:
:func:`decode` rejects it instead of guessing at field layouts.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.checkpoint.io import decode_array, encode_array

WIRE_VERSION = 2
_READABLE_VERSIONS = (1, 2)         # v1 = pre-checksum frames
_MAGIC = b"VFLW"
_HEAD = struct.Struct("!4sHI")      # magic, version, header length
_LENGTH = struct.Struct("!Q")       # stream frame prefix
FRAME_OVERHEAD = _LENGTH.size       # beyond len(encode(msg))


class FrameCorruption(ValueError):
    """A frame failed its integrity checks (truncated body, CRC32
    mismatch, or an unparseable header) — the bytes are damaged, not
    merely foreign."""

# the §V data plane (metered in the privacy ledger) vs scheduler/worker
# bookkeeping (metered separately as control bytes, never in the ledger);
# ping/pong is the liveness heartbeat — an empty control round-trip
DATA_TAGS = ("emb", "loss")
CONTROL_TAGS = ("act", "skip", "collect", "params", "stop", "ping", "pong")


@dataclasses.dataclass
class WireMessage:
    tag: str
    sender: str                                   # "client" | "server"
    round: int = 0
    meta: dict = dataclasses.field(default_factory=dict)
    payload: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.tag not in DATA_TAGS + CONTROL_TAGS:
            raise ValueError(f"unknown wire tag {self.tag!r}")


def encode(msg: WireMessage) -> bytes:
    """Serialize a message (header + raw leaf bytes, no length prefix)."""
    names = sorted(msg.payload)
    arrays = {k: np.asarray(msg.payload[k]) for k in names}
    # note: ascontiguousarray promotes 0-d to (1,), so the header records
    # the TRUE shape from `arrays` (scalar losses must stay scalars)
    enc = {k: encode_array(np.ascontiguousarray(v))
           for k, v in arrays.items()}
    body = b"".join(enc[k].tobytes() for k in names)
    header = {
        "v": WIRE_VERSION, "tag": msg.tag, "sender": msg.sender,
        "round": int(msg.round), "meta": msg.meta,
        "crc": zlib.crc32(body),
        "leaves": [[k, list(arrays[k].shape), str(arrays[k].dtype),
                    str(enc[k].dtype)] for k in names],
    }
    hb = json.dumps(header, sort_keys=True,
                    separators=(",", ":")).encode("utf-8")
    return _HEAD.pack(_MAGIC, WIRE_VERSION, len(hb)) + hb + body


def decode(buf: bytes) -> WireMessage:
    """Inverse of :func:`encode`.

    Rejects foreign/forward-version frames with ``ValueError``; raises
    :class:`FrameCorruption` for frames that claim a readable version but
    fail their integrity checks (short buffer, CRC32 mismatch, broken
    header JSON)."""
    if len(buf) < _HEAD.size:
        raise FrameCorruption(f"truncated wire frame ({len(buf)} bytes)")
    magic, version, hlen = _HEAD.unpack_from(buf, 0)
    if magic != _MAGIC:
        raise ValueError(f"not a wire frame (magic {magic!r})")
    if version not in _READABLE_VERSIONS:
        raise ValueError(
            f"wire protocol version {version} not in "
            f"{_READABLE_VERSIONS}; refusing to guess at the frame layout")
    off = _HEAD.size
    if len(buf) < off + hlen:
        raise FrameCorruption(
            f"truncated wire frame: header claims {hlen} bytes, "
            f"{len(buf) - off} present")
    try:
        header = json.loads(buf[off:off + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameCorruption(f"unparseable frame header: {e}") from e
    off += hlen
    body = buf[off:]
    need = sum(int(np.prod(shape, dtype=np.int64))
               * np.dtype(wire_dtype).itemsize
               for _, shape, _, wire_dtype in header["leaves"])
    if len(body) < need:
        raise FrameCorruption(
            f"truncated wire frame body: {len(body)}/{need} payload bytes")
    if version >= 2 and zlib.crc32(body[:need]) != header["crc"]:
        raise FrameCorruption(
            "frame payload CRC32 mismatch (corrupted in transit)")
    payload: Dict[str, np.ndarray] = {}
    for name, shape, dtype, wire_dtype in header["leaves"]:
        count = int(np.prod(shape, dtype=np.int64))
        arr = np.frombuffer(buf, dtype=np.dtype(wire_dtype), count=count,
                            offset=off).reshape(shape)
        payload[name] = decode_array(arr, dtype)
        off += count * np.dtype(wire_dtype).itemsize
    return WireMessage(tag=header["tag"], sender=header["sender"],
                       round=header["round"], meta=header["meta"],
                       payload=payload)


def frame(encoded: bytes) -> bytes:
    """Prefix an encoded message with its length (stream framing)."""
    return _LENGTH.pack(len(encoded)) + encoded


def unframe_length(prefix: bytes) -> int:
    return int(_LENGTH.unpack(prefix)[0])


# ------------------------------------------------------- pytree payloads --
# Client parameter trees (the ``params``/``collect`` control exchange) are
# string-keyed nested dicts; flatten them with the checkpoint plane's key
# convention so both sides agree without a schema.

_SEP = "::"


def flatten_tree(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out: Dict[str, np.ndarray] = {}
    for path, leaf in flat:
        if not all(hasattr(p, "key") for p in path):
            raise ValueError(
                "wire payloads only carry string-keyed dict trees; "
                f"got path {path!r}")
        out[_SEP.join(str(p.key) for p in path)] = np.asarray(leaf)
    return out


def unflatten_tree(flat: Dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for key in sorted(flat):
        node = tree
        parts: Tuple[str, ...] = tuple(key.split(_SEP))
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = flat[key]
    return tree
