"""Wire backends: where a :class:`~repro.wire.codec.WireMessage` becomes
bytes and crosses a party boundary.

Both backends speak the same frames — ``codec.frame(codec.encode(msg))``
— and report the same measured byte count for the same message, so the
privacy ledger's serialized-byte metering is backend-independent:

* :class:`LoopbackBackend` — an in-process queue pair. The default wire.
  Messages are genuinely encoded to bytes and decoded on the far side
  (no object sharing), so loopback runs measure exactly what a socket
  run would, and the training trace stays bitwise-identical to the
  legacy direct-call engine.
* :class:`SocketBackend` — length-prefixed frames over a TCP stream, so
  a client party can run in another process (see
  ``tests/_wire_socket_child.py``).

``send``/``recv`` are host-boundary operations by construction — they
serialize device arrays and block on I/O — and every data-plane frame
they move is metered by ``Transport.account_wire``.
"""
from __future__ import annotations

import collections
import socket as _socket
import time
from typing import Optional, Protocol, Tuple, runtime_checkable

from repro.analysis import tags
from repro.wire import codec
from repro.wire.codec import WireMessage

DEFAULT_TIMEOUT_S = 120.0


class WireClosed(ConnectionError):
    """The peer closed the wire (clean EOF or reset)."""


class WireTimeout(TimeoutError):
    """No frame arrived within the recv timeout."""


@runtime_checkable
class WireBackend(Protocol):
    """What the engine and workers need from a wire.

    ``send`` returns the measured frame size in bytes (length prefix
    included); ``recv`` returns the decoded message plus the same
    measurement on the receiving side — equal by construction, so either
    end can feed ``Transport.account_wire``."""

    def send(self, msg: WireMessage) -> int: ...

    def recv(self, timeout: Optional[float] = None
             ) -> Tuple[WireMessage, int]: ...

    def close(self) -> None: ...


# ============================================================= loopback ====

class LoopbackBackend:
    """In-process queue pair that still round-trips every frame through
    the byte codec — the far end sees decoded bytes, never shared
    objects, so loopback and socket runs are the same protocol at
    different transport latencies."""

    def __init__(self, inbox: collections.deque,
                 outbox: collections.deque) -> None:
        self._inbox = inbox
        self._outbox = outbox
        self._open = True

    @classmethod
    def pair(cls) -> Tuple["LoopbackBackend", "LoopbackBackend"]:
        """Two cross-wired endpoints (engine end, worker end)."""
        a: collections.deque = collections.deque()
        b: collections.deque = collections.deque()
        return cls(inbox=a, outbox=b), cls(inbox=b, outbox=a)

    @tags.wire("up", accounted_by="Transport.account_wire", kind="frame",
               reason="loopback uplink frames: encoded bytes queued for "
                      "the peer endpoint, metered at their serialized size")
    @tags.wire("down", accounted_by="Transport.account_wire", kind="frame",
               reason="the same queue carries downlink frames; direction "
                      "is a property of the sender's role, not the wire")
    @tags.host_boundary("serializes device arrays into a host-side frame "
                        "queue — the party boundary of the in-proc wire")
    def send(self, msg: WireMessage) -> int:
        if not self._open:
            raise WireClosed("send on a closed loopback endpoint")
        buf = codec.frame(codec.encode(msg))
        self._outbox.append(buf)
        return len(buf)

    def send_bytes(self, buf: bytes) -> int:
        """Raw-frame transmit (the :class:`~repro.wire.faults.ChaosBackend`
        hook): queue already-framed — possibly deliberately damaged —
        bytes for the peer."""
        if not self._open:
            raise WireClosed("send on a closed loopback endpoint")
        self._outbox.append(buf)
        return len(buf)

    @tags.host_boundary("decodes host-side frame bytes back into arrays; "
                        "blocks the host loop, never a trace")
    def recv(self, timeout: Optional[float] = None
             ) -> Tuple[WireMessage, int]:
        # loopback peers run in the same thread (the engine pumps the
        # worker), so an empty inbox cannot fill by waiting
        if not self._inbox:
            if not self._open:
                raise WireClosed("recv on a closed loopback endpoint")
            raise WireTimeout("loopback inbox empty (peer not pumped?)")
        buf = self._inbox.popleft()
        return codec.decode(buf[codec.FRAME_OVERHEAD:]), len(buf)

    def pending(self) -> int:
        return len(self._inbox)

    def close(self) -> None:
        self._open = False


# =============================================================== socket ====

class SocketBackend:
    """Length-prefixed frames over a connected TCP stream.

    Constructed via :meth:`connect` with ``self_heal=True`` the backend
    remembers its dial target and, when the stream dies mid-``send`` /
    mid-``recv``, re-dials it with exponential backoff before giving up —
    a worker survives the engine dropping and re-accepting its
    connection (or an engine restart on the same port) instead of dying
    with the first broken pipe. Accepted (listener-side) backends have no
    dial target and always fail fast."""

    def __init__(self, sock: _socket.socket) -> None:
        sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
        self._sock = sock
        self._peer: Optional[Tuple[str, int]] = None
        self._heal_attempts = 0
        self._heal_delay_s = 0.0
        self.reconnects = 0         # successful self-heals (observability)

    @classmethod
    def connect(cls, host: str, port: int, *, retries: int = 100,
                delay_s: float = 0.1, self_heal: bool = False,
                heal_attempts: int = 5,
                heal_delay_s: float = 0.05) -> "SocketBackend":
        """Dial the engine's listener, retrying while it comes up (the
        subprocess child usually races the parent's ``accept``).

        ``self_heal=True`` arms mid-stream reconnect: a ``WireClosed``
        during ``send``/``recv`` triggers up to ``heal_attempts`` re-dials
        with exponential backoff starting at ``heal_delay_s``."""
        last: Optional[Exception] = None
        for _ in range(retries):
            try:
                be = cls(_socket.create_connection((host, port)))
                if self_heal:
                    be._peer = (host, port)
                    be._heal_attempts = heal_attempts
                    be._heal_delay_s = heal_delay_s
                return be
            except OSError as e:  # pragma: no cover - timing dependent
                last = e
                time.sleep(delay_s)
        raise WireClosed(f"could not connect to {host}:{port}: {last}")

    def _reconnect(self, cause: Exception) -> None:
        """Re-dial the remembered peer with exponential backoff; raises
        ``WireClosed`` (chained to ``cause``) once the budget is spent."""
        if self._peer is None:
            raise cause
        host, port = self._peer
        try:
            self._sock.close()
        except OSError:  # pragma: no cover - already torn down
            pass
        delay = self._heal_delay_s
        last: Exception = cause
        for _ in range(self._heal_attempts):
            try:
                sock = _socket.create_connection((host, port))
                sock.setsockopt(_socket.IPPROTO_TCP,
                                _socket.TCP_NODELAY, 1)
                self._sock = sock
                self.reconnects += 1
                return
            except OSError as e:
                last = e
                time.sleep(delay)
                delay *= 2
        raise WireClosed(
            f"could not re-dial {host}:{port} after "
            f"{self._heal_attempts} attempts: {last}") from cause

    @tags.wire("up", accounted_by="Transport.account_wire", kind="frame",
               reason="TCP uplink frames: the length-prefixed bytes are "
                      "the measured wire cost of the message")
    @tags.wire("down", accounted_by="Transport.account_wire", kind="frame",
               reason="the same stream carries downlink frames; direction "
                      "is a property of the sender's role, not the wire")
    @tags.host_boundary("serializes device arrays and writes them to a "
                        "kernel socket buffer — a genuine process boundary")
    def send(self, msg: WireMessage) -> int:
        buf = codec.frame(codec.encode(msg))
        try:
            self._sock.sendall(buf)
        except OSError as e:
            self._reconnect(WireClosed(f"peer gone during send: {e}"))
            # healed: the frame may have been torn mid-stream — resend it
            # whole on the fresh connection (the far side reads a clean
            # frame; the torn prefix died with the old socket)
            try:
                self._sock.sendall(buf)
            except OSError as e2:  # pragma: no cover - peer flapping
                raise WireClosed(f"peer gone during resend: {e2}") from e2
        return len(buf)

    def send_bytes(self, buf: bytes) -> int:
        """Raw-frame transmit (the :class:`~repro.wire.faults.ChaosBackend`
        hook): push already-framed — possibly deliberately damaged —
        bytes down the stream."""
        try:
            self._sock.sendall(buf)
        except OSError as e:
            raise WireClosed(f"peer gone during send: {e}") from e
        return len(buf)

    @tags.host_boundary("blocking read from a kernel socket buffer back "
                        "into host arrays; never inside a trace")
    def recv(self, timeout: Optional[float] = None
             ) -> Tuple[WireMessage, int]:
        self._sock.settimeout(DEFAULT_TIMEOUT_S if timeout is None
                              else timeout)
        try:
            prefix = self._recv_exact(codec.FRAME_OVERHEAD)
        except WireClosed as e:
            # between frames: safe to heal and wait for the next one (a
            # frame torn mid-read is NOT resumable — that stays fatal)
            self._reconnect(e)
            self._sock.settimeout(DEFAULT_TIMEOUT_S if timeout is None
                                  else timeout)
            prefix = self._recv_exact(codec.FRAME_OVERHEAD)
        body = self._recv_exact(codec.unframe_length(prefix))
        return codec.decode(body), len(prefix) + len(body)

    def _recv_exact(self, n: int) -> bytes:
        chunks = []
        got = 0
        while got < n:
            try:
                chunk = self._sock.recv(n - got)
            except _socket.timeout as e:
                raise WireTimeout(
                    f"no frame within timeout ({got}/{n} bytes)") from e
            except OSError as e:
                raise WireClosed(f"peer gone during recv: {e}") from e
            if not chunk:
                raise WireClosed(f"peer closed mid-frame ({got}/{n} bytes)")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        try:
            self._sock.shutdown(_socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


def listen(host: str = "127.0.0.1", port: int = 0
           ) -> Tuple[_socket.socket, int]:
    """Open a listener for worker processes to dial; returns the bound
    (socket, port) — port 0 lets the OS pick a free one."""
    srv = _socket.socket(_socket.AF_INET, _socket.SOCK_STREAM)
    srv.setsockopt(_socket.SOL_SOCKET, _socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen()
    return srv, srv.getsockname()[1]


def accept(listener: _socket.socket,
           timeout: Optional[float] = None) -> SocketBackend:
    listener.settimeout(DEFAULT_TIMEOUT_S if timeout is None else timeout)
    try:
        sock, _ = listener.accept()
    except _socket.timeout as e:
        raise WireTimeout("no worker dialed the listener in time") from e
    return SocketBackend(sock)
