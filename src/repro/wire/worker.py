"""The client party's side of the wire protocol.

A :class:`ClientWorker` owns ONE client's parameters and feature slice
and speaks the population engine's message protocol over any
:class:`~repro.wire.backend.WireBackend`:

    act      engine -> client   batch indices + this round's row key
    emb      client -> engine   1 clean + q perturbed embeddings (§V uplink)
    loss     engine -> client   1 clean + q perturbed scalar losses
    skip     engine -> client   round aborted (drop / straggler) — clear state
    collect  engine -> client   request the parameter tree
    params   client -> engine   the flattened parameter tree
    ping     engine -> client   liveness probe (heartbeat)
    pong     client -> engine   liveness reply (echoes the ping's nonce)
    stop     engine -> client   exit the serve loop

A crashed worker restarts from the last party-scoped checkpoint:
:meth:`ClientWorker.from_checkpoint` re-materializes its parameter row
from the ``client_XX/`` directory a ``fed.save`` wrote, so a replacement
process rejoins the population with exactly the state the checkpoint
froze (any rounds since are lost — the engine's graceful-degradation
path absorbs them as missed activations).

The compute path is the SAME lane decomposition the in-process engine
jits (``zoo.sample_directions`` → ``stack_lanes`` → batched
``client_forward`` → ``grad_from_losses``), split at the party boundary:
the worker evaluates the (1+q) client forwards, the engine evaluates the
(1+q) server losses. At a fixed row key both sides draw and combine the
exact values of the legacy single-process round, which is what makes the
zero-fault wire run bitwise-identical to ``async_engine.run``.

The worker never sees the server's parameters, any other client's
embeddings, or a gradient — its only inputs from the wire are batch
indices, an RNG key, and (1+q) scalar losses that already passed
``Transport.downlink`` on the server side.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tags
from repro.checkpoint.io import load_tree
from repro.configs.base import VFLConfig
from repro.core import zoo
from repro.core.adapters import ModelAdapter
from repro.wire import codec
from repro.wire.backend import WireBackend, WireClosed, WireTimeout
from repro.wire.codec import WireMessage


@functools.lru_cache(maxsize=64)
def _client_fns(adapter: ModelAdapter,
                vfl: VFLConfig) -> Tuple[Any, Any]:
    """Jitted per-(adapter, vfl) client compute: the uplink fan-out and
    the ZOO update. Cached so every worker of a population shares the
    same compiled executables."""

    @tags.party("client")
    def uplink(client_m: Any, xb: Any, key: Any) -> Any:
        """(1+q)-lane embedding fan-out for one round.

        Mirrors ``zoo_gradient``'s stacked path exactly (same direction
        draws at the same key); lane 0 is the clean forward — the
        embedding the engine's table refresh stores."""
        mask = (adapter.row_mask(client_m, xb)
                if adapter.row_mask is not None else None)
        u_stack, d_eff = zoo.sample_directions(
            key, client_m, vfl.zoo_queries, vfl.zoo_dist, mask)
        phi = zoo.phi_factor(vfl.zoo_dist, d_eff)
        lanes = zoo.stack_lanes(client_m, u_stack, vfl.mu)
        emb_lanes = jax.vmap(
            lambda cm: adapter.client_forward(cm, xb))(lanes)
        return u_stack, phi, emb_lanes

    @tags.party("client")
    def _apply(client_m: Any, g: Any) -> Any:
        return jax.tree.map(
            lambda w, gg: (w - vfl.lr_client * gg).astype(w.dtype),
            client_m, g)

    apply_jit = jax.jit(_apply)

    @tags.party("client")
    def update(client_m: Any, u_stack: Any, phi: Any,
               losses: Any) -> Any:
        """One ZOO step from the downlinked (1+q) scalar losses.

        The jit split here is load-bearing for bitwise parity with
        ``async_engine.run``: the (q,)-coefficient contraction runs EAGER
        (a standalone-compiled tensordot picks different fusion/FMA than
        the same op inside the legacy scan body; the eager kernel matches
        it), while the SGD apply runs in its OWN jit (the scan body's
        fused multiply-add — eager mul+sub does not reproduce it)."""
        g = zoo.grad_from_losses(u_stack, losses[1:], losses[0],
                                 vfl.mu, phi)
        return apply_jit(client_m, g)

    return jax.jit(uplink), update


@dataclasses.dataclass
class _Pending:
    """One in-flight round: the direction stack the update needs, plus
    the loss lanes as they arrive."""
    round: int
    u_stack: Any
    phi: Any
    losses: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    delivered: bool = True


class ClientWorker:
    """One client party behind a wire endpoint.

    ``client_params`` is this client's UNstacked parameter pytree (one
    row of the engine layout); ``x_m`` its full vertical feature slice.
    Drive it with :meth:`pump` (loopback, engine-pumped) or :meth:`serve`
    (blocking loop for a worker process)."""

    def __init__(self, adapter: ModelAdapter, vfl: VFLConfig,
                 client_params: Any, x_m: Any, index: int,
                 backend: WireBackend) -> None:
        self.adapter = adapter
        self.vfl = vfl
        self.client_params = client_params
        self.x_m = jnp.asarray(x_m)
        self.index = index
        self.backend = backend
        self._uplink, self._update = _client_fns(adapter, vfl)
        self._pending: Optional[_Pending] = None
        self._stopped = False

    @classmethod
    def from_checkpoint(cls, adapter: ModelAdapter, vfl: VFLConfig,
                        ckpt_path: str, index: int, x_m: Any,
                        backend: WireBackend) -> "ClientWorker":
        """Restart a crashed worker from a party-scoped ``fed.save``
        directory: load ONLY this party's row (``client_XX/``) — the
        restarted process never touches another party's leaves — and
        rejoin the wire on ``backend``."""
        tree, _, _ = load_tree(os.path.join(ckpt_path,
                                            f"client_{index:02d}"))
        return cls(adapter, vfl, tree, x_m, index, backend)

    # ------------------------------------------------------------ driving --
    def pump(self) -> int:
        """Process every queued message (loopback mode); returns how many
        were handled."""
        handled = 0
        while not self._stopped:
            try:
                msg, _ = self.backend.recv(timeout=0.0)
            except WireTimeout:
                break
            self._handle(msg)
            handled += 1
        return handled

    def serve(self, timeout: Optional[float] = None) -> None:
        """Blocking message loop (socket mode, worker process): run until
        the engine sends ``stop`` or the wire dies."""
        while not self._stopped:
            msg, _ = self.backend.recv(timeout=timeout)
            self._handle(msg)

    # ----------------------------------------------------------- protocol --
    def _handle(self, msg: WireMessage) -> None:
        if msg.tag == "act":
            self._on_act(msg)
        elif msg.tag == "loss":
            self._on_loss(msg)
        elif msg.tag == "skip":
            self._pending = None
        elif msg.tag == "collect":
            self.backend.send(WireMessage(
                "params", "client", msg.round, {"party": self.index},
                codec.flatten_tree(self.client_params)))
        elif msg.tag == "ping":
            self.backend.send(WireMessage(
                "pong", "client", msg.round,
                {"party": self.index, "nonce": msg.meta.get("nonce", 0)}))
        elif msg.tag == "stop":
            self._stopped = True
        else:  # pragma: no cover - protocol error
            raise ValueError(f"client worker got unexpected {msg.tag!r}")

    @tags.wire("up", accounted_by="Transport.account_wire", kind="embedding",
               reason="the §V uplink: 1 clean + q perturbed embeddings per "
                      "activated round, each frame metered at its "
                      "serialized size by the engine")
    def _on_act(self, msg: WireMessage) -> None:
        key = jax.random.wrap_key_data(jnp.asarray(msg.payload["key"]))
        xb = self.x_m[jnp.asarray(msg.payload["idx"])]
        u_stack, phi, emb_lanes = self._uplink(self.client_params, xb, key)
        self._pending = _Pending(round=msg.round, u_stack=u_stack, phi=phi)
        emb_h = np.asarray(emb_lanes)
        for lane in range(emb_h.shape[0]):
            self.backend.send(WireMessage(
                "emb", "client", msg.round,
                {"party": self.index, "lane": lane},
                {"c": emb_h[lane]}))

    def _on_loss(self, msg: WireMessage) -> None:
        pend = self._pending
        if pend is None or msg.round != pend.round:
            # losses for a round the engine already skipped — drop them
            return
        pend.losses[int(msg.meta["lane"])] = msg.payload["h"]
        pend.delivered = pend.delivered and bool(
            msg.meta.get("delivered", True))
        if len(pend.losses) < 1 + self.vfl.zoo_queries:
            return
        self._pending = None
        if not pend.delivered:
            return  # downlink lost after retries: no update this round
        losses = jnp.asarray(np.stack(
            [pend.losses[i] for i in range(len(pend.losses))]))
        self.client_params = self._update(self.client_params, pend.u_stack,
                                          pend.phi, losses)


# ------------------------------------------------------------ liveness ----

def heartbeat(backend: WireBackend, *, nonce: int = 0,
              timeout: Optional[float] = 1.0) -> bool:
    """Engine-side liveness probe: send ``ping``, wait for the matching
    ``pong``. Returns False — never raises — on a dead, hung, or
    desynchronized peer, so callers can poll it from a recovery path.

    Only safe BETWEEN protocol rounds (an in-flight round's frames would
    be eaten as non-pong replies and dropped)."""
    try:
        backend.send(WireMessage("ping", "server", 0, {"nonce": nonce}))
        msg, _ = backend.recv(timeout=timeout)
        return bool(msg.tag == "pong"
                    and msg.meta.get("nonce", None) == nonce)
    except (WireClosed, WireTimeout, OSError, ValueError):
        return False
