"""``repro.wire`` — the wire plane: real transport backends under the
``federation.Transport`` accounting interface.

* :mod:`repro.wire.codec` — tagged, versioned messages and their byte
  encoding (the checkpoint plane's uint-view codec, so bf16 payloads
  round-trip losslessly); v2 frames carry a CRC32 and damaged bytes
  raise :class:`FrameCorruption`.
* :mod:`repro.wire.backend` — :class:`WireBackend` protocol with
  :class:`LoopbackBackend` (in-proc queue, the default) and
  :class:`SocketBackend` (length-prefixed TCP frames with optional
  reconnect-with-backoff self-healing, so a client party can run in
  another process and survive a flapping connection).
* :mod:`repro.wire.faults` — :class:`FaultPlan`: deterministic per-party
  drop/latency/retry injection in virtual time (typed
  :class:`DeliveryFailed` on budget exhaustion), plus the process-level
  :class:`ChaosPlan`/:class:`ChaosBackend` layer (kill at frame n,
  corrupt/truncate/stall real frames).
* :mod:`repro.wire.worker` — :class:`ClientWorker`: one client party
  behind a wire endpoint, restartable from a party-scoped checkpoint,
  answering :func:`heartbeat` liveness probes.
"""
from repro.wire.backend import (LoopbackBackend, SocketBackend, WireBackend,
                                WireClosed, WireTimeout, accept, listen)
from repro.wire.codec import (WIRE_VERSION, FrameCorruption, WireMessage,
                              decode, encode, frame)
from repro.wire.faults import (Attempt, ChaosBackend, ChaosPlan, Delivery,
                               DeliveryFailed, FaultPlan)
from repro.wire.worker import ClientWorker, heartbeat

__all__ = [
    "WIRE_VERSION", "WireMessage", "encode", "decode", "frame",
    "FrameCorruption",
    "WireBackend", "LoopbackBackend", "SocketBackend", "WireClosed",
    "WireTimeout", "listen", "accept",
    "FaultPlan", "Delivery", "Attempt", "DeliveryFailed",
    "ChaosPlan", "ChaosBackend",
    "ClientWorker", "heartbeat",
]
