"""``repro.wire`` — the wire plane: real transport backends under the
``federation.Transport`` accounting interface.

* :mod:`repro.wire.codec` — tagged, versioned messages and their byte
  encoding (the checkpoint plane's uint-view codec, so bf16 payloads
  round-trip losslessly).
* :mod:`repro.wire.backend` — :class:`WireBackend` protocol with
  :class:`LoopbackBackend` (in-proc queue, the default) and
  :class:`SocketBackend` (length-prefixed TCP frames, so a client party
  can run in another process).
* :mod:`repro.wire.faults` — :class:`FaultPlan`: deterministic per-party
  drop/latency/retry injection in virtual time.
* :mod:`repro.wire.worker` — :class:`ClientWorker`: one client party
  behind a wire endpoint.
"""
from repro.wire.backend import (LoopbackBackend, SocketBackend, WireBackend,
                                WireClosed, WireTimeout, accept, listen)
from repro.wire.codec import (WIRE_VERSION, WireMessage, decode, encode,
                              frame)
from repro.wire.faults import Delivery, FaultPlan
from repro.wire.worker import ClientWorker

__all__ = [
    "WIRE_VERSION", "WireMessage", "encode", "decode", "frame",
    "WireBackend", "LoopbackBackend", "SocketBackend", "WireClosed",
    "WireTimeout", "listen", "accept",
    "FaultPlan", "Delivery", "ClientWorker",
]
