"""Deterministic fault injection for the wire plane.

A :class:`FaultPlan` is a pure function from ``(seed, round, party,
direction, attempt)`` to delivery outcomes: every decision draws from
``np.random.default_rng`` seeded with exactly that tuple, so the plan
carries NO mutable state — replaying round t after a checkpoint restore
reproduces the straight-through run's drops, latencies and retries
bit-for-bit, which is what makes the durable async plane exact.

Time here is *virtual*: latencies, jitter and retry backoff accumulate
into millisecond accounting (straggler admission, the engine's clock)
without ever sleeping the host. The plan is an accounting and scheduling
overlay on the real backend — a "dropped" frame still crosses the actual
wire once (so remote workers stay in lockstep with the engine), but it
costs the retried bytes and the timeout budget, and the engine treats the
payload as undelivered.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import numpy as np

# seed-tuple salt keeping the fault stream disjoint from anything else
# seeded from small integers
_SALT = 0x57495245  # "WIRE"
_DIR = {"up": 0, "down": 1}


class Delivery(NamedTuple):
    """Outcome of delivering one logical payload over a faulty wire."""
    ok: bool            # delivered within the retry budget
    attempts: int       # frames actually transmitted (1 = clean)
    elapsed_ms: float   # virtual wall time: timeouts + final latency


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-party drop/latency/retry model, deterministic from ``seed``.

    ``drop`` / ``latency_ms`` / ``jitter_ms`` are the population-wide
    defaults; ``party_drop`` / ``party_latency_ms`` override single
    parties as ``((party, value), ...)`` pairs (tuples, not dicts — the
    plan is hashable and frozen like every other protocol value object).
    A failed attempt costs ``timeout_ms * backoff**attempt`` virtual ms;
    after ``max_retries`` retries the payload is undelivered and the
    engine degrades (skips the party's round) instead of hanging."""
    seed: int = 0
    drop: float = 0.0
    latency_ms: float = 0.0
    jitter_ms: float = 0.0
    timeout_ms: float = 100.0
    max_retries: int = 3
    backoff: float = 2.0
    party_drop: Tuple[Tuple[int, float], ...] = ()
    party_latency_ms: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self) -> None:
        if not 0.0 <= self.drop < 1.0:
            raise ValueError(f"drop must be in [0, 1), got {self.drop}")
        if self.max_retries < 0 or self.timeout_ms < 0:
            raise ValueError(
                f"need max_retries >= 0 and timeout_ms >= 0, got "
                f"{self.max_retries}, {self.timeout_ms}")
        for party, p in self.party_drop:
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"party_drop[{party}] must be in [0, 1], got {p}")

    @classmethod
    def none(cls) -> "FaultPlan":
        """The clean wire: every delivery succeeds in one attempt at zero
        virtual latency (the bitwise-parity configuration)."""
        return cls()

    @property
    def active(self) -> bool:
        return bool(self.drop or self.latency_ms or self.jitter_ms
                    or self.party_drop or self.party_latency_ms)

    # ------------------------------------------------------------ knobs --
    def drop_for(self, party: int) -> float:
        for m, p in self.party_drop:
            if m == party:
                return p
        return self.drop

    def latency_for(self, party: int) -> float:
        for m, l in self.party_latency_ms:
            if m == party:
                return l
        return self.latency_ms

    # ---------------------------------------------------------- sampling --
    def _rng(self, rnd: int, party: int, direction: str,
             attempt: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, _SALT, rnd, party, _DIR[direction], attempt))

    def delivery(self, rnd: int, party: int, direction: str) -> Delivery:
        """Deliver one payload, retrying dropped attempts with exponential
        backoff. Pure in (seed, rnd, party, direction)."""
        if not self.active:
            return Delivery(True, 1, 0.0)
        p_drop = self.drop_for(party)
        latency = self.latency_for(party)
        elapsed = 0.0
        for attempt in range(self.max_retries + 1):
            rng = self._rng(rnd, party, direction, attempt)
            if rng.uniform() < p_drop:
                elapsed += self.timeout_ms * self.backoff ** attempt
                continue
            lat = (rng.normal(latency, self.jitter_ms) if self.jitter_ms
                   else latency)
            return Delivery(True, attempt + 1, elapsed + max(0.0, lat))
        return Delivery(False, self.max_retries + 1, elapsed)
