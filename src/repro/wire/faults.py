"""Deterministic fault injection for the wire plane.

A :class:`FaultPlan` is a pure function from ``(seed, round, party,
direction, attempt)`` to delivery outcomes: every decision draws from
``np.random.default_rng`` seeded with exactly that tuple, so the plan
carries NO mutable state — replaying round t after a checkpoint restore
reproduces the straight-through run's drops, latencies and retries
bit-for-bit, which is what makes the durable async plane exact.

Time here is *virtual*: latencies, jitter and retry backoff accumulate
into millisecond accounting (straggler admission, the engine's clock)
without ever sleeping the host. The plan is an accounting and scheduling
overlay on the real backend — a "dropped" frame still crosses the actual
wire once (so remote workers stay in lockstep with the engine), but it
costs the retried bytes and the timeout budget, and the engine treats the
payload as undelivered.

:class:`ChaosPlan` / :class:`ChaosBackend` are the PROCESS-level layer on
top: where :class:`FaultPlan` models faults in virtual time, the chaos
backend inflicts them for real — it wraps a concrete backend and kills
the process at frame n (``kill -9`` semantics), corrupts or truncates a
frame's bytes on the wire (the far side raises
:class:`~repro.wire.codec.FrameCorruption`), or stalls a send. The serve
chaos bench and the kill/recovery CI tests drive it.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, NamedTuple, Optional, Tuple

import numpy as np

from repro.wire import codec
from repro.wire.codec import WireMessage

# seed-tuple salt keeping the fault stream disjoint from anything else
# seeded from small integers
_SALT = 0x57495245  # "WIRE"
_DIR = {"up": 0, "down": 1}


class Attempt(NamedTuple):
    """One transmission attempt inside a delivery (audit trail)."""
    attempt: int        # 0-based attempt index
    dropped: bool
    elapsed_ms: float   # this attempt's virtual cost (timeout or latency)


class Delivery(NamedTuple):
    """Outcome of delivering one logical payload over a faulty wire."""
    ok: bool            # delivered within the retry budget
    attempts: int       # frames actually transmitted (1 = clean)
    elapsed_ms: float   # virtual wall time: timeouts + final latency
    history: Tuple[Attempt, ...] = ()   # per-attempt audit trail


class DeliveryFailed(ConnectionError):
    """Retry budget exhausted on a faulty wire.

    Carries the full delivery context — which (seed, round, party,
    direction) stream failed and every attempt's outcome — so the caller
    logs a reproducible failure instead of a bare timeout."""

    def __init__(self, *, seed: int, rnd: int, party: int, direction: str,
                 delivery: "Delivery") -> None:
        self.seed = seed
        self.round = rnd
        self.party = party
        self.direction = direction
        self.delivery = delivery
        trail = ", ".join(
            f"#{a.attempt}: {'drop' if a.dropped else 'ok'} "
            f"(+{a.elapsed_ms:.1f}ms)" for a in delivery.history)
        super().__init__(
            f"delivery failed after {delivery.attempts} attempts "
            f"(seed={seed}, round={rnd}, party={party}, "
            f"direction={direction!r}): {trail}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-party drop/latency/retry model, deterministic from ``seed``.

    ``drop`` / ``latency_ms`` / ``jitter_ms`` are the population-wide
    defaults; ``party_drop`` / ``party_latency_ms`` override single
    parties as ``((party, value), ...)`` pairs (tuples, not dicts — the
    plan is hashable and frozen like every other protocol value object).
    A failed attempt costs ``timeout_ms * backoff**attempt`` virtual ms;
    after ``max_retries`` retries the payload is undelivered and the
    engine degrades (skips the party's round) instead of hanging."""
    seed: int = 0
    drop: float = 0.0
    latency_ms: float = 0.0
    jitter_ms: float = 0.0
    timeout_ms: float = 100.0
    max_retries: int = 3
    backoff: float = 2.0
    party_drop: Tuple[Tuple[int, float], ...] = ()
    party_latency_ms: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self) -> None:
        if not 0.0 <= self.drop < 1.0:
            raise ValueError(f"drop must be in [0, 1), got {self.drop}")
        if self.max_retries < 0 or self.timeout_ms < 0:
            raise ValueError(
                f"need max_retries >= 0 and timeout_ms >= 0, got "
                f"{self.max_retries}, {self.timeout_ms}")
        for party, p in self.party_drop:
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"party_drop[{party}] must be in [0, 1], got {p}")

    @classmethod
    def none(cls) -> "FaultPlan":
        """The clean wire: every delivery succeeds in one attempt at zero
        virtual latency (the bitwise-parity configuration)."""
        return cls()

    @property
    def active(self) -> bool:
        return bool(self.drop or self.latency_ms or self.jitter_ms
                    or self.party_drop or self.party_latency_ms)

    # ------------------------------------------------------------ knobs --
    def drop_for(self, party: int) -> float:
        for m, p in self.party_drop:
            if m == party:
                return p
        return self.drop

    def latency_for(self, party: int) -> float:
        for m, l in self.party_latency_ms:
            if m == party:
                return l
        return self.latency_ms

    # ---------------------------------------------------------- sampling --
    def _rng(self, rnd: int, party: int, direction: str,
             attempt: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, _SALT, rnd, party, _DIR[direction], attempt))

    def delivery(self, rnd: int, party: int, direction: str) -> Delivery:
        """Deliver one payload, retrying dropped attempts with exponential
        backoff. Pure in (seed, rnd, party, direction)."""
        if not self.active:
            return Delivery(True, 1, 0.0, (Attempt(0, False, 0.0),))
        p_drop = self.drop_for(party)
        latency = self.latency_for(party)
        elapsed = 0.0
        trail = []
        for attempt in range(self.max_retries + 1):
            rng = self._rng(rnd, party, direction, attempt)
            if rng.uniform() < p_drop:
                cost = self.timeout_ms * self.backoff ** attempt
                trail.append(Attempt(attempt, True, cost))
                elapsed += cost
                continue
            lat = (rng.normal(latency, self.jitter_ms) if self.jitter_ms
                   else latency)
            trail.append(Attempt(attempt, False, max(0.0, lat)))
            return Delivery(True, attempt + 1, elapsed + max(0.0, lat),
                            tuple(trail))
        return Delivery(False, self.max_retries + 1, elapsed, tuple(trail))

    def require(self, rnd: int, party: int, direction: str) -> Delivery:
        """Like :meth:`delivery`, but retry-budget exhaustion raises a
        typed :class:`DeliveryFailed` carrying the attempt history instead
        of returning ``ok=False`` — for callers that treat an undelivered
        payload as an error rather than a degradation."""
        d = self.delivery(rnd, party, direction)
        if not d.ok:
            raise DeliveryFailed(seed=self.seed, rnd=rnd, party=party,
                                 direction=direction, delivery=d)
        return d


# ====================================================== process chaos ======

@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """Real (not virtual) fault injection at the transport layer.

    Frames are counted as they pass through the wrapping
    :class:`ChaosBackend`'s ``send`` (1-based). At the configured frame:

    * ``kill_at_frame`` — ``os._exit(9)`` BEFORE the frame leaves: the
      process vanishes mid-protocol exactly like ``kill -9``.
    * ``corrupt_at_frame`` — one payload bit is flipped; the peer's
      decode raises :class:`~repro.wire.codec.FrameCorruption`.
    * ``truncate_at_frame`` — the frame is cut to ``truncate_to`` bytes
      after the length prefix (the peer sees a short/broken frame).
    * ``stall_at_frame`` — ``time.sleep(stall_s)`` before sending (a
      real straggler, for timeout paths).
    """
    kill_at_frame: Optional[int] = None
    corrupt_at_frame: Optional[int] = None
    truncate_at_frame: Optional[int] = None
    truncate_to: int = 8
    stall_at_frame: Optional[int] = None
    stall_s: float = 0.0


class ChaosBackend:
    """A :class:`~repro.wire.backend.WireBackend` wrapper that inflicts a
    :class:`ChaosPlan` on the frames it sends. The inner backend must
    expose ``send_bytes`` (both :class:`LoopbackBackend` and
    :class:`SocketBackend` do) so corruption happens on the actual wire
    bytes, after encoding."""

    def __init__(self, inner: Any, plan: ChaosPlan) -> None:
        self.inner = inner
        self.plan = plan
        self.frames_sent = 0

    def send(self, msg: WireMessage) -> int:
        self.frames_sent += 1
        n, plan = self.frames_sent, self.plan
        if plan.stall_at_frame == n and plan.stall_s > 0:
            time.sleep(plan.stall_s)
        if plan.kill_at_frame == n:
            os._exit(9)     # the whole point: no cleanup, no goodbyes
        buf = codec.frame(codec.encode(msg))
        if plan.corrupt_at_frame == n:
            flip = bytearray(buf)
            flip[-1] ^= 0x01            # last payload byte: a real bit flip
            buf = bytes(flip)
        elif plan.truncate_at_frame == n:
            body = buf[codec.FRAME_OVERHEAD:]
            cut = body[:max(0, plan.truncate_to)]
            # keep the length prefix honest so the peer reads a complete
            # (but short) frame and fails in decode, not in framing
            buf = codec.frame(cut)
        self.inner.send_bytes(buf)
        return len(buf)

    def recv(self, timeout: Optional[float] = None
             ) -> Tuple[WireMessage, int]:
        out: Tuple[WireMessage, int] = self.inner.recv(timeout=timeout)
        return out

    def close(self) -> None:
        self.inner.close()
