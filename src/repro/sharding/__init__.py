from repro.sharding.rules import (
    ACT_RULES,
    PARAM_RULES,
    Rules,
    named_sharding,
    resolve_spec,
    shard_constraint,
)

__all__ = [
    "ACT_RULES",
    "PARAM_RULES",
    "Rules",
    "named_sharding",
    "resolve_spec",
    "shard_constraint",
]
