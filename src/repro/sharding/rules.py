"""Logical-axis sharding rules with divisibility fallback.

Every tensor in the framework carries a tuple of *logical axis names*
(one per dim, ``None`` = replicated). A :class:`Rules` table maps each
logical name to an ordered list of candidate mesh-axis groups. For a given
mesh, the first candidate whose (available) axes all divide the dim size
and are not already taken by another dim wins; otherwise the dim is
replicated. This single mechanism makes all 10 assigned architectures —
with their wildly different head counts / vocab sizes / expert counts —
shard on the production mesh without per-arch special cases (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisGroup = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    table: dict

    def candidates(self, logical: str) -> Tuple[AxisGroup, ...]:
        return tuple(self.table.get(logical, ()))


# Parameter sharding: tensor-parallel over "model", FSDP over "data",
# vocab over "model" (padded to 256 so it always divides).
PARAM_RULES = Rules({
    "vocab":      ("model",),
    "embed":      ("data",),             # FSDP
    "heads_out":  ("model", "data"),     # fused (H*hd) projection outputs
    "kv_out":     ("model", "data"),
    "ffn":        ("model",),
    "ffn_in":     ("data",),
    "experts":    ("model",),
    "expert_d":   ("data",),
    "latent":     ("model", "data"),     # MLA lora ranks
    "ssm_inner":  ("model",),
    "ssm_state":  (),
    "pos":        (),
    "layers":     (),
    "frontend":   ("data",),
    # VFL party plane: the async engine's stacked per-client leading axis
    # (client params (M, ...) and the server's embedding table (M, n, e)).
    # Rows partition over "data" — one device hosts M/D clients — and the
    # divisibility fallback replicates on meshes that don't divide M.
    "clients":    ("data",),
})

# §Perf variant: tensor/expert-parallel only — no FSDP over "data". For
# models whose (params/model_axis) fits HBM (<~30B bf16 at 16-way TP) this
# removes every per-layer weight all-gather; weights are replicated across
# the data axis. (DeepSeek-671B still needs FSDP.)
PARAM_RULES_NO_FSDP = Rules({
    **{k: tuple(a for a in v if a != "data")
       for k, v in PARAM_RULES.table.items()},
    "embed": (),
    "ffn_in": (),
    "expert_d": (),
    "frontend": (),
})

# Activation sharding: batch over (pod, data), heads/ffn over "model".
ACT_RULES = Rules({
    "batch":      (("pod", "data"), "data"),
    "seq":        (),
    # sequence-parallel residual boundaries: the saved (remat) block inputs
    # are sharded over "model" along seq — 16× smaller checkpoints; decode
    # (S=1) falls back to replicated automatically via divisibility.
    "seq_act":    ("model",),
    "embed_act":  (),
    "heads_act":  ("model",),
    "kv_heads":   ("model",),
    "ffn_act":    ("model",),
    "experts":    ("model",),
    "vocab_act":  ("model",),
    # decode KV caches: batch -> (pod,data); the cache sequence dim takes
    # whatever remains ("model"; for long_500k batch=1 it takes
    # ("data","model") = 256-way). Head-sharded decode is a §Perf variant.
    "cache_batch":   (("pod", "data"), "data"),
    "cache_seq":     (("pod", "data", "model"), ("data", "model"), "model"),
    "cache_heads":   ("model",),
})


def _group_axes(group: AxisGroup) -> Tuple[str, ...]:
    return (group,) if isinstance(group, str) else tuple(group)


def _available(group: AxisGroup, mesh: Mesh) -> Tuple[str, ...]:
    """Filter a candidate group down to axes present in the mesh
    (a ("pod","data") candidate degrades to ("data",) on single-pod)."""
    return tuple(a for a in _group_axes(group) if a in mesh.shape)


def resolve_spec(
    mesh: Mesh,
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: Rules,
) -> P:
    """Build a PartitionSpec for ``shape`` given per-dim logical names."""
    assert len(shape) == len(logical), (shape, logical)
    taken: set = set()
    entries = []
    for size, name in zip(shape, logical):
        if name is None:
            entries.append(None)
            continue
        chosen = None
        for cand in Rules.candidates(rules, name):
            axes = _available(cand, mesh)
            if not axes or any(a in taken for a in axes):
                continue
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if size % prod == 0 and prod > 1:
                chosen = axes
                break
        if chosen is None:
            entries.append(None)
        else:
            taken.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    # trim trailing Nones for a tidy spec
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def named_sharding(
    mesh: Mesh,
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: Rules = ACT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, shape, logical, rules))


def shard_constraint(x, logical: Sequence[Optional[str]], rules: Rules = ACT_RULES):
    """with_sharding_constraint if tracing inside a mesh context, else id."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(mesh, x.shape, logical, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return am
    except Exception:
        pass
    try:
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.interpreters.pxla import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None
