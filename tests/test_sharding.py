"""Sharding rules: divisibility fallback, spec resolution, mesh degrade."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import ACT_RULES, PARAM_RULES, Rules, resolve_spec


@pytest.fixture(scope="module")
def mesh2d():
    # tiny host mesh with the production axis names (sizes 1x1 on CPU
    # can't test divisibility, so build an abstract mesh over fake devices)
    devs = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    return jax.sharding.Mesh(devs, ("data", "model"))


def test_divisible_dims_shard(mesh2d):
    spec = resolve_spec(mesh2d, (8, 6), ("batch", "ffn"), Rules({
        "batch": ("data",), "ffn": ("model",)}))
    assert spec == P("data", "model")


def test_indivisible_dim_replicates(mesh2d):
    spec = resolve_spec(mesh2d, (7, 6), ("batch", "ffn"), Rules({
        "batch": ("data",), "ffn": ("model",)}))
    assert spec == P(None, "model")


def test_taken_axis_not_reused(mesh2d):
    spec = resolve_spec(mesh2d, (8, 6), ("heads", "ffn"), Rules({
        "heads": ("model",), "ffn": ("model",)}))
    assert spec == P("model")      # second dim found model taken -> None


def test_missing_pod_axis_degrades(mesh2d):
    spec = resolve_spec(mesh2d, (8,), ("batch",), Rules({
        "batch": (("pod", "data"),)}))
    assert spec == P("data")       # pod filtered out on single-pod mesh


def test_candidate_priority_order(mesh2d):
    # cache_seq prefers (data, model) when both free, else model
    r = Rules({"cache_seq": (("data", "model"), "model")})
    spec = resolve_spec(mesh2d, (16,), ("cache_seq",), r)
    assert spec == P(("data", "model"))
    spec2 = resolve_spec(mesh2d, (16, 16), ("batch", "cache_seq"), Rules({
        "batch": ("data",), "cache_seq": (("data", "model"), "model")}))
    assert spec2 == P("data", "model")


def test_param_rules_cover_model_families():
    """Every logical name used by the model specs exists in the tables."""
    from repro.configs import get_config
    from repro.models.model_api import build_model
    from repro.models.common import is_spec
    import jax as _jax

    used = set()
    for arch in ("deepseek-v3-671b", "zamba2-2.7b", "rwkv6-7b",
                 "whisper-medium", "internvl2-26b"):
        m = build_model(get_config(arch), max_seq=128)
        for leaf in _jax.tree.leaves(m.param_specs, is_leaf=is_spec):
            used.update(n for n in leaf.logical if n is not None)
    unknown = {n for n in used if n not in PARAM_RULES.table}
    assert not unknown, unknown


def test_act_rules_cache_names_known():
    for name in ("batch", "seq_act", "cache_batch", "cache_seq",
                 "cache_heads", "heads_act", "ffn_act", "vocab_act"):
        assert name in ACT_RULES.table
