"""ZOO estimator unit + statistical tests (paper Eq. 2/3, Lemma A.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import zoo


def quad_loss(w):
    """Simple smooth loss with known gradient."""
    return 0.5 * jnp.sum(jnp.square(w["a"])) + jnp.sum(w["b"] * w["a"][:3])


def test_phi_factor():
    assert float(zoo.phi_factor("normal", 10)) == 1.0
    assert float(zoo.phi_factor("sphere", 10)) == 10.0
    with pytest.raises(ValueError):
        zoo.phi_factor("cauchy", 3)


def test_sphere_direction_unit_norm(rng_key):
    tree = {"a": jnp.zeros(17), "b": jnp.zeros((3, 5))}
    u, d = zoo.sample_direction(rng_key, tree, "sphere")
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(u)))
    assert abs(float(norm) - 1.0) < 1e-5
    assert int(d) == 17 + 15


def test_perturb_roundtrip(rng_key):
    tree = {"a": jnp.ones(4), "b": jnp.full((2, 2), 2.0)}
    u, _ = zoo.sample_direction(rng_key, tree, "normal")
    pert = zoo.perturb(tree, u, 0.5)
    back = zoo.perturb(pert, u, -0.5)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


@pytest.mark.parametrize("dist", ["sphere", "normal"])
def test_two_point_estimator_unbiased_direction(dist):
    """E[∇̂f] ≈ ∇f_mu ≈ ∇f for small mu (Lemma A.1 Eq. 5): the averaged
    estimator over many directions must align with the true gradient."""
    w = {"a": jnp.asarray(np.linspace(-1, 1, 8), jnp.float32),
         "b": jnp.asarray(np.ones(3), jnp.float32)}
    true_grad = jax.grad(quad_loss)(w)
    est = None
    n = 3000
    keys = jax.random.split(jax.random.key(1), n)

    @jax.jit
    def one(k):
        g, _, _ = zoo.zoo_gradient(k, quad_loss, w, mu=1e-4, dist=dist)
        return g
    gs = jax.vmap(one)(keys)
    est = jax.tree.map(lambda g: jnp.mean(g, 0), gs)

    tg = jnp.concatenate([x.ravel() for x in jax.tree.leaves(true_grad)])
    eg = jnp.concatenate([x.ravel() for x in jax.tree.leaves(est)])
    cos = jnp.dot(tg, eg) / (jnp.linalg.norm(tg) * jnp.linalg.norm(eg))
    assert float(cos) > 0.95, float(cos)
    # magnitude within 25% (finite-sample)
    assert 0.75 < float(jnp.linalg.norm(eg) / jnp.linalg.norm(tg)) < 1.25


def test_multi_query_reduces_variance():
    w = {"a": jnp.ones(16)}
    keys = jax.random.split(jax.random.key(3), 300)

    def est_norm(q):
        @jax.jit
        def one(k):
            g, _, _ = zoo.zoo_gradient(k, quad_loss_a, w, 1e-4, "sphere",
                                       n_queries=q)
            return g["a"]
        gs = jax.vmap(one)(keys)
        return float(jnp.mean(jnp.var(gs, axis=0)))

    def quad_loss_a(w):
        return 0.5 * jnp.sum(jnp.square(w["a"]))

    v1, v4 = est_norm(1), est_norm(4)
    assert v4 < v1 * 0.5, (v1, v4)


def test_active_row_mask():
    toks = jnp.asarray([[1, 2], [2, 3]])
    m = zoo.embedding_row_mask(toks, 8)
    np.testing.assert_array_equal(np.asarray(m),
                                  [0, 1, 1, 1, 0, 0, 0, 0])


def test_row_masked_direction_zeroes_inactive(rng_key):
    tree = {"emb": jnp.zeros((8, 4))}
    mask = {"emb": jnp.asarray([1., 0, 1, 0, 0, 0, 0, 0])}
    u, d_eff = zoo.sample_direction(rng_key, tree, "sphere", mask)
    uu = np.asarray(u["emb"])
    assert np.all(uu[1] == 0) and np.all(uu[3:] == 0)
    assert np.any(uu[0] != 0) and np.any(uu[2] != 0)
    assert int(d_eff) == 2 * 4
