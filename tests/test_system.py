"""End-to-end behaviour tests for the paper's system.

Exercises the public API the way a user would: build a model from the
registry, train it with the cascaded VFL driver, serve it, and check the
paper's qualitative claims (cascaded ≈ FOO ≫ full-ZOO; no gradients on
the wire)."""
import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


@pytest.mark.slow
def test_train_driver_cascaded_loss_decreases():
    res = train("phi3-mini-3.8b", steps=60, batch=8, seq=64,
                method="cascaded", lr=0.02, log_every=1000)
    assert res["loss_last"] < res["loss_first"]
    assert not res["wire_has_gradients"]


@pytest.mark.slow
def test_train_driver_methods_ordering():
    """Paper Table II at smoke scale: with the wire kept gradient-free,
    cascaded hybrid descends clearly faster than full-ZOO (whose server is
    also ZOO and therefore dimension-limited, Rmk IV.12)."""
    kw = dict(steps=200, batch=8, seq=64, log_every=1000)
    cas = train("phi3-mini-3.8b", method="cascaded", lr=0.05, **kw)
    zoo = train("phi3-mini-3.8b", method="zoo-vfl", lr=0.003, **kw)
    foo = train("phi3-mini-3.8b", method="split-learning", lr=0.05,
                steps=60, batch=8, seq=64, log_every=1000)
    assert foo["wire_has_gradients"]
    assert not cas["wire_has_gradients"]
    drop_cas = cas["loss_first"] - cas["loss_last"]
    drop_zoo = zoo["loss_first"] - zoo["loss_last"]
    assert drop_cas > 2.0 * drop_zoo, (drop_cas, drop_zoo)


@pytest.mark.slow
def test_serve_driver_families():
    for arch in ("granite-20b", "zamba2-2.7b", "whisper-medium"):
        res = serve(arch, batch=2, prompt_len=8, gen_len=8)
        assert res["gen_len"] == 8
        assert len(res["sample_output"]) == 8


def test_config_registry_complete():
    from repro.configs import INPUT_SHAPES, list_archs
    assert len(list_archs()) == 10
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


def test_active_rows_shrinks_zoo_dimension():
    """Beyond-paper: active-row perturbation must not break training and
    keeps the client update supported on touched rows only."""
    res = train("phi3-mini-3.8b", steps=10, batch=4, seq=32,
                method="cascaded", active_rows=True, log_every=1000)
    assert np.isfinite(res["loss_last"])
