"""Chunked-vs-recurrent equivalence for the sub-quadratic mixers."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.rwkv import wkv6_chunked, wkv6_recurrent_ref
from repro.models.ssm import _ssd_chunked, ssd_recurrent_ref


def _rwkv_inputs(seed, B, S, H, K):
    ks = jax.random.split(jax.random.key(seed), 5)
    r, k, v = [jax.random.normal(ks[i], (B, S, H, K)) * 0.5 for i in range(3)]
    w = jnp.exp(jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K))),
                         -4.0, -1e-3))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    return r, k, v, w, u


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]),
       S=st.sampled_from([16, 32, 64]))
def test_wkv6_chunked_equals_recurrent(seed, chunk, S):
    r, k, v, w, u = _rwkv_inputs(seed, 2, S, 2, 8)
    y1, _ = wkv6_chunked(r, k, v, w, u, chunk)
    y2 = wkv6_recurrent_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)


def test_wkv6_state_carry_across_chunks():
    """Running two half-sequences with carried state == one full pass."""
    r, k, v, w, u = _rwkv_inputs(0, 1, 32, 2, 8)
    y_full, s_full = wkv6_chunked(r, k, v, w, u, 8)
    y1, s1 = wkv6_chunked(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, 8)
    y2, s2 = wkv6_chunked(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, 8,
                          state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-4, rtol=1e-3)


def _ssd_inputs(seed, B, S, H, P, N):
    ks = jax.random.split(jax.random.key(seed), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, H))) * 0.9 + 0.05
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, S, H)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    return xh, a, dt, Bm, Cm


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]),
       S=st.sampled_from([16, 32, 64]))
def test_ssd_chunked_equals_recurrent(seed, chunk, S):
    xh, a, dt, Bm, Cm = _ssd_inputs(seed, 2, S, 2, 8, 4)
    y1, _ = _ssd_chunked(xh, a, dt, Bm, Cm, chunk)
    y2 = ssd_recurrent_ref(xh, a, dt, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)


def test_ssd_state_carry():
    xh, a, dt, Bm, Cm = _ssd_inputs(1, 1, 32, 2, 8, 4)
    y_full, s_full = _ssd_chunked(xh, a, dt, Bm, Cm, 8)
    y1, s1 = _ssd_chunked(xh[:, :16], a[:, :16], dt[:, :16], Bm[:, :16],
                          Cm[:, :16], 8)
    y2, s2 = _ssd_chunked(xh[:, 16:], a[:, 16:], dt[:, 16:], Bm[:, 16:],
                          Cm[:, 16:], 8, state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-4, rtol=1e-3)


def test_wkv6_chunked_long_sequence_stable():
    """No overflow/NaN at 1k tokens with extreme (clamped) decays."""
    r, k, v, w, u = _rwkv_inputs(2, 1, 1024, 2, 8)
    y, s = wkv6_chunked(r, k, v, w, u, 32)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))
