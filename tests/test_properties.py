"""Property-style tests for the ZOO estimator and the privacy ledger
(hypothesis when available, deterministic fixed examples otherwise via
tests/_hypothesis_compat)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import zoo
from repro.core.privacy import GRADIENT_KINDS, Ledger, round_messages

ZOO_METHODS = ("cascaded", "zoo-vfl", "syn-zoo-vfl")


# --------------------------------------------------- sphere direction ------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.sampled_from([1, 3, 8]),
       q=st.sampled_from([1, 2, 5]))
def test_sphere_directions_unit_norm_under_row_masks(seed, rows, q):
    """Every stacked lane is an exact unit vector on the masked support,
    and carries no mass outside it, for any mask width and lane count."""
    tree = {"emb": jnp.zeros((8, 4)), "v": jnp.zeros(6)}
    mask = {"emb": jnp.asarray([1.0] * rows + [0.0] * (8 - rows)),
            "v": jnp.ones(6)}
    u_stack, d_eff = zoo.sample_directions(jax.random.key(seed), tree, q,
                                           "sphere", mask)
    flat = np.concatenate(
        [np.asarray(u).reshape(q, -1) for u in jax.tree.leaves(u_stack)], 1)
    np.testing.assert_allclose(np.linalg.norm(flat, axis=1), 1.0, atol=1e-5)
    masked_rows = np.asarray(u_stack["emb"])[:, rows:]
    assert np.all(masked_rows == 0.0)
    np.testing.assert_allclose(np.asarray(d_eff), rows * 4 + 6)


# ----------------------------------------------------------- phi factor ----

@settings(max_examples=12, deadline=None)
@given(d=st.integers(1, 10_000))
def test_phi_factor_matches_sampling_distribution(d):
    """φ is the estimator's distribution-dependent scale (paper Eq. 2):
    d for the unit sphere, 1 for the standard normal; anything else is a
    config error, not a silent misestimate."""
    assert float(zoo.phi_factor("sphere", d)) == float(d)
    assert float(zoo.phi_factor("normal", d)) == 1.0
    with pytest.raises(ValueError):
        zoo.phi_factor("rademacher", d)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 1000))
def test_estimator_scale_consistent_across_distributions(seed):
    """With the matching φ, sphere and normal estimators agree with the
    true gradient direction on a smooth quadratic — i.e. φ really does
    match the sampling distribution, not just a constant."""
    w = {"a": jnp.asarray(np.linspace(-1.0, 1.0, 6), jnp.float32)}

    def loss(t):
        return 0.5 * jnp.sum(jnp.square(t["a"]))

    tg = np.asarray(jax.grad(loss)(w)["a"])
    for dist in ("sphere", "normal"):
        keys = jax.random.split(jax.random.key(seed), 1500)
        gs = jax.vmap(
            lambda k: zoo.zoo_gradient(k, loss, w, 1e-4, dist)[0]["a"])(keys)
        eg = np.asarray(jnp.mean(gs, 0))
        cos = eg @ tg / (np.linalg.norm(eg) * np.linalg.norm(tg))
        assert cos > 0.9, (dist, cos)
        ratio = np.linalg.norm(eg) / np.linalg.norm(tg)
        assert 0.6 < ratio < 1.4, (dist, ratio)


# ------------------------------------------------------- privacy ledger ----

@settings(max_examples=25, deadline=None)
@given(batch=st.integers(1, 4096), embed=st.integers(1, 8192))
def test_ledger_never_ships_gradients_for_zoo_methods(batch, embed):
    """§V structural guarantee at ANY (batch, embed): ZOO rounds consist of
    embeddings up and scalar losses down — no GRADIENT_KINDS message ever
    enters the ledger."""
    for method in ZOO_METHODS:
        msgs = round_messages(method, batch, embed)
        assert all(m.kind not in GRADIENT_KINDS for m in msgs)
        led = Ledger()
        led.log_round(method, batch, embed)
        assert not led.transmits_gradients
        # and the byte accounting stays consistent with the wire shape
        up = sum(m.nbytes for m in led.messages if m.sender == "client")
        down = sum(m.nbytes for m in led.messages if m.sender == "server")
        assert up == 2 * batch * embed * 4
        assert down == 2 * batch * 4
