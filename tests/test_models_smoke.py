"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one cascaded train step on CPU,
asserting output shapes and no NaNs. Decode consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig, get_config, list_archs, reduced
from repro.core.cascade import make_cascaded_step
from repro.models import common
from repro.models.model_api import build_cache_specs, build_model
from repro.optim import sgd
from tests.conftest import tiny_batch

ALL_ARCHS = list_archs()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg, max_seq=32)
    params = common.materialize(model.param_specs, jax.random.key(0))
    B, S = 2, 16
    batch = tiny_batch(cfg, B, S)

    # forward: logits shape + finite
    logits = jax.jit(model.forward_fn)(params, batch)
    exp_S = S if cfg.family != "vlm" else S
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one cascaded train step: loss finite, params change, no NaNs
    opt = sgd(0.01)
    step = jax.jit(make_cascaded_step(model.loss_fn, model.client_keys,
                                      VFLConfig(mu=1e-3), opt,
                                      vocab=cfg.padded_vocab))
    p2, _, out = step(params, opt.init(params), batch, jax.random.key(1))
    assert np.isfinite(float(out.loss))
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


# every assigned arch: serve_step must reproduce the teacher-forced forward
DECODE_ARCHS = ["granite-20b", "qwen3-moe-30b-a3b", "internvl2-26b",
                "nemotron-4-15b", "whisper-medium", "phi3-mini-3.8b",
                "internlm2-20b", "deepseek-v3-671b", "rwkv6-7b",
                "zamba2-2.7b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    """serve_step over t=0..S must reproduce the full-forward logits."""
    cfg = reduced(get_config(arch), remat=False)
    model = build_model(cfg, max_seq=16)
    params = common.materialize(model.param_specs, jax.random.key(3))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)

    extra = {}
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        frames = jnp.ones((B, cfg.encoder_seq, cfg.frontend_dim),
                          jnp.bfloat16)
        full = model.forward_fn(params, {"tokens": toks, "frames": frames})
        extra["enc_out"] = encdec.encode(cfg, params, frames)
    elif cfg.family == "vlm":
        # decode path is text-only; compare against text-only forward
        full = model.forward_fn(params, {"tokens": toks})
    else:
        full = model.forward_fn(params, {"tokens": toks})

    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        build_cache_specs(cfg, B, S),
        is_leaf=lambda x: hasattr(x, "logical"))
    dec = jax.jit(model.decode_fn)
    for t in range(S):
        logits, caches = dec(params, {"tokens": toks[:, t:t + 1], **extra},
                             caches, t)
    err = jnp.max(jnp.abs(logits[:, 0].astype(jnp.float32)
                          - full[:, -1].astype(jnp.float32)))
    assert float(err) < 2e-2, float(err)


def test_sliding_window_variant_changes_logits():
    """window>0 must actually mask old keys (long_500k SWA variant)."""
    cfg = reduced(get_config("phi3-mini-3.8b"), remat=False)
    m_full = build_model(cfg, max_seq=32)
    m_win = build_model(cfg, max_seq=32, window=4)
    params = common.materialize(m_full.param_specs, jax.random.key(5))
    toks = jax.random.randint(jax.random.key(6), (1, 16), 0, cfg.vocab_size)
    lf = m_full.forward_fn(params, {"tokens": toks})
    lw = m_win.forward_fn(params, {"tokens": toks})
    # early positions identical (window covers full history), late differ
    a = np.asarray(lf[:, -1], np.float32)
    b = np.asarray(lw[:, -1], np.float32)
    assert not np.allclose(a, b)
    np.testing.assert_allclose(np.asarray(lf[:, 1], np.float32),
                               np.asarray(lw[:, 1], np.float32), atol=1e-3)


def test_param_counts_are_plausible():
    """Analytic param_count within 2x of the materialized spec count for
    the reduced configs, and full configs in the right ballpark."""
    for arch, lo, hi in [("phi3-mini-3.8b", 3e9, 5e9),
                         ("internlm2-20b", 15e9, 25e9),
                         ("qwen3-moe-30b-a3b", 25e9, 36e9),
                         ("deepseek-v3-671b", 6e11, 7.5e11),
                         ("rwkv6-7b", 5e9, 9e9)]:
        cfg = get_config(arch)
        model = build_model(cfg, max_seq=128)
        n = common.param_count(model.param_specs)
        assert lo < n < hi, (arch, n)
