"""Fallback shim for ``hypothesis``.

When the real library is installed, re-export it untouched. When it is
absent (the pinned CI/container image ships without it), ``@given`` runs
the test body over a small deterministic set of fixed example values drawn
from each strategy and ``@settings`` becomes a no-op — property coverage
degrades to fixed-example coverage instead of killing collection.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A pre-drawn tuple of representative examples."""

        def __init__(self, examples):
            # dedupe while preserving order (min == max collapses to one)
            self.examples = tuple(dict.fromkeys(examples))

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=0):
            mid = (min_value + max_value) // 2
            return _Strategy((min_value, mid, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy((min_value, (min_value + max_value) / 2.0,
                              max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy((seq[0], seq[len(seq) // 2], seq[-1]))

        @staticmethod
        def booleans():
            return _Strategy((False, True))

    st = _St()

    def given(**strategies):
        """Run the test once per example column (pools zipped, cycling the
        shorter ones) — a handful of deterministic cases, not a product."""
        def deco(fn):
            names = list(strategies)
            pools = [strategies[n].examples for n in names]
            width = max(len(p) for p in pools) if pools else 1

            # NOTE: deliberately not functools.wraps — the wrapper must NOT
            # expose the strategy parameters in its signature, or pytest
            # would try to resolve them as fixtures.
            def wrapper(**kwargs):
                for i in range(width):
                    drawn = {n: pools[j][i % len(pools[j])]
                             for j, n in enumerate(names)}
                    fn(**drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco
