"""The jaxpr-level information-flow certifier (IF301–IF304).

Four layers, innermost out:

* the identity anchor primitives (``analysis.marks``) are bitwise no-ops
  that survive vmap/grad/jit — the certifier must not perturb the
  engine's numerics to observe them;
* the taint pass (``analysis.ifc``) propagates through the structured
  higher-order primitives (scan fixpoints, cond control-dependence) and
  launders exactly at the wire;
* each seeded leaky fixture (tests/analysis_fixtures/ifc/) trips
  EXACTLY its rule, and every shipped method configuration certifies
  clean while the declared-leaky FOO baselines trip IF301;
* certificate <-> runtime agreement: the frames a REAL population round
  puts on the wire are exactly the crossings the certificate lists, and
  the per-round device->host transfer increment is the certificate's
  downlink count plus the engine's two bookkeeping pulls (IF304 tied to
  the d2h sentinel).
"""
import collections
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import certify, ifc, marks, runtime
from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.adapters import tabular_adapter
from repro.core.async_engine import EngineConfig
from repro.data import make_classification, vertical_partition
from repro.federation import Transport
from repro.models import common, tabular
from repro.wire import FaultPlan

IFC_FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures",
                            "ifc")
SERVER = frozenset({ifc.SERVER})
CLEAN = frozenset()


def _load_fixture(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(IFC_FIXTURES, name + ".py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ======================================================= mark identity ====

def test_marks_are_bitwise_identities():
    x = jnp.linspace(-2, 2, 12).reshape(3, 4).astype(jnp.bfloat16)
    for f in (lambda a: marks.wire_boundary(a, kind="emb", direction="up"),
              marks.dp_noise, marks.grad_mark):
        np.testing.assert_array_equal(np.asarray(f(x), np.float32),
                                      np.asarray(x, np.float32))
        np.testing.assert_array_equal(
            np.asarray(jax.jit(f)(x), np.float32),
            np.asarray(x, np.float32))


def test_marks_are_transparent_to_grad_and_vmap():
    def loss(w):
        return jnp.sum(marks.wire_boundary(w * 3.0, kind="loss",
                                           direction="down") ** 2)

    w = jnp.arange(4.0)
    np.testing.assert_array_equal(jax.grad(loss)(w), 18.0 * w)
    batched = jax.vmap(lambda a: marks.dp_noise(a) + 1)(jnp.ones((5, 2)))
    np.testing.assert_array_equal(batched, np.full((5, 2), 2.0))


def test_marks_compile_to_identical_hlo():
    """The anchors vanish at lowering: same optimized HLO ops with and
    without them, so every bitwise-equality guarantee in the suite is
    preserved by construction."""
    def plain(x):
        return jnp.sum(x * 2.0)

    def marked(x):
        return jnp.sum(marks.grad_mark(
            marks.wire_boundary(x, kind="emb", direction="up")) * 2.0)

    x = jnp.ones((8,))

    def ops(fn):
        txt = jax.jit(fn).lower(x).compile().as_text()
        return [ln.split("=")[1].split("(")[0].strip()
                for ln in txt.splitlines() if "=" in ln and "ROOT" not in ln]

    assert ops(plain) == ops(marked)


def test_wire_boundary_validates_kind_and_direction():
    x = jnp.ones(3)
    with pytest.raises(ValueError):
        marks.wire_boundary(x, kind="logits", direction="down")
    with pytest.raises(ValueError):
        marks.wire_boundary(x, kind="emb", direction="sideways")


# ========================================================== taint pass ====

def test_taint_flows_through_scan_fixpoint():
    """A scan that mixes the server seed into its carry on every step:
    the fixpoint must taint the carry output (and IF302 must fire, since
    no boundary launders it)."""
    def fn(server_w, xs):
        def body(c, x):
            return c + jnp.sum(server_w) * x, c
        return jax.lax.scan(body, 0.0, xs)

    rep = ifc.trace_and_analyze(fn, (jnp.ones(3), jnp.ones(4)),
                                is_server=lambda p: p.startswith("[0]"))
    assert all(ifc.SERVER in t for t in rep.out_taints)
    rules = [f.rule for f in ifc.check_flows(
        rep, name="scan", dp_configured=False, down_limits={"loss": 3})]
    assert rules == ["IF302"]


def test_cond_predicate_is_control_dependence():
    """Branch outputs inherit the predicate's taint: selecting between
    two client constants ON a server value leaks one bit."""
    def fn(server_flag, a):
        return jax.lax.cond(server_flag > 0, lambda: a + 1.0, lambda: a)

    rep = ifc.trace_and_analyze(fn, (jnp.float32(1.0), jnp.float32(2.0)),
                                is_server=lambda p: p.startswith("[0]"))
    assert rep.out_taints == [SERVER]


def test_wire_boundary_launders_and_records():
    def fn(server_w):
        e = marks.wire_boundary(server_w * 2.0, kind="loss",
                                direction="down")
        return e + 1.0

    rep = ifc.trace_and_analyze(fn, (jnp.ones(3),),
                                is_server=lambda p: True)
    assert rep.out_taints == [CLEAN]
    (c,) = rep.crossings
    assert (c.kind, c.direction, c.shape, c.taint) == (
        "loss", "down", (3,), SERVER)


def test_dp_noise_replaces_taint():
    def fn(server_w):
        return marks.wire_boundary(marks.dp_noise(server_w),
                                   kind="loss", direction="down")

    rep = ifc.trace_and_analyze(fn, (jnp.ones(2),),
                                is_server=lambda p: True)
    assert rep.n_dp_eqns == 1
    assert rep.down("loss")[0].taint == frozenset({ifc.DP})
    assert not ifc.check_flows(rep, name="dp", dp_configured=True,
                               down_limits={"loss": 3})


# ================================================== the leaky fixtures ====

@pytest.mark.parametrize("name", ["if301_skip_downlink",
                                  "if302_embedding_downlink",
                                  "if303_noise_after_estimator"])
def test_leaky_fixture_trips_exactly_its_rule(name):
    mod = _load_fixture(name)
    b = mod.build()
    rep = ifc.trace_and_analyze(b["fn"], b["args"],
                                is_server=b["is_server"])
    findings = ifc.check_flows(rep, name=name,
                               dp_configured=b["dp_configured"],
                               down_limits=b["down_limits"])
    assert [f.rule for f in findings] == [mod.EXPECT]


# ======================================================== certificates ====

@pytest.fixture(scope="module")
def certificate():
    return certify.build_certificate()


def test_all_shipped_methods_certify_clean(certificate):
    findings, cert = certificate
    assert findings == []
    assert cert["clean"]
    certified = {n for n, m in cert["methods"].items()
                 if m["status"] == "certified"}
    assert {"cascaded", "cascaded-lanes", "cascaded-dp", "cascaded-sharded",
            "zoo-vfl", "syn-zoo", "population", "population-dp",
            "split-serve"} == certified


def test_negative_controls_trip_if301(certificate):
    _, cert = certificate
    for name in ("vafl", "split"):
        entry = cert["methods"][name]
        assert entry["status"] == "declared-leaky"
        assert entry["tripped"], f"{name} no longer trips IF301"
        assert "IF301" in entry["findings"]


def test_certified_bottleneck_is_scalar_lanes(certificate):
    """The paper's §V claim, read off the certificate: every training
    downlink is (1+q)-scalar lanes, the DP variants are noise-dominated,
    the serve downlink is integer token ids."""
    _, cert = certificate
    for name in ("cascaded", "zoo-vfl", "syn-zoo", "population"):
        entry = cert["methods"][name]
        q = entry["meta"]["zoo_queries"]
        downs = [c for c in entry["report"]["crossings"]
                 if c["direction"] == "down"]
        assert downs and all(c["kind"] == "loss" for c in downs)
        for c in downs:
            assert c["shape"][-1] == 1 + q
    for name in ("cascaded-dp", "population-dp"):
        entry = cert["methods"][name]
        assert entry["report"]["n_dp_eqns"] >= 1
        for c in entry["report"]["crossings"]:
            if c["direction"] == "down":
                assert c["taint"] == ["dp"]
    serve = cert["methods"]["split-serve"]["report"]
    toks = [c for c in serve["crossings"] if c["direction"] == "down"]
    assert [c["kind"] for c in toks] == ["token"]
    assert all("int" in c["dtype"] for c in toks)


def test_certify_main_writes_certificate(tmp_path, capsys, certificate):
    out = str(tmp_path / "CERT_boundary.json")
    assert certify.main(["--strict", "--out", out]) == 0
    capsys.readouterr()
    cert = json.load(open(out))
    assert cert["clean"] and cert["version"] == 1
    assert sorted(cert["rules"]) == ["IF301", "IF302", "IF303", "IF304"]
    # --json mode prints the same document
    assert certify.main(["--json", "--out", out]) == 0
    printed = json.loads(capsys.readouterr().out)
    assert printed["methods"].keys() == cert["methods"].keys()


def test_if304_catches_wire_disagreement():
    """Force a disagreement: an inventory whose downlink carries more
    scalars than the ledger formula bills must be IF304."""
    rep = ifc.IFCReport(
        out_taints=[CLEAN],
        crossings=[ifc.Crossing("loss", "down", (7,), "float32", SERVER),
                   ifc.Crossing("emb", "up", (3, 4, 4), "float32", CLEAN)],
        n_dp_eqns=0)
    meta = {"method": "cascaded", "zoo_queries": 2, "batch": 4}
    findings = certify._train_if304("forced", rep, meta, rounds_per_trace=1)
    assert [f.rule for f in findings] == ["IF304"]
    # and an unserializable payload kind is IF304 regardless of counts
    rep2 = ifc.IFCReport(
        out_taints=[CLEAN],
        crossings=[ifc.Crossing("token", "down", (3,), "int32", SERVER)],
        n_dp_eqns=0)
    rules = {f.rule for f in certify._train_if304("forced2", rep2, meta,
                                                  rounds_per_trace=1)}
    assert rules == {"IF304"}


# ==================================== certificate <-> runtime agreement ====

CFG = PaperMLPConfig(n_features=8, n_classes=3, n_clients=2,
                     client_embed=4, server_embed=6)
VFL = VFLConfig(n_clients=2, zoo_queries=2, mu=1e-3)


def _run_population(steps):
    X, y = make_classification(0, 32, CFG.n_features, CFG.n_classes)
    Xp = jnp.asarray(vertical_partition(X, CFG.n_clients))
    params = common.materialize(tabular.param_specs(CFG), jax.random.key(0))
    return async_engine.run_population(
        tabular_adapter(CFG), Transport("cascaded"), VFL,
        EngineConfig(method="cascaded", steps=steps, batch_size=4),
        params, Xp, jnp.asarray(y), fault_plan=FaultPlan.none())


def test_certificate_matches_runtime_wire_frames():
    """IF304 closed loop: one activated client's REAL wire traffic is
    exactly the certificate's crossing inventory — (1+q) embedding
    frames of the uplink crossing's per-lane shape up, (1+q) scalar loss
    frames down, nothing else, no gradient-kind frame anywhere."""
    fed = certify._toy_session("cascaded")
    report, meta = certify._trace_population(fed)
    lanes = 1 + meta["zoo_queries"]
    steps = 3
    res = _run_population(steps)

    counts = collections.Counter(m.kind for m in res.ledger.messages)
    # block_size=1, FaultPlan.none(): every round admits exactly 1 client
    assert counts == {"embedding": lanes * steps, "loss": lanes * steps}
    assert not res.ledger.transmits_gradients

    (up,) = [c for c in report.crossings if c.direction == "up"]
    (down,) = [c for c in report.crossings if c.direction == "down"]
    assert up.shape == (lanes,) + tuple(
        m.shape for m in res.ledger.messages if m.kind == "embedding")[0]
    assert down.shape == (lanes,)
    for m in res.ledger.messages:
        if m.kind == "loss":
            assert m.shape == ()          # one scalar per lane frame


def test_certificate_downlinks_match_d2h_increment():
    """The d2h sentinel against the certificate: on a WARM engine the
    per-round host pulls are three bookkeeping fetches (the activation
    key handoff, the loss-history append, the in-proc client worker's
    loss pull) plus EXACTLY one materialization per certified downlink
    crossing — so the steady-state d2h increment is 3 + len(downlinks).
    A second server->client channel would show up here before it showed
    up anywhere else."""
    fed = certify._toy_session("cascaded")
    report, _meta = certify._trace_population(fed)
    _run_population(2)                    # warm the lru-cached jits

    with runtime.strict(check=False) as r1:
        _run_population(2)
    with runtime.strict(check=False) as r2:
        _run_population(5)
    per_round = (r2.d2h - r1.d2h) / 3
    assert per_round == 3 + len(report.down())
