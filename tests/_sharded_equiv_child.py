"""Child process for the sharded-engine equivalence tests.

Forces 8 virtual host devices BEFORE the first jax import (the parent
pytest process has already locked the real topology, so this must run in
its own interpreter — ``test_async_sharded.py`` spawns it and asserts on
the exit code). Checks the ISSUE acceptance pair:

  * block_size=1 sharded losses/params == single-device engine BITWISE
  * block_size=4 over a 4-shard mesh matches to float tolerance
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402

from repro.configs import VFLConfig                    # noqa: E402
from repro.configs.paper_mlp import PaperMLPConfig     # noqa: E402
from repro.core import async_engine                    # noqa: E402
from repro.data import make_classification, vertical_partition  # noqa: E402
from repro.launch.mesh import make_client_mesh         # noqa: E402
from repro.models import common, tabular               # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()

    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=8,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    y = jnp.asarray(y)
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)

    # ---- block_size=1: sharded path must be bitwise-exact ---------------
    ec1 = async_engine.EngineConfig(method="cascaded", steps=25,
                                    batch_size=8, block_size=1)
    single = async_engine.run(ec1, vfl, params, Xp, y)
    shard = async_engine.run(ec1, vfl, params, Xp, y,
                             mesh=make_client_mesh(1))
    assert np.array_equal(single.losses, shard.losses), (
        np.abs(single.losses - shard.losses).max())
    for a, b in zip(jax.tree.leaves(single.params),
                    jax.tree.leaves(shard.params)):
        assert jnp.array_equal(a, b)
    print("block1 bitwise: ok")

    # ---- block_size=4 over 4 shards: allclose across 25 rounds ----------
    ec4 = async_engine.EngineConfig(method="cascaded", steps=25,
                                    batch_size=8, block_size=4)
    single4 = async_engine.run(ec4, vfl, params, Xp, y)
    shard4 = async_engine.run(ec4, vfl, params, Xp, y,
                              mesh=make_client_mesh(4))
    assert np.all(np.isfinite(shard4.losses))
    assert np.allclose(single4.losses, shard4.losses,
                       rtol=1e-5, atol=1e-6), (
        np.abs(single4.losses - shard4.losses).max())
    print("block4/4-shard allclose: ok")

    # the wire ledger is sharding-invariant (protocol, not placement)
    assert single4.wire_bytes == shard4.wire_bytes
    assert not shard4.transmits_gradients

    # indivisible block rejected on a real >1-shard mesh
    try:
        async_engine.run(ec4, vfl, params, Xp, y, mesh=make_client_mesh(3))
    except ValueError:
        print("indivisible block rejected: ok")
    else:
        raise AssertionError("block=4 on 3 shards should raise")


if __name__ == "__main__":
    main()
    print("CHILD_OK")
