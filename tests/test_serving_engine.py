"""Fused split-serve engine (scan decode + chunked prefill + continuous
batching): the compiled paths must buy speed WITHOUT moving the
correctness or accounting anchors.

* scan decode == per-token loop == global decode, bitwise, across the
  cache families (KV / SSM-state / hybrid) and sampling modes;
* chunked prefill == per-token prefill (tokens exact; logits equal up to
  the chunked recurrent forms' float reassociation);
* continuous batching: every request's tokens equal a solo decode with
  the same key, and every request's ledger total is EXACT under slot
  churn (more requests than slots, mixed lengths);
* compile time is reported separately from the timed phases;
* the subsampled DP accountant tightens (never loosens) the budget.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig, get_config, reduced
from repro.core.async_engine import EngineConfig
from repro.core.privacy import GaussianLossChannel, Ledger, serve_messages
from repro.federation import Federation
from repro.federation.serving import prefill_plan
from repro.models import common
from repro.models.model_api import build_cache_specs, build_model


def tiny_dense(**overrides):
    return reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                   n_kv_heads=1, d_ff=128, vocab_size=256, **overrides)


ARCH_CFGS = {
    "dense": tiny_dense,
    "ssm": lambda: reduced(get_config("rwkv6-7b")),
    "hybrid": lambda: reduced(get_config("zamba2-2.7b")),
}


def _build(cfg, seq_len, n_clients=2):
    fed = Federation.build(cfg, VFLConfig(), EngineConfig(),
                           n_clients=n_clients, seq_len=seq_len)
    model = build_model(cfg, max_seq=seq_len)
    key = jax.random.key(0)
    gp = common.materialize(model.param_specs, key)
    return fed, model, gp, key


def _global_decode(cfg, model, gp, toks, gen_len, key, temperature):
    """The pre-session global serve loop — the bitwise oracle."""
    B, prompt_len = toks.shape
    max_seq = prompt_len + gen_len
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        build_cache_specs(cfg, B, max_seq),
        is_leaf=lambda x: hasattr(x, "logical"))
    decode = jax.jit(model.decode_fn, donate_argnums=(2,))
    logits = None
    for t in range(prompt_len):
        logits, caches = decode(gp, {"tokens": toks[:, t:t + 1]}, caches, t)
    out = []
    for t in range(prompt_len, max_seq):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(jax.random.fold_in(key, 100 + t),
                                         lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, caches = decode(gp, {"tokens": nxt[:, None]}, caches, t)
    return np.stack(out, axis=1)


# --------------------------------------------------- scan == loop == global

@pytest.mark.parametrize("family,temperature", [
    ("dense", 0.0), ("dense", 0.8), ("ssm", 0.8), ("hybrid", 0.8)])
def test_scan_decode_bitwise(family, temperature):
    """ISSUE acceptance: the compiled decode scan is bitwise-equal to the
    per-token loop, which stays bitwise-equal to global decode — per
    cache family (KV / SSM state / hybrid)."""
    cfg = ARCH_CFGS[family]()
    B, PL, GL = 2, 4, 6
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    scan = fed.decode(gp, toks, gen_len=GL, temperature=temperature,
                      key=key, chunked_prefill=False)
    loop = fed.decode(gp, toks, gen_len=GL, temperature=temperature,
                      key=key, use_scan=False, chunked_prefill=False)
    ref = _global_decode(cfg, model, gp, toks, GL, key, temperature)
    np.testing.assert_array_equal(scan.tokens, loop.tokens)
    np.testing.assert_array_equal(
        np.asarray(scan.logits, np.float32),
        np.asarray(loop.logits, np.float32))
    np.testing.assert_array_equal(scan.tokens, ref)


# ------------------------------------------------------- chunked prefill --

@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_chunked_prefill_matches_per_token(family):
    """One (B, chunk, d_model) span upload through server_prefill decodes
    to the same tokens as prompt_len per-token steps (the recurrent-state
    families reassociate floats in the chunked form; tokens stay exact)."""
    cfg = ARCH_CFGS[family]()
    B, PL, GL = 2, 6, 6          # PL spans both parties' chunks (span=6)
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    chunked = fed.decode(gp, toks, gen_len=GL, key=key)
    stepped = fed.decode(gp, toks, gen_len=GL, key=key,
                         chunked_prefill=False)
    np.testing.assert_array_equal(chunked.tokens, stepped.tokens)
    loose = family in ("ssm", "hybrid")   # chunked recurrent reassociation
    np.testing.assert_allclose(           # lands on bf16 ulp boundaries
        np.asarray(chunked.logits, np.float32),
        np.asarray(stepped.logits, np.float32),
        rtol=2e-2 if loose else 1e-5, atol=2e-2 if loose else 1e-4)


def test_prefill_plan_span_aligned():
    """Chunks never straddle a party boundary and tile the prompt."""
    assert prefill_plan(10, 4) == [(0, 4, 0), (4, 8, 1), (8, 10, 2)]
    assert prefill_plan(3, 8) == [(0, 3, 0)]
    plan = prefill_plan(16, 8)
    assert plan == [(0, 8, 0), (8, 16, 1)]
    assert all(t1 <= (m + 1) * 8 for t0, t1, m in plan)


def test_compile_reported_separately():
    """prefill_s/decode_s time pure execution: the first call on a fresh
    program shape reports its compilation in compile_s, a repeat call
    reports zero (the bench warm-up keys off this)."""
    cfg = tiny_dense()
    B, PL, GL = 3, 4, 10         # shapes not used by the other tests
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    first = fed.decode(gp, toks, gen_len=GL, key=key)
    again = fed.decode(gp, toks, gen_len=GL, key=key)
    assert first.compile_s > 0.0
    assert again.compile_s == 0.0
    assert again.decode_s < first.compile_s + first.decode_s
    np.testing.assert_array_equal(first.tokens, again.tokens)


# --------------------------------------------------- continuous batching --

def test_continuous_matches_solo_with_churn():
    """ISSUE acceptance: with more requests than slots and mixed
    prompt/gen lengths, every request's tokens equal a solo fed.decode
    with the same key, and every request's ledger total is EXACTLY the
    solo ledger — slot churn never leaks or drops a message."""
    cfg = tiny_dense()
    seq = 12
    fed, model, gp, key = _build(cfg, seq)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8)

    specs = [(4, 8), (3, 5), (6, 6), (4, 4), (2, 3)]   # (prompt, gen)
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 10 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 100 + i)
        rid = srv.submit(prompt, gl, key=k)
        reqs.append((rid, prompt, gl, k))
    results = srv.run()
    assert [r.rid for r in results] == [rid for rid, *_ in reqs]

    for (rid, prompt, gl, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        assert res.ledger.total_bytes == solo.ledger.total_bytes
        assert res.ledger.bytes_by_kind() == solo.ledger.bytes_by_kind()
        assert not res.transmits_gradients

    # churn actually happened: later requests were admitted mid-flight,
    # after earlier retirements — not in one up-front batch
    assert results[2].admitted_at > 0
    assert max(r.finished_at for r in results) == srv.steps
    assert srv.generated_tokens == sum(gl for _, gl in specs)


def test_continuous_wire_formula():
    """Per-request continuous accounting reproduces the closed form:
    prompt_len + gen_len embedding uploads, gen_len token downlinks."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 10)
    srv = fed.serve(fed.params_from_global(gp), max_batch=1)
    PL, GL = 4, 6
    srv.submit(np.zeros(PL, np.int32), GL)
    (res,) = srv.run()
    up, token = serve_messages(1, cfg.d_model)
    assert res.wire_bytes == (PL + GL) * up.nbytes + GL * token.nbytes


def test_scheduler_reuse_returns_only_new_results():
    """A reused scheduler's run() returns the requests IT drained; earlier
    drains stay retrievable via .results."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 8)
    srv = fed.serve(fed.params_from_global(gp), max_batch=2)
    a = srv.submit(np.zeros(4, np.int32), 3)
    (first,) = srv.run()
    assert first.rid == a
    b = srv.submit(np.ones(4, np.int32), 3, seed=1)
    (second,) = srv.run()
    assert second.rid == b
    assert set(srv.results) == {a, b}


def test_scheduler_validation():
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 8)
    srv = fed.serve(fed.params_from_global(gp), max_batch=2)
    with pytest.raises(ValueError, match="seq_len"):
        srv.submit(np.zeros(6, np.int32), 6)
    with pytest.raises(ValueError, match="max_batch"):
        fed.serve(fed.params_from_global(gp), max_batch=0)
    # a gen_len=0 request would never retire its slot (run() would spin);
    # an empty prompt has no logits to seed decode — both refused up front
    with pytest.raises(ValueError, match="gen_len"):
        srv.submit(np.zeros(4, np.int32), 0)
    with pytest.raises(ValueError, match="prompt"):
        srv.submit(np.zeros(0, np.int32), 4)


# ------------------------------------------------- DP subsampling ---------

def test_subsample_one_is_identity():
    a = GaussianLossChannel(epsilon=1.0, delta=1e-5)
    b = GaussianLossChannel(epsilon=1.0, delta=1e-5, subsample=1.0)
    for k in (1, 7, 500):
        assert a.spent(k) == b.spent(k)
    assert b.per_release() == (1.0, 1e-5)


def test_subsample_amplification_tightens():
    """ISSUE acceptance: the subsampled accountant never exceeds the
    non-subsampled bound, and σ (the actual noise) is untouched."""
    base = GaussianLossChannel(epsilon=1.0, delta=1e-5)
    sub = GaussianLossChannel(epsilon=1.0, delta=1e-5, subsample=0.1)
    assert sub.sigma == base.sigma
    for k in (1, 10, 100, 10000):
        eb, db = base.spent(k)
        es, ds = sub.spent(k)
        assert es < eb and ds <= db
    # k=1 is exactly the classic amplified bound
    q, eps = 0.1, 1.0
    e1, d1 = sub.spent(1)
    assert e1 == pytest.approx(math.log1p(q * math.expm1(eps)))
    assert d1 == pytest.approx(q * 1e-5)


def test_subsample_rdp_min_of_valid_bounds():
    rdp = GaussianLossChannel(epsilon=1.0, delta=1e-5, accountant="rdp")
    sub = GaussianLossChannel(epsilon=1.0, delta=1e-5, accountant="rdp",
                              subsample=0.05)
    for k in (1, 100, 10000):
        assert sub.spent(k)[0] <= rdp.spent(k)[0]
        # still a valid bound: never below what amplified basic gives at
        # tiny k where the unamplified RDP conversion overhead dominates
        assert sub.spent(k)[0] > 0


def test_subsample_validation():
    with pytest.raises(ValueError, match="subsample"):
        GaussianLossChannel(subsample=0.0)
    with pytest.raises(ValueError, match="subsample"):
        GaussianLossChannel(subsample=1.5)


def test_subsample_survives_checkpoint_roundtrip(tmp_path):
    """The session manifest carries the subsample rate: a restored
    session reports the same amplified budget."""
    cfg = tiny_dense()
    noise = GaussianLossChannel(clip=5.0, epsilon=0.5, delta=1e-5,
                                subsample=0.25)
    fed = Federation.build(cfg, VFLConfig(),
                           EngineConfig(method="cascaded"), noise=noise,
                           n_clients=2, seq_len=8)
    params = fed.init_params(jax.random.key(0))
    path = fed.save(str(tmp_path / "ck"), params, dp_releases=12,
                    ledger=Ledger())
    fed2, _, state = Federation.restore(path)
    assert fed2.transport.noise.subsample == 0.25
    assert state.dp_spent(fed2.transport) == noise.spent(12)
