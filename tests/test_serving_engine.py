"""Fused split-serve engine (scan decode + chunked prefill + continuous
batching): the compiled paths must buy speed WITHOUT moving the
correctness or accounting anchors.

* scan decode == per-token loop == global decode, bitwise, across the
  cache families (KV / SSM-state / hybrid) and sampling modes;
* chunked prefill == per-token prefill (tokens exact; logits equal up to
  the chunked recurrent forms' float reassociation);
* continuous batching: every request's tokens equal a solo decode with
  the same key, and every request's ledger total is EXACT under slot
  churn (more requests than slots, mixed lengths);
* compile time is reported separately from the timed phases;
* the subsampled DP accountant tightens (never loosens) the budget.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig, get_config, reduced
from repro.core.async_engine import EngineConfig
from repro.core.privacy import GaussianLossChannel, Ledger, serve_messages
from repro.federation import Federation
from repro.federation.serving import prefill_plan
from repro.models import common
from repro.models.model_api import build_cache_specs, build_model


def tiny_dense(**overrides):
    return reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                   n_kv_heads=1, d_ff=128, vocab_size=256, **overrides)


ARCH_CFGS = {
    "dense": tiny_dense,
    "ssm": lambda: reduced(get_config("rwkv6-7b")),
    "hybrid": lambda: reduced(get_config("zamba2-2.7b")),
}


def _build(cfg, seq_len, n_clients=2):
    fed = Federation.build(cfg, VFLConfig(), EngineConfig(),
                           n_clients=n_clients, seq_len=seq_len)
    model = build_model(cfg, max_seq=seq_len)
    key = jax.random.key(0)
    gp = common.materialize(model.param_specs, key)
    return fed, model, gp, key


def _global_decode(cfg, model, gp, toks, gen_len, key, temperature):
    """The pre-session global serve loop — the bitwise oracle."""
    B, prompt_len = toks.shape
    max_seq = prompt_len + gen_len
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        build_cache_specs(cfg, B, max_seq),
        is_leaf=lambda x: hasattr(x, "logical"))
    decode = jax.jit(model.decode_fn, donate_argnums=(2,))
    logits = None
    for t in range(prompt_len):
        logits, caches = decode(gp, {"tokens": toks[:, t:t + 1]}, caches, t)
    out = []
    for t in range(prompt_len, max_seq):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(jax.random.fold_in(key, 100 + t),
                                         lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, caches = decode(gp, {"tokens": nxt[:, None]}, caches, t)
    return np.stack(out, axis=1)


# --------------------------------------------------- scan == loop == global

@pytest.mark.parametrize("family,temperature", [
    ("dense", 0.0), ("dense", 0.8), ("ssm", 0.8), ("hybrid", 0.8)])
def test_scan_decode_bitwise(family, temperature):
    """ISSUE acceptance: the compiled decode scan is bitwise-equal to the
    per-token loop, which stays bitwise-equal to global decode — per
    cache family (KV / SSM state / hybrid)."""
    cfg = ARCH_CFGS[family]()
    B, PL, GL = 2, 4, 6
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    scan = fed.decode(gp, toks, gen_len=GL, temperature=temperature,
                      key=key, chunked_prefill=False)
    loop = fed.decode(gp, toks, gen_len=GL, temperature=temperature,
                      key=key, use_scan=False, chunked_prefill=False)
    ref = _global_decode(cfg, model, gp, toks, GL, key, temperature)
    np.testing.assert_array_equal(scan.tokens, loop.tokens)
    np.testing.assert_array_equal(
        np.asarray(scan.logits, np.float32),
        np.asarray(loop.logits, np.float32))
    np.testing.assert_array_equal(scan.tokens, ref)


# ------------------------------------------------------- chunked prefill --

@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_chunked_prefill_matches_per_token(family):
    """One (B, chunk, d_model) span upload through server_prefill decodes
    to the same tokens as prompt_len per-token steps (the recurrent-state
    families reassociate floats in the chunked form; tokens stay exact)."""
    cfg = ARCH_CFGS[family]()
    B, PL, GL = 2, 6, 6          # PL spans both parties' chunks (span=6)
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    chunked = fed.decode(gp, toks, gen_len=GL, key=key)
    stepped = fed.decode(gp, toks, gen_len=GL, key=key,
                         chunked_prefill=False)
    np.testing.assert_array_equal(chunked.tokens, stepped.tokens)
    loose = family in ("ssm", "hybrid")   # chunked recurrent reassociation
    np.testing.assert_allclose(           # lands on bf16 ulp boundaries
        np.asarray(chunked.logits, np.float32),
        np.asarray(stepped.logits, np.float32),
        rtol=2e-2 if loose else 1e-5, atol=2e-2 if loose else 1e-4)


def test_prefill_plan_span_aligned():
    """Chunks never straddle a party boundary and tile the prompt."""
    assert prefill_plan(10, 4) == [(0, 4, 0), (4, 8, 1), (8, 10, 2)]
    assert prefill_plan(3, 8) == [(0, 3, 0)]
    plan = prefill_plan(16, 8)
    assert plan == [(0, 8, 0), (8, 16, 1)]
    assert all(t1 <= (m + 1) * 8 for t0, t1, m in plan)


def test_compile_reported_separately():
    """prefill_s/decode_s time pure execution: the first call on a fresh
    program shape reports its compilation in compile_s, a repeat call
    reports zero (the bench warm-up keys off this)."""
    cfg = tiny_dense()
    B, PL, GL = 3, 4, 10         # shapes not used by the other tests
    fed, model, gp, key = _build(cfg, PL + GL)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    first = fed.decode(gp, toks, gen_len=GL, key=key)
    again = fed.decode(gp, toks, gen_len=GL, key=key)
    assert first.compile_s > 0.0
    assert again.compile_s == 0.0
    assert again.decode_s < first.compile_s + first.decode_s
    np.testing.assert_array_equal(first.tokens, again.tokens)


# --------------------------------------------------- continuous batching --

def test_continuous_matches_solo_with_churn():
    """ISSUE acceptance: with more requests than slots and mixed
    prompt/gen lengths, every request's tokens equal a solo fed.decode
    with the same key, and every request's ledger total is EXACTLY the
    solo ledger — slot churn never leaks or drops a message."""
    cfg = tiny_dense()
    seq = 12
    fed, model, gp, key = _build(cfg, seq)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8)

    specs = [(4, 8), (3, 5), (6, 6), (4, 4), (2, 3)]   # (prompt, gen)
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 10 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 100 + i)
        rid = srv.submit(prompt, gl, key=k)
        reqs.append((rid, prompt, gl, k))
    results = srv.run()
    assert [r.rid for r in results] == [rid for rid, *_ in reqs]

    for (rid, prompt, gl, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        assert res.ledger.total_bytes == solo.ledger.total_bytes
        assert res.ledger.bytes_by_kind() == solo.ledger.bytes_by_kind()
        assert not res.transmits_gradients

    # churn actually happened: later requests were admitted mid-flight,
    # after earlier retirements — not in one up-front batch
    assert results[2].admitted_at > 0
    assert max(r.finished_at for r in results) == srv.steps
    assert srv.generated_tokens == sum(gl for _, gl in specs)


def test_continuous_wire_formula():
    """Per-request continuous accounting reproduces the closed form:
    prompt_len + gen_len embedding uploads, gen_len token downlinks."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 10)
    srv = fed.serve(fed.params_from_global(gp), max_batch=1)
    PL, GL = 4, 6
    srv.submit(np.zeros(PL, np.int32), GL)
    (res,) = srv.run()
    up, token = serve_messages(1, cfg.d_model)
    assert res.wire_bytes == (PL + GL) * up.nbytes + GL * token.nbytes


def test_scheduler_reuse_returns_only_new_results():
    """A reused scheduler's run() returns the requests IT drained; earlier
    drains stay retrievable via .results."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 8)
    srv = fed.serve(fed.params_from_global(gp), max_batch=2)
    a = srv.submit(np.zeros(4, np.int32), 3)
    (first,) = srv.run()
    assert first.rid == a
    b = srv.submit(np.ones(4, np.int32), 3, seed=1)
    (second,) = srv.run()
    assert second.rid == b
    assert set(srv.results) == {a, b}


def test_scheduler_validation():
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 8)
    srv = fed.serve(fed.params_from_global(gp), max_batch=2)
    with pytest.raises(ValueError, match="seq_len"):
        srv.submit(np.zeros(6, np.int32), 6)
    with pytest.raises(ValueError, match="max_batch"):
        fed.serve(fed.params_from_global(gp), max_batch=0)
    # a gen_len=0 request would never retire its slot (run() would spin);
    # an empty prompt has no logits to seed decode — both refused up front
    with pytest.raises(ValueError, match="gen_len"):
        srv.submit(np.zeros(4, np.int32), 0)
    with pytest.raises(ValueError, match="prompt"):
        srv.submit(np.zeros(0, np.int32), 4)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_continuous_churn_ledger_byte_identity(temperature):
    """ISSUE acceptance: under slot churn (staggered admits, unequal
    lengths, greedy and sampled) every request's ledger is BYTE-IDENTICAL
    to its solo fed.decode ledger — the same ordered Message sequence,
    not just equal totals — and its tokens are bitwise-equal."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 12)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=temperature)
    specs = [(4, 8), (3, 5), (6, 6), (2, 3)]
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 20 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 200 + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, gl, k))
    results = srv.run()
    for (prompt, gl, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=temperature, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        assert res.ledger.messages == solo.ledger.messages
    assert results[2].admitted_at > 0        # admitted mid-flight


@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_continuous_matches_solo_recurrent_families(family):
    """Paged KV + frozen slot-stacked recurrent state: the continuous
    engine stays bitwise-solo-equal for the SSM and hybrid cache
    families too (their state must freeze exactly while a retired slot
    rides along in the batch). The first two requests share a prompt
    length, so the drain opens with a width-2 batched admission wave —
    pinning wave-prefill row stability on these families as well."""
    cfg = ARCH_CFGS[family]()
    fed, model, gp, key = _build(cfg, 10)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8)
    specs = [(4, 6), (4, 4), (3, 4), (2, 3)]
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 30 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 300 + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, gl, k))
    for (prompt, gl, k), res in zip(reqs, srv.run()):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        assert res.ledger.messages == solo.ledger.messages


def test_wave_admission_bitwise_solo_under_sampling():
    """Equal-length prompts admit as one (w, prompt_len) batched wave
    prefill, and XLA does not GUARANTEE a batched matmul is bitwise
    row-stable across batch widths — low-bit logit drift would sample
    different tokens than a solo decode at temperature > 0. Row
    stability is an empirical backend property the scheduler's
    bitwise-solo contract leans on (same status as scan == eager loop
    and split == global); this pins it on a KV-cache family at sampling
    temperature, where low-bit drift is actually visible. The greedy
    width>1 tests would not catch it."""
    cfg = reduced(get_config("granite-20b"))
    fed, model, gp, key = _build(cfg, 10)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8)
    pl, gl = 4, 6
    reqs = []
    for i in range(4):                  # equal lengths -> width-2 waves
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 40 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 400 + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, k))
    results = srv.run()
    assert results[1].admitted_at == 0   # proves a width-2 wave happened
    for (prompt, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        assert res.ledger.messages == solo.ledger.messages


def test_retirement_fetch_is_per_wave_not_per_step():
    """ISSUE acceptance (regression): a churn-heavy drain issues O(requests)
    device->host transfers, not O(steps) — retirements fetch one batched
    wave, never per token."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 10)
    srv = fed.serve(fed.params_from_global(gp), max_batch=2)
    n_req, gl = 4, 8
    for i in range(n_req):
        srv.submit(np.full(2, i, np.int32), gl)
    results = srv.run()
    assert len(results) == n_req
    assert srv.generated_tokens == n_req * gl
    # equal lengths -> both slots retire together: one wave per admission
    # round, and never more waves than requests
    assert srv.host_transfers == n_req // 2
    assert srv.host_transfers <= n_req < srv.generated_tokens


def test_paged_memory_tracks_lengths_in_flight():
    """ISSUE acceptance: peak slot-cache memory scales with the pages
    requests actually touch, not max_batch x seq_len — short requests on
    a long-seq scheduler leave most of the pool untouched."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 16)
    srv = fed.serve(fed.params_from_global(gp), max_batch=4)
    assert srv.page_size == 8 and srv.pages_per_seq == 2
    for i in range(4):
        srv.submit(np.full(3, i, np.int32), 4)   # 7 tokens -> 1 page each
    srv.run()
    worst = srv.max_batch * srv.pages_per_seq    # dense-equivalent: 8 pages
    assert srv.allocator.peak_in_use == 4 < worst
    assert srv.allocator.in_use == 0             # all freed at retirement


def test_small_pool_gates_admission_on_pages():
    """An undersized pool admission-gates on free pages (FIFO) instead of
    free slots: requests still drain in order, tokens stay solo-equal."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 12)
    params = fed.params_from_global(gp)
    # capacity 2 pages = ONE 12-token request at a time, despite 2 slots
    srv = fed.serve(params, max_batch=2, n_pages=4)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(key, 40 + i), (4,), 0, cfg.vocab_size))
        for i in range(3)]
    for p in prompts:
        srv.submit(p, 7)                         # 11 tokens -> 2 pages
    results = srv.run()
    for p, res in zip(prompts, results):
        solo = fed.decode(params, p[None], gen_len=7)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
    assert results[1].admitted_at > 0            # waited for pages
    assert srv.allocator.peak_in_use == 2        # never two in flight
    with pytest.raises(ValueError, match="pages"):
        fed.serve(params, max_batch=1, n_pages=3).submit(
            np.zeros(5, np.int32), 7)            # 2 pages > capacity 1


def test_preempted_requests_resume_bitwise():
    """ISSUE acceptance: with preempt=True and a page-starved pool, a
    victim is evicted mid-flight and later re-admitted via re-prefill +
    token replay — its final tokens are BITWISE-equal to an unpreempted
    solo decode with the same key (absolute-position key folding makes
    the resumed stream identical), at sampling temperature."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 32)
    params = fed.params_from_global(gp)
    # capacity 6 pages; (4+12 -> 4 pages) + (4+2 -> 2 pages) fills the
    # pool, the short request's early retirement strands the second long
    # request behind a page-starved head -> preemption ping-pong
    srv = fed.serve(params, max_batch=2, temperature=0.8, page_size=4,
                    n_pages=8, preempt=True)
    specs = [(4, 12), (4, 2), (4, 12)]
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 50 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 500 + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, gl, k))
    results = srv.run()
    assert srv.preemptions >= 1                 # starvation really bit
    assert sum(r.preemptions for r in results) == srv.preemptions
    assert all(r.status == "ok" for r in results)
    for (prompt, gl, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
        # a preempted tenancy pays REAL extra wire (re-prefill + replay):
        # its ledger dominates the solo cost, never undercounts it
        assert res.ledger.total_bytes >= solo.ledger.total_bytes
    assert srv.allocator.in_use == 0


def test_queue_full_is_typed_and_recoverable():
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 8)
    from repro.federation.scheduler import QueueFull
    srv = fed.serve(fed.params_from_global(gp), max_batch=1, max_queue=2)
    srv.submit(np.zeros(4, np.int32), 3)
    srv.submit(np.ones(4, np.int32), 3)
    with pytest.raises(QueueFull, match="admission queue full"):
        srv.submit(np.full(4, 2, np.int32), 3)
    assert isinstance(QueueFull("x"), RuntimeError)
    results = srv.run()                      # drain frees the queue bound
    assert [r.status for r in results] == ["ok", "ok"]
    assert srv.submit(np.full(4, 3, np.int32), 3) == 2   # admits again
    (late,) = srv.run()
    assert late.status == "ok"


def test_deadline_miss_and_cancel_ledger_exact():
    """A queued request that can no longer meet its deadline fails typed
    (status="deadline") without hanging the drain; an in-flight cancel
    returns the tokens generated so far with a ledger that meters EXACTLY
    the steps that ran — byte-identical to a solo decode of that length."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 12)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=1, temperature=0.8)
    prompt = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 60), (4,), 0, cfg.vocab_size))
    k = jax.random.fold_in(key, 600)
    a = srv.submit(prompt, 8, key=k)
    # needs 6 steps but only 2 are allowed: infeasible from the start,
    # and the single slot is busy with `a` anyway
    b = srv.submit(np.zeros(4, np.int32), 6, deadline=2)
    # generous deadline: meets it comfortably behind `a`
    c = srv.submit(np.full(4, 3, np.int32), 3, deadline=100)

    # partial drain, then cancel the in-flight request between blocks
    srv.run(max_steps=4)
    res_a = srv.cancel(a)
    assert res_a.status == "cancelled" and res_a.rid == a
    ran = res_a.tokens.size
    assert 0 < ran < 8
    solo = fed.decode(params, prompt[None], gen_len=ran,
                      temperature=0.8, key=k)
    np.testing.assert_array_equal(res_a.tokens, solo.tokens[0])
    assert res_a.ledger.messages == solo.ledger.messages
    # cancelling an unknown/finished rid is a no-op, not an error
    assert srv.cancel(a) is None and srv.cancel(999) is None

    srv.run()
    # b expired at the FIRST admission pass (infeasibility is checkable
    # up front), so its terminal result landed in the bounded run
    assert srv.results[b].status == "deadline"
    assert srv.results[b].tokens.size == 0   # expired in the queue
    assert srv.results[c].status == "ok"
    assert srv.deadline_misses == 1
    assert srv.allocator.in_use == 0         # nothing leaked


def test_serve_kill_mid_drain_resumes_bitwise(tmp_path):
    """ISSUE acceptance: kill the process mid-drain (snapshot after a
    bounded run), persist via fed.save(serve_state=...), restore in a
    fresh Federation, and finish — every request's tokens, status AND
    ordered ledger messages are bitwise-identical to an uninterrupted
    drain."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 12)
    params = fed.params_from_global(gp)
    specs = [(4, 8), (3, 5), (6, 6), (2, 3)]

    def submit_all(srv):
        for i, (pl, gl) in enumerate(specs):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(key, 70 + i), (pl,), 0, cfg.vocab_size))
            srv.submit(prompt, gl, key=jax.random.fold_in(key, 700 + i))

    ref = fed.serve(params, max_batch=2, temperature=0.8)
    submit_all(ref)
    ref.run()

    srv = fed.serve(params, max_batch=2, temperature=0.8)
    submit_all(srv)
    srv.run(max_steps=6)                     # "killed" with work in flight
    assert srv.active > 0 or srv.pending > 0
    path = fed.save(str(tmp_path / "ck"), params,
                    serve_state=srv.snapshot())
    del srv

    fed2, params2, state = Federation.restore(path)
    assert state.serve_state is not None
    srv2 = fed2.serve(params2, state=state.serve_state)
    srv2.run()

    assert set(srv2.results) == set(ref.results)
    for rid, want in ref.results.items():
        got = srv2.results[rid]
        np.testing.assert_array_equal(got.tokens, want.tokens)
        assert got.status == want.status
        assert got.ledger.messages == want.ledger.messages
    assert srv2.allocator.in_use == 0


def test_poisoned_request_isolated_and_pages_scrubbed():
    """A request whose cache pages go non-finite (poisoned activations)
    terminates as status="poisoned" instead of crashing the engine or
    publishing NaN tokens as "ok" — and its pages are scrubbed before
    reuse, so the NEXT tenant of the same pool decodes bitwise-clean
    (0·NaN = NaN: stale poison would pierce the causal mask)."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 12)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8)
    prompt = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 80), (4,), 0, cfg.vocab_size))
    a = srv.submit(prompt, 8, key=jax.random.fold_in(key, 800))
    srv.run(max_steps=2)                     # in flight, tokens pending
    # poison the slot's first cache page (prompt positions, inside the
    # causal mask of every later decode step)
    pg = int(srv._slot_pages[0][0])
    srv._caches_st = jax.tree.map(
        lambda st, plan: (st.at[:, pg].set(jnp.nan) if plan.pooled
                          else st),
        srv._caches_st, srv._plans)
    (res_a,) = srv.run()
    assert res_a.rid == a and res_a.status == "poisoned"
    assert srv.poisoned == 1
    assert srv.allocator.in_use == 0

    # the engine SURVIVES: a fresh request over the scrubbed pages is
    # bitwise-equal to its solo decode
    k_b = jax.random.fold_in(key, 801)
    prompt_b = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 81), (4,), 0, cfg.vocab_size))
    srv.submit(prompt_b, 6, key=k_b)
    (res_b,) = srv.run()
    assert res_b.status == "ok"
    solo = fed.decode(params, prompt_b[None], gen_len=6,
                      temperature=0.8, key=k_b)
    np.testing.assert_array_equal(res_b.tokens, solo.tokens[0])
    assert res_b.ledger.messages == solo.ledger.messages


def test_small_pool_churn_with_preemption_drains_clean():
    """An undersized pool + preempt=True under mixed-length churn: every
    request terminates "ok" with solo-bitwise tokens, the pool is empty
    at the end, and peak usage never exceeded capacity — preemption adds
    liveness, never corruption or leaks."""
    cfg = tiny_dense()
    fed, model, gp, key = _build(cfg, 16)
    params = fed.params_from_global(gp)
    srv = fed.serve(params, max_batch=2, temperature=0.8, page_size=4,
                    n_pages=6, preempt=True)     # capacity: 4 pages
    specs = [(4, 10), (4, 2), (4, 8), (2, 3), (4, 4)]
    reqs = []
    for i, (pl, gl) in enumerate(specs):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 90 + i), (pl,), 0, cfg.vocab_size))
        k = jax.random.fold_in(key, 900 + i)
        srv.submit(prompt, gl, key=k)
        reqs.append((prompt, gl, k))
    results = srv.run()
    assert len(results) == len(specs)
    assert all(r.status == "ok" for r in results)
    for (prompt, gl, k), res in zip(reqs, results):
        solo = fed.decode(params, prompt[None], gen_len=gl,
                          temperature=0.8, key=k)
        np.testing.assert_array_equal(res.tokens, solo.tokens[0])
    assert srv.allocator.in_use == 0
    assert srv.allocator.peak_in_use <= srv.allocator.capacity


def test_sig_memo_skips_tree_reflatten():
    """The AOT-cache signature memoizes big containers: a repeated lookup
    with the same live params tree must not re-flatten it."""
    from repro.federation import serving
    tree = {"w": jnp.zeros((8, 8)), "sub": {"b": jnp.ones((3,))}}
    before = dict(serving._SIG_STATS)
    sig1 = serving._sig((tree, 3))
    sig2 = serving._sig((tree, 3))
    assert sig1 == sig2
    assert serving._SIG_STATS["flattens"] == before["flattens"] + 1
    assert serving._SIG_STATS["memo_hits"] == before["memo_hits"] + 1
    # a structurally-equal DIFFERENT tree re-flattens but yields an equal
    # signature — executables still shared across fresh-but-equal trees
    tree2 = {"w": jnp.zeros((8, 8)), "sub": {"b": jnp.ones((3,))}}
    assert serving._sig((tree2, 3)) == sig1
    assert serving._SIG_STATS["flattens"] == before["flattens"] + 2


# ------------------------------------------------- DP subsampling ---------

def test_subsample_one_is_identity():
    a = GaussianLossChannel(epsilon=1.0, delta=1e-5)
    b = GaussianLossChannel(epsilon=1.0, delta=1e-5, subsample=1.0)
    for k in (1, 7, 500):
        assert a.spent(k) == b.spent(k)
    assert b.per_release() == (1.0, 1e-5)


def test_subsample_amplification_tightens():
    """ISSUE acceptance: the subsampled accountant never exceeds the
    non-subsampled bound, and σ (the actual noise) is untouched."""
    base = GaussianLossChannel(epsilon=1.0, delta=1e-5)
    sub = GaussianLossChannel(epsilon=1.0, delta=1e-5, subsample=0.1)
    assert sub.sigma == base.sigma
    for k in (1, 10, 100, 10000):
        eb, db = base.spent(k)
        es, ds = sub.spent(k)
        assert es < eb and ds <= db
    # k=1 is exactly the classic amplified bound
    q, eps = 0.1, 1.0
    e1, d1 = sub.spent(1)
    assert e1 == pytest.approx(math.log1p(q * math.expm1(eps)))
    assert d1 == pytest.approx(q * 1e-5)


def test_subsample_rdp_min_of_valid_bounds():
    rdp = GaussianLossChannel(epsilon=1.0, delta=1e-5, accountant="rdp")
    sub = GaussianLossChannel(epsilon=1.0, delta=1e-5, accountant="rdp",
                              subsample=0.05)
    for k in (1, 100, 10000):
        assert sub.spent(k)[0] <= rdp.spent(k)[0]
        # still a valid bound: never below what amplified basic gives at
        # tiny k where the unamplified RDP conversion overhead dominates
        assert sub.spent(k)[0] > 0


def test_subsample_validation():
    with pytest.raises(ValueError, match="subsample"):
        GaussianLossChannel(subsample=0.0)
    with pytest.raises(ValueError, match="subsample"):
        GaussianLossChannel(subsample=1.5)


def test_subsample_survives_checkpoint_roundtrip(tmp_path):
    """The session manifest carries the subsample rate: a restored
    session reports the same amplified budget."""
    cfg = tiny_dense()
    noise = GaussianLossChannel(clip=5.0, epsilon=0.5, delta=1e-5,
                                subsample=0.25)
    fed = Federation.build(cfg, VFLConfig(),
                           EngineConfig(method="cascaded"), noise=noise,
                           n_clients=2, seq_len=8)
    params = fed.init_params(jax.random.key(0))
    path = fed.save(str(tmp_path / "ck"), params, dp_releases=12,
                    ledger=Ledger())
    fed2, _, state = Federation.restore(path)
    assert fed2.transport.noise.subsample == 0.25
    assert state.dp_spent(fed2.transport) == noise.spent(12)
