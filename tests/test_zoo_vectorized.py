"""Tentpole coverage: the vectorized (stacked-lane) ZOO fan-out must be
numerically equivalent to the unrolled per-query oracle at a fixed PRNG
key, end to end — estimator, cascade step, Pallas kernel, async engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine, cascade, zoo
from repro.core.adapters import mlp_adapter, tabular_adapter
from repro.data import make_classification, vertical_partition
from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul_stacked
from repro.kernels.zoo_dual_matmul.ref import zoo_dual_matmul_stacked_ref
from repro.models import common, tabular
from repro.optim import sgd

CLIENT_KEYS = ("embed",)


def tree_allclose(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **kw)


def quad_loss(w):
    return (0.5 * jnp.sum(jnp.square(w["a"]))
            + jnp.sum(w["b"] * w["a"][:3]), {"s": jnp.sum(w["a"])})


def make_toy():
    key = jax.random.key(0)
    params = {
        "embed": {"w": jax.random.normal(key, (8, 4)) * 0.3},
        "head": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                        (4, 3)) * 0.3},
    }
    x = jax.random.randint(jax.random.fold_in(key, 2), (16,), 0, 8)
    y = jax.random.randint(jax.random.fold_in(key, 3), (16,), 0, 3)

    def loss_fn(p, batch):
        h = jnp.take(p["embed"]["w"], batch["x"], axis=0)
        logits = h @ p["head"]["w"]
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold), {}

    return params, {"x": x, "y": y}, loss_fn


# ------------------------------------------------- estimator equivalence --

@pytest.mark.parametrize("dist", ["sphere", "normal"])
@pytest.mark.parametrize("q", [1, 4])
def test_stacked_gradient_matches_unrolled_oracle(dist, q):
    w = {"a": jnp.asarray(np.linspace(-1, 1, 8), jnp.float32),
         "b": jnp.ones(3, jnp.float32)}
    key = jax.random.key(42)
    g_u, l_u, a_u = zoo.zoo_gradient(key, quad_loss, w, 1e-3, dist, q,
                                     unrolled=True)
    g_s, l_s, a_s = zoo.zoo_gradient(key, quad_loss, w, 1e-3, dist, q)
    tree_allclose(g_u, g_s, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(float(l_u), float(l_s), rtol=1e-6)
    np.testing.assert_allclose(float(a_u["s"]), float(a_s["s"]), rtol=1e-5)


def test_stacked_gradient_matches_with_row_mask():
    w = {"emb": jax.random.normal(jax.random.key(7), (8, 4))}
    mask = {"emb": jnp.asarray([1., 0, 1, 1, 0, 0, 0, 0])}

    def loss(t):
        return jnp.sum(jnp.square(t["emb"])) * 0.5

    key = jax.random.key(3)
    g_u, _, _ = zoo.zoo_gradient(key, loss, w, 1e-3, "sphere", 4,
                                 row_mask=mask, unrolled=True)
    g_s, _, _ = zoo.zoo_gradient(key, loss, w, 1e-3, "sphere", 4,
                                 row_mask=mask)
    tree_allclose(g_u, g_s, rtol=2e-5, atol=1e-6)
    # masked rows never receive gradient on either path
    assert np.all(np.asarray(g_s["emb"])[np.asarray([1, 4, 5, 6, 7])] == 0)


def test_sample_directions_match_per_key_draws():
    """Lane l of the stacked draw == sample_direction(split(key, q)[l])."""
    tree = {"a": jnp.zeros((5, 3)), "b": jnp.zeros(7)}
    key = jax.random.key(11)
    u_stack, d_eff = zoo.sample_directions(key, tree, 3, "sphere")
    for l, k in enumerate(jax.random.split(key, 3)):
        u_l, d_l = zoo.sample_direction(k, tree, "sphere")
        tree_allclose(jax.tree.map(lambda u: u[l], u_stack), u_l,
                      rtol=1e-6, atol=0)
    assert d_eff.shape == (3,)
    np.testing.assert_allclose(np.asarray(d_eff), 22.0)


# --------------------------------------------------- cascade equivalence --

@pytest.mark.parametrize("q", [1, 4])
def test_fused_cascade_step_matches_unrolled_oracle(q):
    params, batch, loss_fn = make_toy()
    key = jax.random.key(5)
    outs = {}
    for fused in (True, False):
        vfl = VFLConfig(mu=1e-3, zoo_queries=q, fused_dual=fused,
                        lr_server=0.05, lr_client=0.05)
        opt = sgd(0.05)
        step = jax.jit(cascade.make_cascaded_step(loss_fn, CLIENT_KEYS, vfl,
                                                  opt))
        outs[fused] = step(params, opt.init(params), batch, key)
    p_f, _, o_f = outs[True]
    p_u, _, o_u = outs[False]
    # tolerance note: the ZOO signal (ĥ−h) is a catastrophic cancellation
    # (~1e-5 here) amplified by φ/μ ≈ 3e4, so two float32 evaluation
    # orders legitimately differ at the 1e-4 level in the updated params
    tree_allclose(p_f, p_u, rtol=2e-3, atol=5e-4)
    np.testing.assert_allclose(float(o_f.loss), float(o_u.loss), rtol=1e-6)
    np.testing.assert_allclose(float(o_f.loss_perturbed),
                               float(o_u.loss_perturbed), rtol=1e-5)
    np.testing.assert_allclose(float(o_f.grad_client_norm),
                               float(o_u.grad_client_norm), rtol=5e-3)


def test_full_zoo_step_vectorized_matches_oracle():
    params, batch, loss_fn = make_toy()
    key = jax.random.key(9)
    res = {}
    for oracle in (True, False):
        vfl = VFLConfig(mu=1e-3, zoo_queries=4, lr_server=0.01,
                        lr_client=0.01, zoo_unrolled_oracle=oracle)
        opt = sgd(0.01)
        step = jax.jit(cascade.make_full_zoo_step(loss_fn, CLIENT_KEYS, vfl,
                                                  opt))
        res[oracle] = step(params, opt.init(params), batch, key)
    tree_allclose(res[True][0], res[False][0], rtol=1e-5, atol=1e-7)


# ----------------------------------------------------- stacked Pallas op --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,q", [(128, 64, 128, 4), (64, 32, 16, 3),
                                     (128, 128, 128, 16)])
def test_zoo_dual_matmul_stacked_sweep(M, K, N, q, dtype):
    ks = jax.random.split(jax.random.key(M + K + N + q), 3)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    us = jax.random.normal(ks[2], (q, K, N), dtype)
    y, y_hat = zoo_dual_matmul_stacked(x, w, us, 1e-2, bm=64,
                                       bn=min(64, N))
    ry, ry_hat = zoo_dual_matmul_stacked_ref(x, w, us, 1e-2)
    tol = 1e-4 if dtype == jnp.float32 else 1.5e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ry, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_hat, np.float32),
                               np.asarray(ry_hat, np.float32),
                               atol=tol, rtol=tol)


def test_stacked_kernel_lane_directions():
    """(ŷ_l − y)/μ must equal x@u_l per lane — the ZOO estimator's signal."""
    ks = jax.random.split(jax.random.key(1), 3)
    x = jax.random.normal(ks[0], (128, 64))
    w = jax.random.normal(ks[1], (64, 128))
    us = jax.random.normal(ks[2], (4, 64, 128))
    y, y_hat = zoo_dual_matmul_stacked(x, w, us, 1e-3)
    np.testing.assert_allclose(np.asarray((y_hat - y[None]) / 1e-3),
                               np.einsum("mk,qkn->qmn", np.asarray(x),
                                         np.asarray(us)),
                               atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,q", [(128, 64, 128, 4), (64, 32, 128, 3)])
def test_stacked_kernel_bias_relu_epilogue(M, K, N, q, dtype):
    """The fused bias+ReLU epilogue (the tabular client path) matches the
    unfused oracle in interpret mode, lane for lane."""
    from repro.kernels.zoo_dual_matmul.ref import (
        zoo_dual_matmul_stacked_bias_relu_ref)
    ks = jax.random.split(jax.random.key(M + N + q), 5)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    us = jax.random.normal(ks[2], (q, K, N), dtype)
    b = jax.random.normal(ks[3], (N,), jnp.float32)
    ub = jax.random.normal(ks[4], (q, N), jnp.float32)
    y, y_hat = zoo_dual_matmul_stacked(x, w, us, 1e-2, b=b, ub=ub,
                                       bm=64, bn=64)
    ry, ry_hat = zoo_dual_matmul_stacked_bias_relu_ref(x, w, us, b, ub, 1e-2)
    tol = 1e-4 if dtype == jnp.float32 else 1.5e-1
    assert float(jnp.min(y)) >= 0.0 and float(jnp.min(y_hat)) >= 0.0
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ry, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_hat, np.float32),
                               np.asarray(ry_hat, np.float32),
                               atol=tol, rtol=tol)
    with pytest.raises(ValueError, match="both b and ub"):
        zoo_dual_matmul_stacked(x, w, us, 1e-2, b=b)


def test_tabular_pallas_lanes_match_xla_lanes():
    """tabular_adapter(use_pallas_lanes=True) — the fused-epilogue kernel
    path — produces the same (1+q) activation lanes as the XLA oracle."""
    from repro.core import zoo
    from repro.core.adapters import tabular_adapter
    cfg = PaperMLPConfig(n_features=512, n_classes=4, n_clients=4,
                         client_embed=128, server_embed=64)
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    c0 = jax.tree.map(lambda a: a[0], params["clients"])
    x = jax.random.normal(jax.random.key(1), (64, cfg.features_per_client))
    u_stack, _ = zoo.sample_directions(jax.random.key(2), c0, 3)
    lanes_pallas = tabular_adapter(cfg, use_pallas_lanes=True).client_lanes(
        c0, u_stack, 1e-3, x)
    lanes_xla = tabular_adapter(cfg).client_lanes(c0, u_stack, 1e-3, x)
    assert lanes_pallas.shape == (4, 64, cfg.client_embed)
    np.testing.assert_allclose(np.asarray(lanes_pallas),
                               np.asarray(lanes_xla), atol=2e-5, rtol=2e-5)


# ---------------------------------------------- async engine + adapters --

@pytest.fixture(scope="module")
def tabular_setup():
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 512, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


def test_async_engine_mlp_adapter_smoke(tabular_setup):
    """The jitted scan drives a NON-tabular repro.models client/server pair
    (SwiGLU-MLP clients + SwiGLU-MLP server) through the same protocol."""
    _, Xp, y, _ = tabular_setup
    ad = mlp_adapter(n_clients=4, features=32, client_embed=16, d_ff=32,
                     server_embed=32, n_classes=4)
    params = ad.init_params(jax.random.key(1))
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=150,
                                  batch_size=32),
        vfl, params, Xp, y, adapter=ad)
    assert np.isfinite(res.losses).all()
    assert res.losses[-15:].mean() < res.losses[:15].mean()


def test_async_engine_block_activation(tabular_setup):
    """block_size > 1 vmaps several concurrent client activations/round."""
    cfg, Xp, y, params = tabular_setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=120,
                                  batch_size=32, block_size=3),
        vfl, params, Xp, y)
    assert np.isfinite(res.losses).all()
    assert res.losses[-15:].mean() < res.losses[:15].mean()
    # with 3 of 4 clients active per round staleness stays lower than the
    # one-client schedule over the same horizon
    res_1 = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=120,
                                  batch_size=32, block_size=1),
        vfl, params, Xp, y)
    assert res.mean_delay < res_1.mean_delay


def test_block_schedule_draws_distinct_clients():
    sched = async_engine.make_schedule(jax.random.key(0), 200, 5,
                                       block_size=3)
    assert sched.shape == (200, 3)
    s = np.asarray(sched)
    for t in range(200):
        assert len(set(s[t])) == 3, s[t]


def test_lanes_routing_matches_generic_path(tabular_setup):
    """use_lanes=True (adapter fused dual-pass) == the generic vectorized
    zoo_gradient path, trajectory-level, at a fixed engine seed."""
    cfg, Xp, y, params = tabular_setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    ad = tabular_adapter(cfg)
    kw = dict(method="cascaded", steps=25, batch_size=16)
    r_lanes = async_engine.run(
        async_engine.EngineConfig(use_lanes=True, **kw), vfl, params, Xp, y,
        adapter=ad)
    r_gen = async_engine.run(
        async_engine.EngineConfig(**kw), vfl, params, Xp, y, adapter=ad)
    # tolerance note: lanes compute x@w + μ(x@u) vs x@(w+μu) generically —
    # a float32 evaluation-order gap amplified by φ/μ over the trajectory,
    # and CPU matmul reduction order makes it run-to-run nondeterministic
    np.testing.assert_allclose(r_lanes.losses, r_gen.losses, atol=1e-3)


def test_pallas_lanes_match_jnp_lanes(tabular_setup):
    """Routing the stacked perturbation through the zoo_dual_matmul Pallas
    kernel reproduces the XLA lanes bit-for-bit at trajectory level."""
    cfg, Xp, y, params = tabular_setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    kw = dict(method="cascaded", steps=4, batch_size=16, use_lanes=True)
    r_jnp = async_engine.run(
        async_engine.EngineConfig(**kw), vfl, params, Xp, y,
        adapter=tabular_adapter(cfg))
    r_pl = async_engine.run(
        async_engine.EngineConfig(**kw), vfl, params, Xp, y,
        adapter=tabular_adapter(cfg, use_pallas_lanes=True))
    np.testing.assert_allclose(r_pl.losses, r_jnp.losses, atol=1e-5)


def test_default_adapter_reuses_compiled_runner(tabular_setup):
    """run() without adapter= must hit the compiled-runner cache on the
    second call (the adapter factories are memoized for this)."""
    cfg, Xp, y, params = tabular_setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    ec = async_engine.EngineConfig(method="cascaded", steps=5, batch_size=8)
    before = async_engine._make_runner.cache_info()
    async_engine.run(ec, vfl, params, Xp, y)
    async_engine.run(ec, vfl, params, Xp, y)
    after = async_engine._make_runner.cache_info()
    assert after.hits >= before.hits + 1
    assert after.misses <= before.misses + 1


def test_engine_rejects_lanes_for_sync_methods(tabular_setup):
    cfg, Xp, y, params = tabular_setup
    with pytest.raises(ValueError, match="use_lanes"):
        async_engine.run(
            async_engine.EngineConfig(method="split", steps=2, batch_size=8,
                                      use_lanes=True),
            VFLConfig(), params, Xp, y)


def test_engine_rejects_block_for_sync_methods(tabular_setup):
    cfg, Xp, y, params = tabular_setup
    with pytest.raises(ValueError, match="block_size"):
        async_engine.run(
            async_engine.EngineConfig(method="syn-zoo", steps=2,
                                      batch_size=8, block_size=3),
            VFLConfig(), params, Xp, y)


def test_engine_rejects_lanes_without_hook(tabular_setup):
    cfg, Xp, y, _ = tabular_setup
    ad = mlp_adapter(n_clients=4, features=32, client_embed=16, d_ff=32,
                     server_embed=32, n_classes=4)
    params = ad.init_params(jax.random.key(1))
    with pytest.raises(ValueError, match="client_lanes"):
        async_engine.run(
            async_engine.EngineConfig(method="cascaded", steps=2,
                                      batch_size=8, use_lanes=True),
            VFLConfig(), params, Xp, y, adapter=ad)
