"""§Perf variants must be numerically equivalent to the baseline paths —
an optimization that changes the math is a bug, not a speedup."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config, reduced
from repro.models import common, model_api
from repro.models.layers import embed_lookup


def _zero_caches(model, B, S):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        model.cache_specs(ShapeConfig("t", S, B, "decode")),
        is_leaf=lambda x: hasattr(x, "logical"))


def test_iota_embed_equals_gather():
    table = {"table": jax.random.normal(jax.random.key(0), (64, 16))}
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, 64)
    a = embed_lookup(table, toks, iota=False)
    b = embed_lookup(table, toks, iota=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("flag", ["iota_embed", "rs_outputs"])
def test_train_variants_match_baseline_loss(flag):
    base = reduced(get_config("phi3-mini-3.8b"), remat=False)
    opt = dataclasses.replace(base, **{flag: True})
    m0 = model_api.build_model(base, max_seq=32)
    m1 = model_api.build_model(opt, max_seq=32)
    params = common.materialize(m0.param_specs, jax.random.key(2))
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0, base.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l0 = float(m0.loss_fn(params, batch)[0])
    l1 = float(m1.loss_fn(params, batch)[0])
    assert abs(l0 - l1) < 2e-2, (l0, l1)


def test_mla_absorb_matches_expanded_decode():
    base = reduced(get_config("deepseek-v3-671b"), remat=False,
                   param_dtype="float32", dtype="float32")
    outs = {}
    for absorb in (False, True):
        cfg = dataclasses.replace(base, mla_absorb=absorb)
        m = model_api.build_model(cfg, max_seq=16)
        params = common.materialize(m.param_specs, jax.random.key(4))
        toks = jax.random.randint(jax.random.key(5), (2, 8), 0,
                                  cfg.vocab_size)
        caches = _zero_caches(m, 2, 8)
        dec = jax.jit(m.decode_fn)
        for t in range(8):
            logits, caches = dec(params, {"tokens": toks[:, t:t + 1]},
                                 caches, t)
        outs[absorb] = np.asarray(logits[:, 0], np.float32)
    np.testing.assert_allclose(outs[False], outs[True], atol=2e-3, rtol=1e-3)


def test_window_gather_matches_masked_decode():
    from repro.models.attention import decode_attend
    ks = jax.random.split(jax.random.key(6), 3)
    B, S, H, hd = 2, 32, 4, 16
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, S, H, hd))
    vc = jax.random.normal(ks[2], (B, S, H, hd))
    for cur in (7, 15, 31):
        a = decode_attend(q, kc, vc, cur, window=8, window_gather=False)
        b = decode_attend(q, kc, vc, cur, window=8, window_gather=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)
