"""Device-sharded async engine: equivalence, wire accounting, validation.

The multi-device half runs in a subprocess that forces 8 virtual host
devices (``tests/_sharded_equiv_child.py``) — this process keeps the real
topology per conftest. The in-process half exercises the shard_map code
path on a 1-shard mesh, where it must be BITWISE identical to the
single-device engine."""
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.privacy import round_messages
from repro.data import make_classification, vertical_partition
from repro.launch.mesh import make_client_mesh
from repro.models import common, tabular


@pytest.fixture(scope="module")
def setup():
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


VFL = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)


def test_sharded_mesh1_block1_bitwise(setup):
    """The shard_map path on a trivial mesh IS the single-device engine."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=25, batch_size=8)
    single = async_engine.run(ec, VFL, params, Xp, y)
    shard = async_engine.run(ec, VFL, params, Xp, y,
                             mesh=make_client_mesh(1))
    assert np.array_equal(single.losses, shard.losses)
    for a, b in zip(jax.tree.leaves(single.params),
                    jax.tree.leaves(shard.params)):
        assert jnp.array_equal(a, b)


def test_sharded_mesh1_block4_bitwise(setup):
    """Concurrent blocks too: gather/psum boundaries are float-exact."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=15, batch_size=8,
                                   block_size=4)
    single = async_engine.run(ec, VFL, params, Xp, y)
    shard = async_engine.run(ec, VFL, params, Xp, y,
                             mesh=make_client_mesh(1))
    assert np.array_equal(single.losses, shard.losses)


def test_sharded_eight_virtual_devices():
    """Full acceptance pair (bitwise b=1, allclose b=4/4-shard) on a forced
    8-virtual-device topology — own process, own XLA_FLAGS."""
    child = os.path.join(os.path.dirname(__file__), "_sharded_equiv_child.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, child], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, f"stdout:{proc.stdout}\nstderr:{proc.stderr}"
    assert "CHILD_OK" in proc.stdout


# --------------------------------------------------- engine-side ledger ---

def test_engine_result_wire_accounting(setup):
    """run() threads a q-aware Ledger: block rounds log block_size× the
    per-client messages, and EngineResult reports the totals."""
    cfg, Xp, y, params = setup
    q, block, steps, bs = 3, 2, 5, 8
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=q)
    ec = async_engine.EngineConfig(method="cascaded", steps=steps,
                                   batch_size=bs, block_size=block)
    res = async_engine.run(ec, vfl, params, Xp, y)
    per_client = sum(m.nbytes
                     for m in round_messages("cascaded", bs,
                                             cfg.client_embed, q))
    assert res.wire_bytes == steps * block * per_client
    assert not res.transmits_gradients
    assert len(res.ledger.messages) == steps * block * (2 * q + 2)


def test_engine_result_vafl_ships_gradients(setup):
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="vafl", steps=3, batch_size=8)
    res = async_engine.run(ec, VFL, params, Xp, y)
    assert res.transmits_gradients
    per_client = sum(m.nbytes
                     for m in round_messages("vafl", 8, cfg.client_embed))
    assert res.wire_bytes == 3 * per_client


def test_sync_method_logs_all_clients(setup):
    """Sync rounds activate every client: M× the per-client messages."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="syn-zoo", steps=4, batch_size=8)
    res = async_engine.run(ec, VFL, params, Xp, y)
    per_client = sum(m.nbytes
                     for m in round_messages("syn-zoo", 8, cfg.client_embed))
    assert res.wire_bytes == 4 * cfg.n_clients * per_client


# -------------------------------------------------------- validation ------

def test_mesh_rejects_sync_method(setup):
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="split", steps=2, batch_size=8)
    with pytest.raises(ValueError, match="asynchronous"):
        async_engine.run(ec, VFL, params, Xp, y, mesh=make_client_mesh(1))


def test_validate_mesh_divisibility_errors():
    fake = types.SimpleNamespace(shape={"data": 3})
    with pytest.raises(ValueError, match="block_size"):
        async_engine._validate_mesh(fake, False, "cascaded", block=4, M=6)
    with pytest.raises(ValueError, match="n_clients"):
        async_engine._validate_mesh(fake, False, "cascaded", block=3, M=4)
    with pytest.raises(ValueError, match="axis"):
        async_engine._validate_mesh(
            types.SimpleNamespace(shape={"model": 2}), False, "cascaded",
            block=2, M=4)


def test_make_client_mesh_bounds():
    with pytest.raises(ValueError):
        make_client_mesh(0)
    with pytest.raises(ValueError):
        make_client_mesh(jax.device_count() + 1)
    mesh = make_client_mesh()
    assert mesh.shape["data"] == jax.device_count()
