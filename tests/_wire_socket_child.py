"""Worker process for the socket-backend parity and chaos tests.

Rebuilds the SAME party state the parent's loopback run uses — the
param init is cross-process deterministic (path-crc32 keys in
``common.materialize``), so this process can materialize its client row
instead of shipping parameters out of band — then serves one client
party over a :class:`SocketBackend` until the engine says stop.

Usage: python _wire_socket_child.py <port> <party> [--die-after-frames N]
                                                   [--from-checkpoint DIR]

``--die-after-frames N`` wraps the backend in a :class:`ChaosBackend`
that ``kill -9``'s this process the moment it tries to SEND its Nth
frame — the crash-mid-round fixture for the engine's declared-dropout
path. ``--from-checkpoint DIR`` restarts the worker from a party-scoped
``fed.save`` directory instead of materializing fresh params.
"""
import argparse

import jax

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core.adapters import tabular_adapter
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular
from repro.wire import ChaosBackend, ChaosPlan, ClientWorker, SocketBackend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("port", type=int)
    ap.add_argument("party", type=int)
    ap.add_argument("--die-after-frames", type=int, default=0)
    ap.add_argument("--from-checkpoint", default="")
    args = ap.parse_args()
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, _ = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = vertical_partition(X, cfg.n_clients)
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    backend = SocketBackend.connect("127.0.0.1", args.port)
    if args.die_after_frames:
        backend = ChaosBackend(
            backend, ChaosPlan(kill_at_frame=args.die_after_frames))
    if args.from_checkpoint:
        worker = ClientWorker.from_checkpoint(
            tabular_adapter(cfg), vfl, args.from_checkpoint, args.party,
            Xp[args.party], backend)
    else:
        params = common.materialize(tabular.param_specs(cfg),
                                    jax.random.key(0))
        worker = ClientWorker(
            tabular_adapter(cfg), vfl,
            jax.tree.map(lambda a: a[args.party], params["clients"]),
            Xp[args.party], args.party, backend)
    worker.serve()
    print("CHILD_OK", flush=True)


if __name__ == "__main__":
    main()
