"""Worker process for the socket-backend parity test.

Rebuilds the SAME party state the parent's loopback run uses — the
param init is cross-process deterministic (path-crc32 keys in
``common.materialize``), so this process can materialize its client row
instead of shipping parameters out of band — then serves one client
party over a :class:`SocketBackend` until the engine says stop.

Usage: python _wire_socket_child.py <port> <party>
"""
import sys

import jax

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core.adapters import tabular_adapter
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular
from repro.wire import ClientWorker, SocketBackend


def main():
    port, party = int(sys.argv[1]), int(sys.argv[2])
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, _ = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = vertical_partition(X, cfg.n_clients)
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    worker = ClientWorker(
        tabular_adapter(cfg), vfl,
        jax.tree.map(lambda a: a[party], params["clients"]),
        Xp[party], party,
        SocketBackend.connect("127.0.0.1", port))
    worker.serve()
    print("CHILD_OK", flush=True)


if __name__ == "__main__":
    main()
