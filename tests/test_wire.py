"""The wire plane: codec exactness, fault determinism, legacy parity,
measured-byte ledger metering, graceful degradation and durable resume.

The ISSUE acceptance pairs covered here:
  * ``run_population`` with ``FaultPlan.none()`` reproduces the legacy
    direct-call engine BITWISE (losses, params, delays);
  * loopback and socket backends produce identical traces AND identical
    per-message ledger byte counts (the socket half runs a real worker
    subprocess via ``tests/_wire_socket_child.py``);
  * the ledger meters ACTUAL serialized frame bytes, with the payload
    formula surviving as a cross-check lower bound;
  * 20% dropout degrades convergence instead of hanging a round;
  * a mid-run kill + resume replays the identical schedule/RNG/fault
    streams and lands bitwise on the straight-through run;
  * damaged frames (bit flip, truncation) raise typed FrameCorruption —
    and v1 pre-checksum frames stay readable;
  * a worker ``kill -9``'d mid-round is declared dead and the population
    finishes every round (graceful degradation, never a hang);
  * a self-healing socket survives its peer dropping the connection;
  * a crashed worker restarts from a party-scoped checkpoint.
"""
import collections
import contextlib
import json
import os
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import save_checkpoint
from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine
from repro.core.adapters import tabular_adapter
from repro.core.async_engine import (AsyncPlaneState, EngineConfig,
                                     PopulationConfig)
from repro.core.privacy import Ledger
from repro.data import make_classification, vertical_partition
from repro.federation import Transport
from repro.models import common, tabular
from repro.wire import (ChaosBackend, ChaosPlan, ClientWorker,
                        DeliveryFailed, FaultPlan, FrameCorruption,
                        LoopbackBackend, SocketBackend, WireMessage, accept,
                        codec, heartbeat, listen)

CFG = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                     client_embed=16, server_embed=32)
VFL = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
EC = EngineConfig(method="cascaded", steps=20, batch_size=8)


@pytest.fixture(scope="module")
def setup():
    X, y = make_classification(0, 256, CFG.n_features, CFG.n_classes)
    Xp = jnp.asarray(vertical_partition(X, CFG.n_clients))
    params = common.materialize(tabular.param_specs(CFG), jax.random.key(0))
    return Xp, jnp.asarray(y), params


def _pop(setup, ec=EC, **kw):
    Xp, y, params = setup
    return async_engine.run_population(
        tabular_adapter(CFG), Transport("cascaded"), VFL, ec,
        params, Xp, y, **kw)


@contextlib.contextmanager
def _hard_timeout(seconds):
    """HARD per-test deadline for the socket/reconnect tests: a deadlock
    in the accept/heal dance fails THIS test with a TimeoutError instead
    of wedging the whole pytest process until the session-level
    faulthandler fires."""

    def _fire(signum, frame):  # pragma: no cover - only on deadlock
        raise TimeoutError(f"socket test exceeded {seconds}s hard timeout")

    old_handler = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)


# ================================================================ codec ====

def test_codec_roundtrip_preserves_dtypes_and_scalars():
    """bf16 arrays and 0-d scalars survive the byte codec bitwise — in
    particular a scalar loss must come back shape (), not (1,)."""
    msg = WireMessage("emb", "client", 7, {"party": 2, "lane": 0}, {
        "c": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": jnp.linspace(-1, 1, 8, dtype=jnp.bfloat16),
        "s": np.float32(3.25),
    })
    out = codec.decode(codec.encode(msg))
    assert (out.tag, out.sender, out.round, out.meta) == (
        "emb", "client", 7, {"party": 2, "lane": 0})
    assert out.payload["s"].shape == ()
    assert out.payload["s"] == np.float32(3.25)
    assert out.payload["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out.payload["b"], np.float32),
                                  np.asarray(msg.payload["b"], np.float32))
    np.testing.assert_array_equal(out.payload["c"], msg.payload["c"])


def test_codec_rejects_foreign_frames():
    buf = codec.encode(WireMessage("act", "server"))
    with pytest.raises(ValueError, match="magic"):
        codec.decode(b"NOPE" + buf[4:])
    bad_version = buf[:4] + (99).to_bytes(2, "big") + buf[6:]
    with pytest.raises(ValueError, match="version"):
        codec.decode(bad_version)
    with pytest.raises(ValueError, match="unknown wire tag"):
        WireMessage("gradient", "server")


def test_frame_prefix_is_the_measured_overhead():
    buf = codec.encode(WireMessage("stop", "server"))
    framed = codec.frame(buf)
    assert len(framed) == codec.FRAME_OVERHEAD + len(buf)
    assert codec.unframe_length(framed[:codec.FRAME_OVERHEAD]) == len(buf)
    # both loopback endpoints report the framed size
    a, b = LoopbackBackend.pair()
    sent = a.send(WireMessage("stop", "server"))
    msg, got = b.recv()
    assert sent == got == len(framed) and msg.tag == "stop"


def test_flatten_tree_roundtrip():
    tree = {"embed": {"w": np.ones((3, 2)), "b": np.zeros((2,))},
            "norm": {"scale": np.full((2,), 0.5)}}
    flat = codec.flatten_tree(tree)
    assert set(flat) == {"embed::w", "embed::b", "norm::scale"}
    out = codec.unflatten_tree(flat)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(tree),
            jax.tree_util.tree_leaves_with_path(out)):
        assert pa == pb and np.array_equal(a, b)


# =============================================================== faults ====

def test_fault_plan_deterministic_and_pure():
    plan = FaultPlan(seed=3, drop=0.3, latency_ms=5.0, jitter_ms=2.0,
                     max_retries=2)
    again = FaultPlan(seed=3, drop=0.3, latency_ms=5.0, jitter_ms=2.0,
                      max_retries=2)
    for t in range(40):
        for m in range(4):
            for d in ("up", "down"):
                one, two = plan.delivery(t, m, d), again.delivery(t, m, d)
                assert one == two          # pure in (seed, round, party, dir)
                assert one.elapsed_ms >= 0.0
    assert FaultPlan.none().delivery(0, 0, "up").ok
    assert not FaultPlan.none().active
    assert plan.active


def test_fault_plan_drop_rate_and_retries():
    # no retries: failures at the raw drop rate
    raw = FaultPlan(seed=0, drop=0.5, max_retries=0)
    fails = sum(not raw.delivery(t, m, "up").ok
                for t in range(200) for m in range(4))
    assert 0.4 < fails / 800 < 0.6
    # 3 retries: P(all fail) = 0.5^4 — rare, and attempts are counted
    retried = FaultPlan(seed=0, drop=0.5, max_retries=3)
    outs = [retried.delivery(t, m, "up") for t in range(200)
            for m in range(4)]
    assert sum(not o.ok for o in outs) / 800 < 0.15
    assert any(o.attempts > 1 for o in outs)
    # per-party overrides beat the global knobs
    party = FaultPlan(seed=0, party_drop=((2, 1.0),), max_retries=0)
    assert not party.delivery(0, 2, "up").ok
    assert party.delivery(0, 1, "up").ok


# ========================================== parity with the legacy engine ==

def test_population_matches_legacy_bitwise(setup):
    """ISSUE acceptance: FaultPlan(none) + loopback reproduces the legacy
    single-process trace bitwise — losses, params AND delay bookkeeping."""
    Xp, y, params = setup
    legacy = async_engine.run(EC, VFL, params, Xp, y)
    pop = _pop(setup)
    assert np.array_equal(legacy.losses, pop.losses), (
        np.abs(legacy.losses - pop.losses).max())
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(legacy.params),
            jax.tree_util.tree_leaves_with_path(pop.params)):
        assert jnp.array_equal(a, b), pa
    assert pop.max_delay_seen == legacy.max_delay_seen
    assert pop.mean_delay == legacy.mean_delay
    assert pop.stats["participation"] == 1.0
    assert pop.stats["degraded_rounds"] == 0


def test_ledger_meters_serialized_bytes(setup):
    """Every data-plane message carries its MEASURED frame size; the
    payload formula survives as a strict lower bound (headers + length
    prefixes are real bytes)."""
    pop = _pop(setup)
    ledger = pop.ledger
    assert ledger.messages and all(m.wired is not None
                                   for m in ledger.messages)
    assert all(m.wired > m.nbytes for m in ledger.messages)
    assert {m.kind for m in ledger.messages} == {"embedding", "loss"}
    assert pop.serialized_bytes == ledger.serialized_bytes
    assert pop.serialized_bytes > pop.wire_bytes == ledger.total_bytes
    assert pop.overhead_bytes == pop.serialized_bytes - pop.wire_bytes
    # formula cross-check: the measurement dominates the legacy estimate
    assert pop.serialized_bytes >= pop.stats["formula_bytes"]
    assert not pop.transmits_gradients
    assert pop.control_bytes > 0          # act/collect/params/stop frames


def test_socket_backend_matches_loopback(setup):
    """ISSUE acceptance: party 2 behind a REAL subprocess + TCP socket —
    the trace and the per-message ledger bytes are identical to the
    all-loopback run."""
    loop = _pop(setup, ledger=Ledger())
    listener, port = listen()
    child = os.path.join(os.path.dirname(__file__),
                         "_wire_socket_child.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.Popen([sys.executable, child, str(port), "2"],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    try:
        chan = accept(listener, timeout=120.0)
        sock = _pop(setup, channels={2: chan}, ledger=Ledger())
        out, err = proc.communicate(timeout=120)
    finally:
        listener.close()
        if proc.poll() is None:  # pragma: no cover - failure path
            proc.kill()
    assert proc.returncode == 0, f"stdout:{out}\nstderr:{err}"
    assert "CHILD_OK" in out
    assert np.array_equal(loop.losses, sock.losses)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(loop.params),
            jax.tree_util.tree_leaves_with_path(sock.params)):
        assert jnp.array_equal(a, b), pa
    # byte-exact parity: same messages, same order, same wired sizes
    assert loop.ledger.messages == sock.ledger.messages
    assert loop.serialized_bytes == sock.serialized_bytes
    assert loop.control_bytes == sock.control_bytes


# ================================================= degradation / faults ====

def test_dropout_degrades_gracefully(setup):
    """20% dropout (no retries) loses rounds, not the run: every round
    completes, losses stay finite, the server keeps stepping."""
    plan = FaultPlan(seed=1, drop=0.2, max_retries=0)
    pop = _pop(setup, fault_plan=plan)
    assert len(pop.losses) == EC.steps            # no hung/aborted rounds
    assert np.all(np.isfinite(pop.losses))
    drops = pop.stats["uplink_drops"] + pop.stats["downlink_drops"]
    assert drops > 0
    assert pop.stats["participation"] < 1.0
    # dropped uplinks leave stale rows behind → more staleness than clean
    clean = _pop(setup)
    assert pop.max_delay_seen >= clean.max_delay_seen


def test_straggler_admission_and_staleness_forcing(setup):
    """Slow clients are refused at the admission budget; clients starved
    past the staleness bound preempt the sampled block."""
    plan = FaultPlan(seed=2, latency_ms=4.0, jitter_ms=4.0,
                     party_latency_ms=((1, 20.0),))
    pop = _pop(setup, fault_plan=plan,
               population=PopulationConfig(admission_ms=10.0,
                                           staleness_bound=5))
    assert pop.stats["stragglers"] > 0            # party 1 misses the budget
    assert pop.stats["forced"] > 0                # ...and gets forced back in
    assert np.all(np.isfinite(pop.losses))
    assert pop.stats["virtual_ms"] > 0.0    # latency accrues virtual time


# ======================================================== durable resume ===

def test_resume_midrun_bitwise(setup, tmp_path):
    """Kill at round 12, save the async plane to disk, reload, continue:
    the combined trace is the straight-through run bitwise, and the
    ledger multiset + byte totals continue exactly."""
    full = _pop(setup, fault_plan=FaultPlan(seed=4, drop=0.3, max_retries=0),
                ledger=Ledger())
    plan = FaultPlan(seed=4, drop=0.3, max_retries=0)
    half = _pop(setup, fault_plan=plan, until=12, ledger=Ledger())
    assert half.state.step == 12

    path = str(tmp_path / "plane")
    half.state.save(path)
    loaded = AsyncPlaneState.load(path)
    assert loaded.step == 12 and loaded.seed == EC.seed
    np.testing.assert_array_equal(loaded.table, half.state.table)
    np.testing.assert_array_equal(loaded.delays, half.state.delays)

    Xp, y, _ = setup
    cont = async_engine.run_population(
        tabular_adapter(CFG), Transport("cascaded"), VFL, EC,
        half.params, Xp, y, fault_plan=plan, state=loaded,
        ledger=half.ledger, dp_releases=half.dp_releases)
    assert np.array_equal(full.losses[12:], cont.losses)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(full.params),
            jax.tree_util.tree_leaves_with_path(cont.params)):
        assert jnp.array_equal(a, b), pa
    np.testing.assert_array_equal(full.state.delays, cont.state.delays)
    np.testing.assert_array_equal(full.state.last_active,
                                  cont.state.last_active)
    assert full.state.clock_ms == cont.state.clock_ms
    assert full.max_delay_seen == cont.max_delay_seen
    # accounting: same message multiset and byte totals (the mid-run
    # collect adds real control frames, so only control_bytes may differ)
    assert (collections.Counter(full.ledger.messages)
            == collections.Counter(cont.ledger.messages))
    assert full.serialized_bytes == cont.serialized_bytes
    assert full.wire_bytes == cont.wire_bytes
    assert cont.control_bytes >= full.control_bytes


# ============================================================ validation ===

def test_population_validation(setup):
    Xp, y, params = setup
    adapter, wire = tabular_adapter(CFG), Transport("cascaded")
    with pytest.raises(ValueError, match="synchronous"):
        async_engine.run_population(
            adapter, Transport("split"), VFL,
            EngineConfig(method="split", steps=2), params, Xp, y)
    with pytest.raises(ValueError, match="use_lanes"):
        async_engine.run_population(
            adapter, wire, VFL,
            EngineConfig(method="cascaded", steps=2, use_lanes=True),
            params, Xp, y)
    with pytest.raises(ValueError, match="seed"):
        stale = AsyncPlaneState(step=1, table=np.zeros((4, 256, 16)),
                                delays=np.zeros((4, 256), np.int32),
                                last_active=np.zeros((4,), np.int32),
                                seed=99)
        async_engine.run_population(adapter, wire, VFL, EC, params, Xp, y,
                                    state=stale)


# ================================================ frame integrity (v2) =====

def _payload_msg(rnd=3):
    return WireMessage("emb", "client", rnd, {"party": 1, "lane": 0},
                       {"c": np.arange(24, dtype=np.float32).reshape(4, 6)})


def test_codec_crc_detects_bit_flip():
    """A single flipped payload bit raises typed FrameCorruption (a
    ValueError subclass — legacy except clauses still catch it)."""
    buf = codec.encode(_payload_msg())
    flipped = buf[:-1] + bytes([buf[-1] ^ 0x01])
    with pytest.raises(FrameCorruption, match="CRC32"):
        codec.decode(flipped)
    assert issubclass(FrameCorruption, ValueError)
    # header damage is corruption too, not a foreign frame
    hdr = bytearray(buf)
    hdr[codec._HEAD.size] ^= 0x01          # first header byte: breaks JSON
    with pytest.raises(FrameCorruption, match="header"):
        codec.decode(bytes(hdr))


def test_codec_detects_truncation():
    buf = codec.encode(_payload_msg())
    with pytest.raises(FrameCorruption, match="truncated"):
        codec.decode(buf[:-3])             # short payload body
    with pytest.raises(FrameCorruption, match="truncated"):
        codec.decode(buf[:codec._HEAD.size + 4])   # short header
    with pytest.raises(FrameCorruption, match="truncated"):
        codec.decode(buf[:6])              # shorter than the fixed head


def _as_v1(buf: bytes) -> bytes:
    """Re-pack a v2 frame as the pre-checksum v1 layout."""
    _, _, hlen = codec._HEAD.unpack_from(buf, 0)
    header = json.loads(buf[codec._HEAD.size:codec._HEAD.size + hlen])
    body = buf[codec._HEAD.size + hlen:]
    del header["crc"]
    header["v"] = 1
    hb = json.dumps(header, sort_keys=True,
                    separators=(",", ":")).encode("utf-8")
    return codec._HEAD.pack(codec._MAGIC, 1, len(hb)) + hb + body


def test_codec_still_reads_v1_frames():
    """The CRC bump is backward-compatible on the read side: a v1 frame
    (no checksum in the header) decodes exactly — and, lacking a
    checksum, a corrupted v1 body decodes WITHOUT raising (the gap the
    version bump closes)."""
    msg = _payload_msg()
    v1 = _as_v1(codec.encode(msg))
    out = codec.decode(v1)
    assert (out.tag, out.sender, out.round, out.meta) == (
        msg.tag, msg.sender, msg.round, msg.meta)
    np.testing.assert_array_equal(out.payload["c"], msg.payload["c"])
    # same damage that test_codec_crc_detects_bit_flip catches on v2:
    damaged = v1[:-1] + bytes([v1[-1] ^ 0x01])
    bad = codec.decode(damaged)            # no checksum -> silent garbage
    assert not np.array_equal(bad.payload["c"], msg.payload["c"])


# ================================== typed delivery failures (FaultPlan) ====

def test_delivery_failed_carries_attempt_history():
    plan = FaultPlan(seed=7, party_drop=((2, 1.0),), max_retries=2,
                     timeout_ms=10.0)
    with pytest.raises(DeliveryFailed) as ei:
        plan.require(5, 2, "up")
    e = ei.value
    assert (e.seed, e.round, e.party, e.direction) == (7, 5, 2, "up")
    assert not e.delivery.ok and e.delivery.attempts == 3
    trail = e.delivery.history
    assert [a.attempt for a in trail] == [0, 1, 2]
    assert all(a.dropped for a in trail)
    # exponential backoff costs are part of the audit trail
    assert [a.elapsed_ms for a in trail] == [10.0, 20.0, 40.0]
    assert e.delivery.elapsed_ms == 70.0
    assert "3 attempts" in str(e) and "party=2" in str(e)
    # a clean delivery through the same plan does NOT raise
    assert plan.require(5, 1, "up").ok


def test_party_override_beats_global_knobs_both_directions():
    """Per-party overrides take precedence over the population-wide
    default in BOTH directions — a pinned-clean party never drops under
    a hostile global rate, and a pinned-dead party always fails under a
    clean one."""
    clean2 = FaultPlan(seed=0, drop=0.99, party_drop=((2, 0.0),),
                       max_retries=0)
    assert clean2.drop_for(2) == 0.0 and clean2.drop_for(1) == 0.99
    for t in range(50):
        for d in ("up", "down"):
            out = clean2.delivery(t, 2, d)
            assert out.ok and out.attempts == 1
    assert any(not clean2.delivery(t, 1, "up").ok for t in range(50))

    dead2 = FaultPlan(seed=0, party_drop=((2, 1.0),), max_retries=0)
    for d in ("up", "down"):
        assert not dead2.delivery(0, 2, d).ok
        with pytest.raises(DeliveryFailed):
            dead2.require(0, 2, d)
        assert dead2.delivery(0, 1, d).ok

    lat = FaultPlan(seed=0, latency_ms=1.0, party_latency_ms=((3, 9.0),))
    assert lat.latency_for(3) == 9.0 and lat.latency_for(0) == 1.0


# ====================================================== chaos backend ======

def test_chaos_backend_damages_real_wire_bytes():
    """ChaosBackend corruption/truncation happens on the ACTUAL framed
    bytes, after encoding — the receiving endpoint's decode raises typed
    FrameCorruption, and the wire keeps working for later frames."""
    a, b = LoopbackBackend.pair()
    chaos = ChaosBackend(a, ChaosPlan(corrupt_at_frame=2,
                                      truncate_at_frame=3))
    for r in range(4):
        chaos.send(_payload_msg(rnd=r))
    msg, _ = b.recv()
    assert msg.round == 0                  # frame 1: clean
    with pytest.raises(FrameCorruption, match="CRC32"):
        b.recv()                           # frame 2: bit-flipped payload
    with pytest.raises(FrameCorruption, match="truncated"):
        b.recv()                           # frame 3: cut to 8 bytes
    msg, _ = b.recv()
    assert msg.round == 3                  # frame 4: clean again
    assert chaos.frames_sent == 4


def test_chaos_backend_stalls_a_send():
    a, b = LoopbackBackend.pair()
    chaos = ChaosBackend(a, ChaosPlan(stall_at_frame=2, stall_s=0.15))
    t0 = time.monotonic()
    chaos.send(WireMessage("act", "server", 0))
    fast = time.monotonic() - t0
    t0 = time.monotonic()
    chaos.send(WireMessage("act", "server", 1))
    slow = time.monotonic() - t0
    assert slow >= 0.15 > fast
    for r in (0, 1):
        msg, _ = b.recv()
        assert msg.round == r              # stalled, not dropped


# ================================================== liveness heartbeat =====

def test_heartbeat_liveness_loopback(setup):
    Xp, y, params = setup
    eng, cli = LoopbackBackend.pair()
    worker = ClientWorker(tabular_adapter(CFG), VFL,
                          jax.tree.map(lambda a: a[0], params["clients"]),
                          Xp[0], 0, cli)
    # loopback peers are engine-pumped, so drive the round-trip manually
    eng.send(WireMessage("ping", "server", 0, {"nonce": 41}))
    assert worker.pump() == 1
    msg, _ = eng.recv()
    assert msg.tag == "pong" and msg.meta["nonce"] == 41
    # heartbeat() against a silent peer reports dead — it never raises
    assert heartbeat(eng, nonce=7, timeout=0.0) is False


def test_heartbeat_over_live_socket_worker(setup):
    """End-to-end liveness: after a full population run with
    ``stop_workers=False`` the subprocess worker still answers pings;
    after ``stop`` it reads as dead."""
    child = os.path.join(os.path.dirname(__file__), "_wire_socket_child.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    with _hard_timeout(240):
        listener, port = listen()
        proc = subprocess.Popen([sys.executable, child, str(port), "2"],
                                env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True)
        try:
            chan = accept(listener, timeout=120.0)
            pop = _pop(setup, channels={2: chan}, stop_workers=False,
                       ledger=Ledger())
            assert len(pop.losses) == EC.steps
            # between rounds: the worker is idle and answers the probe
            assert heartbeat(chan, nonce=99, timeout=30.0) is True
            chan.send(WireMessage("stop", "server"))
            out, err = proc.communicate(timeout=120)
            assert proc.returncode == 0, f"stdout:{out}\nstderr:{err}"
            # the peer is gone now: the probe reports dead, no exception
            assert heartbeat(chan, nonce=100, timeout=2.0) is False
        finally:
            listener.close()
            if proc.poll() is None:  # pragma: no cover - failure path
                proc.kill()


# ============================================= self-healing socket wire ====

def test_socket_self_heal_reconnects_after_peer_drop():
    """A ``self_heal=True`` socket survives its peer dropping the
    connection between frames: the recv that hits the dead stream
    re-dials with backoff and lands on the listener's next accept."""
    with _hard_timeout(60):
        listener, port = listen()
        got = {}

        def server():
            be1 = accept(listener, timeout=30.0)
            msg, _ = be1.recv(timeout=30.0)
            got["before"] = msg.meta["n"]
            be1.close()                    # drop the worker's connection
            be2 = accept(listener, timeout=30.0)   # the heal lands here
            be2.send(WireMessage("pong", "server", 0, {"nonce": 1}))
            msg2, _ = be2.recv(timeout=30.0)
            got["after"] = msg2.meta["n"]
            be2.close()

        th = threading.Thread(target=server, daemon=True)
        th.start()
        try:
            cli = SocketBackend.connect("127.0.0.1", port, self_heal=True,
                                        heal_attempts=20, heal_delay_s=0.05)
            cli.send(WireMessage("ping", "client", 0, {"n": 1}))
            msg, _ = cli.recv(timeout=30.0)    # peer died -> heal -> pong
            assert msg.tag == "pong" and msg.meta["nonce"] == 1
            cli.send(WireMessage("ping", "client", 0, {"n": 2}))
            th.join(timeout=30.0)
            assert not th.is_alive()
            assert cli.reconnects == 1         # exactly one self-heal
            assert got == {"before": 1, "after": 2}
            cli.close()
        finally:
            listener.close()


# ===================================== kill -9 a worker, finish the run ====

def test_population_survives_worker_kill9(setup):
    """ISSUE acceptance: party 2's subprocess is ``kill -9``'d mid-round
    (ChaosPlan kill before its 2nd frame — inside its FIRST round's
    (1+q)-lane embedding fan-out, after lane 0 already crossed the
    wire). The engine declares the party dead after the wire error,
    finishes EVERY round without hanging, keeps losses finite, and falls
    back to the initial parameter row at collect time."""
    child = os.path.join(os.path.dirname(__file__), "_wire_socket_child.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    with _hard_timeout(240):
        listener, port = listen()
        proc = subprocess.Popen(
            [sys.executable, child, str(port), "2",
             "--die-after-frames", "2"],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True)
        try:
            chan = accept(listener, timeout=120.0)
            pop = _pop(setup, channels={2: chan}, wire_timeout_s=30.0,
                       ledger=Ledger())
            out, err = proc.communicate(timeout=120)
        finally:
            listener.close()
            if proc.poll() is None:  # pragma: no cover - failure path
                proc.kill()
    assert proc.returncode == 9            # os._exit(9): died mid-protocol
    assert "CHILD_OK" not in out           # never reached a clean exit
    assert len(pop.losses) == EC.steps     # every round completed
    assert np.all(np.isfinite(pop.losses))
    assert pop.stats["dead_parties"] == 1
    assert pop.stats["uplink_drops"] > 0   # missed activations, not hangs
    assert pop.stats["participation"] < 1.0
    # collect fell back to the initial row for the dead party
    Xp, y, params = setup
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params["clients"]),
            jax.tree_util.tree_leaves_with_path(pop.params["clients"])):
        assert np.array_equal(np.asarray(a[2]), np.asarray(b[2])), pa


# ======================================= worker restart from checkpoint ====

def test_worker_restarts_from_checkpoint(setup, tmp_path):
    """A replacement worker process re-materializes its party row from a
    party-scoped checkpoint directory and speaks the protocol with
    exactly the frozen parameters (it never reads another party's row)."""
    Xp, y, params = setup
    row = jax.tree.map(lambda a: np.asarray(a[2]), params["clients"])
    save_checkpoint(str(tmp_path / "client_02"), row)

    eng, cli = LoopbackBackend.pair()
    worker = ClientWorker.from_checkpoint(
        tabular_adapter(CFG), VFL, str(tmp_path), 2, Xp[2], cli)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(row),
            jax.tree_util.tree_leaves_with_path(worker.client_params)):
        assert np.array_equal(a, np.asarray(b)), pa
    # the restarted worker serves the protocol from the restored state
    eng.send(WireMessage("collect", "server", 0))
    assert worker.pump() == 1
    msg, _ = eng.recv()
    assert msg.tag == "params" and msg.meta["party"] == 2
    restored = codec.unflatten_tree(msg.payload)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(row),
            jax.tree_util.tree_leaves_with_path(restored)):
        assert np.array_equal(a, np.asarray(b)), pa
    # a missing party directory is a hard error, not a silent fresh init
    with pytest.raises(FileNotFoundError):
        ClientWorker.from_checkpoint(
            tabular_adapter(CFG), VFL, str(tmp_path), 3, Xp[3], cli)
