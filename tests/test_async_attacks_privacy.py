"""Async engine semantics, the Table-I attack reproduction, and the
structural privacy ledger."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine, attacks
from repro.core.privacy import Ledger, round_messages
from repro.data import make_classification, vertical_partition
from repro.models import common, tabular


@pytest.fixture(scope="module")
def setup():
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 512, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


def test_schedule_distribution():
    probs = (0.7, 0.1, 0.1, 0.1)
    sched = async_engine.make_schedule(jax.random.key(0), 4000, 4, probs)
    frac0 = float(jnp.mean((sched == 0).astype(jnp.float32)))
    assert 0.65 < frac0 < 0.75


def test_cascaded_converges(setup):
    cfg, Xp, y, params = setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=300,
                                  batch_size=32), vfl, params, Xp, y)
    acc = float(tabular.accuracy(res.params, Xp, y))
    assert acc > 0.8, acc
    assert res.losses[-20:].mean() < res.losses[:20].mean()


def test_cascaded_faster_than_zoo_vfl(setup):
    """The paper's core claim at engine scale: same budget, same (safe)
    wire protocol — cascaded reaches a lower loss than full-ZOO."""
    cfg, Xp, y, params = setup
    n = 250
    res_c = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=n, batch_size=32),
        VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05), params, Xp, y)
    res_z = async_engine.run(
        async_engine.EngineConfig(method="zoo-vfl", steps=n, batch_size=32),
        VFLConfig(mu=1e-3, lr_server=0.001, lr_client=0.001), params, Xp, y)
    assert res_c.losses[-20:].mean() < res_z.losses[-20:].mean()


def test_delay_bookkeeping(setup):
    cfg, Xp, y, params = setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.01, lr_client=0.01)
    res = async_engine.run(
        async_engine.EngineConfig(method="cascaded", steps=50, batch_size=8),
        vfl, params, Xp, y)
    # async: some (client, sample) pairs go stale; sync resets every round
    assert res.max_delay_seen > 0
    res_sync = async_engine.run(
        async_engine.EngineConfig(method="split", steps=20, batch_size=8),
        vfl, params, Xp, y)
    assert res_sync.max_delay_seen == 0


# ------------------------------------------------------- Table I attack ---

def test_label_inference_foo_leaks():
    r = attacks.run_label_inference(jax.random.key(0), 10, 512,
                                    framework="foo")
    assert r.curious_client_acc == 1.0
    assert r.eavesdropper_acc == 1.0


def test_label_inference_zoo_defends():
    r = attacks.run_label_inference(jax.random.key(0), 10, 2048,
                                    framework="zoo")
    # paper Table I: curious client 11.7%, eavesdropper 10.0 (chance)
    assert r.curious_client_acc < 0.35
    assert abs(r.eavesdropper_acc - 0.10) < 0.05


def test_feature_inference_blackbox_defends():
    """§V-B: inversion needs the client model; the black-box wire reduces
    the server to chance-level feature reconstruction."""
    r = attacks.run_feature_inference(jax.random.key(1))
    assert r.mse_with_model_access < 0.2 * r.mse_black_box
    assert r.mse_black_box > 0.9 * r.mse_chance


# ------------------------------------------------------- privacy ledger ---

def test_ledger_zoo_methods_never_ship_gradients():
    for m in ("cascaded", "zoo-vfl", "syn-zoo-vfl"):
        led = Ledger()
        led.log_round(m, 64, 128)
        assert not led.transmits_gradients
        kinds = {msg.kind for msg in led.messages}
        assert kinds == {"embedding", "loss"}


def test_ledger_foo_methods_ship_gradients():
    for m in ("vafl", "split-learning"):
        led = Ledger()
        led.log_round(m, 64, 128)
        assert led.transmits_gradients


def test_ledger_byte_accounting():
    msgs = round_messages("cascaded", 64, 128)
    up = sum(m.nbytes for m in msgs if m.sender == "client")
    down = sum(m.nbytes for m in msgs if m.sender == "server")
    assert up == 2 * 64 * 128 * 4          # c and ĉ
    assert down == 2 * 64 * 4              # h and ĥ (scalars per sample)


def test_ledger_byte_accounting_scales_with_q():
    """q-aware wire accounting: ZOO rounds carry q perturbed embeddings up
    and q losses ĥ down; the clean c/h pair does not scale."""
    b, e = 64, 128
    for method in ("cascaded", "zoo-vfl", "syn-zoo"):
        ref = round_messages(method, b, e, zoo_queries=1)
        ref_up = sum(m.nbytes for m in ref if m.sender == "client")
        ref_down = sum(m.nbytes for m in ref if m.sender == "server")
        for q in (2, 4):
            msgs = round_messages(method, b, e, zoo_queries=q)
            up = sum(m.nbytes for m in msgs if m.sender == "client")
            down = sum(m.nbytes for m in msgs if m.sender == "server")
            # perturbed-only scaling: totals = clean + q * (one ĉ / one ĥ)
            assert up - b * e * 4 == q * (ref_up - b * e * 4)
            assert down - b * 4 == q * (ref_down - b * 4)
    # FOO wires have no query fan-out: q never changes the bytes
    assert (round_messages("vafl", b, e, zoo_queries=4)
            == round_messages("vafl", b, e, zoo_queries=1))


def test_ledger_q4_exactly_4x_perturbed_bytes():
    """ISSUE acceptance: cascaded q=4 totals are exactly 4× the perturbed
    embedding and ĥ bytes of q=1."""
    led1, led4 = Ledger(), Ledger()
    led1.log_round("cascaded", 64, 128, zoo_queries=1)
    led4.log_round("cascaded", 64, 128, zoo_queries=4)
    pert1 = sum(m.nbytes for m in led1.messages[1:]
                if m.kind == "embedding")
    pert4 = sum(m.nbytes for m in led4.messages[1:]
                if m.kind == "embedding")
    hhat1 = sum(m.nbytes for m in led1.messages if m.kind == "loss") / 2
    hhat4 = (sum(m.nbytes for m in led4.messages if m.kind == "loss")
             - 64 * 4)                       # minus the one clean h
    assert pert4 == 4 * pert1
    assert hhat4 == 4 * hhat1


def test_round_messages_accepts_engine_method_spellings():
    """The alias table is shared: every spelling cascade/async_engine
    accept must be accepted by the ledger (the 'syn-zoo' regression)."""
    from repro.core.methods import METHOD_ALIASES
    for spelling in METHOD_ALIASES:
        msgs = round_messages(spelling, 8, 4)
        assert msgs, spelling
    with pytest.raises(ValueError):
        round_messages("sgd-vfl", 8, 4)


def test_zoo_vfl_server_update_uses_zoo_queries(setup):
    """Regression: the engine's zoo-vfl SERVER step must honour
    vfl.zoo_queries (it silently used q=1 while the client used q)."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="zoo-vfl", steps=1, batch_size=16)
    res = {}
    for q in (1, 4):
        vfl = VFLConfig(mu=1e-3, lr_server=0.01, lr_client=0.01,
                        zoo_queries=q)
        res[q] = async_engine.run(ec, vfl, params, Xp, y)
    same = [bool(jnp.array_equal(a, b)) for a, b in zip(
        jax.tree.leaves(res[1].params["server"]),
        jax.tree.leaves(res[4].params["server"]))]
    assert not all(same), "server ZOO gradient ignored zoo_queries"
