"""Property tests for ``adapters.from_model_config`` — the bridge that
derives an async-engine ModelAdapter from any decoder ``ModelConfig``
(clients own the embedding spans, the server owns the backbone + head)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import VFLConfig, get_config, reduced
from repro.core import async_engine, zoo
from repro.core.adapters import from_model_config, lm_engine_params
from repro.data import lm_token_batches, vertical_partition
from repro.federation import Federation, GaussianLossChannel
from repro.models import common
from repro.models.model_api import build_model

SEQ = 16


def tiny_cfg(**overrides):
    return reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                   n_kv_heads=1, d_ff=128, vocab_size=256, **overrides)


def token_data(cfg, n=64, seq=SEQ, seed=3):
    toks = next(lm_token_batches(seed, cfg.vocab_size, n, seq))["tokens"]
    return jnp.asarray(toks)


# ------------------------------------------- global-loss equivalence ------

@settings(max_examples=6, deadline=None)
@given(n_clients=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**16))
def test_global_loss_matches_model_loss_fn(n_clients, seed):
    """With every client party holding the same embedding table, the
    derived adapter's global (all-fresh) loss IS the global model's
    ``loss_fn`` — the bridge changes the protocol, not the model."""
    cfg = tiny_cfg()
    model = build_model(cfg, max_seq=SEQ)
    gp = common.materialize(model.param_specs, jax.random.key(seed))
    adapter = from_model_config(cfg, n_clients=n_clients, seq_len=SEQ)
    ep = lm_engine_params(gp, n_clients)

    toks = token_data(cfg, n=8, seed=seed % 97)
    x_parts = jnp.asarray(vertical_partition(np.asarray(toks), n_clients))
    got = adapter.global_loss(ep, x_parts, toks)
    want, _ = model.loss_fn(gp, {"tokens": toks, "labels": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-6)


def test_engine_param_layout():
    cfg = tiny_cfg()
    adapter = from_model_config(cfg, n_clients=4, seq_len=SEQ)
    params = adapter.init_params(jax.random.key(0))
    assert set(params) == {"clients", "server"}
    table = params["clients"]["embed"]["table"]
    assert table.shape == (4, cfg.padded_vocab, cfg.d_model)
    assert "embed" not in params["server"]
    assert "mtp" not in params["server"]
    # replicated layout: every client row holds the same global table
    gp = common.materialize(build_model(cfg, max_seq=SEQ).param_specs,
                            jax.random.key(1))
    ep = lm_engine_params(gp, 3)
    rows = ep["clients"]["embed"]["table"]
    assert all(jnp.array_equal(rows[i], gp["embed"]["table"])
               for i in range(3))


def test_from_model_config_rejects_unsupported():
    with pytest.raises(ValueError, match="frontend"):
        from_model_config(reduced(get_config("whisper-medium")),
                          n_clients=2, seq_len=SEQ)
    with pytest.raises(ValueError, match="frontend"):
        from_model_config(reduced(get_config("internvl2-26b")),
                          n_clients=2, seq_len=SEQ)
    with pytest.raises(ValueError, match="split evenly"):
        from_model_config(tiny_cfg(), n_clients=3, seq_len=SEQ)


# ---------------------------------------------------- lanes fan-out -------

def test_client_lanes_matches_perturb_then_forward():
    """The fused lanes (one gather into the stacked direction tables)
    equal perturb-the-table-then-embed, lane for lane."""
    cfg = tiny_cfg()
    adapter = from_model_config(cfg, n_clients=2, seq_len=SEQ)
    params = adapter.init_params(jax.random.key(0))
    client_0 = jax.tree.map(lambda a: a[0], params["clients"])
    x_m = token_data(cfg, n=8)[:, : SEQ // 2]
    q, mu = 3, 1e-3
    u_stack, _ = zoo.sample_directions(jax.random.key(5), client_0, q)

    lanes = adapter.client_lanes(client_0, u_stack, mu, x_m)
    assert lanes.shape == (1 + q, 8, (SEQ // 2) * cfg.d_model)
    np.testing.assert_array_equal(
        np.asarray(lanes[0]), np.asarray(adapter.client_forward(client_0,
                                                                x_m)))
    for i in range(q):
        u_i = jax.tree.map(lambda a: a[i], u_stack)
        ref = adapter.client_forward(zoo.perturb(client_0, u_i, mu), x_m)
        np.testing.assert_array_equal(np.asarray(lanes[1 + i]),
                                      np.asarray(ref))


def test_lanes_engine_matches_unrolled_oracle():
    """Engine acceptance: the fused-lanes client fan-out tracks the
    unrolled per-query ZOO oracle over a full async run."""
    cfg = tiny_cfg()
    M = 4
    adapter = from_model_config(cfg, n_clients=M, seq_len=SEQ)
    params = adapter.init_params(jax.random.key(0))
    toks = token_data(cfg, n=64)
    x_parts = jnp.asarray(vertical_partition(np.asarray(toks), M))
    kw = dict(steps=6, batch_size=4)
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=1e-4, zoo_queries=2)
    r_lanes = async_engine.run(
        async_engine.EngineConfig(method="cascaded", use_lanes=True, **kw),
        vfl, params, x_parts, toks, adapter=adapter)
    import dataclasses
    r_oracle = async_engine.run(
        async_engine.EngineConfig(method="cascaded", use_lanes=False, **kw),
        dataclasses.replace(vfl, zoo_unrolled_oracle=True),
        params, x_parts, toks, adapter=adapter)
    np.testing.assert_allclose(r_lanes.losses, r_oracle.losses,
                               rtol=2e-4, atol=2e-5)


# ------------------------------------------------------ active rows -------

def test_row_mask_restricts_to_batch_tokens():
    cfg = tiny_cfg()
    adapter = from_model_config(cfg, n_clients=2, seq_len=SEQ)
    params = adapter.init_params(jax.random.key(0))
    client_0 = jax.tree.map(lambda a: a[0], params["clients"])
    x_m = jnp.asarray([[3, 7, 3, 11]], jnp.int32)
    mask = adapter.row_mask(client_0, x_m)["embed"]["table"]
    assert mask.shape == (cfg.padded_vocab,)
    assert set(np.flatnonzero(np.asarray(mask))) == {3, 7, 11}
    # masked directions carry no mass off the active rows
    u_stack, d_eff = zoo.sample_directions(
        jax.random.key(1), client_0, 2, "sphere",
        adapter.row_mask(client_0, x_m))
    off_rows = np.delete(np.asarray(u_stack["embed"]["table"]),
                         [3, 7, 11], axis=1)
    assert np.all(off_rows == 0.0)
    np.testing.assert_allclose(np.asarray(d_eff), 3 * cfg.d_model)


# ------------------------------------------- async end-to-end (ISSUE) -----

def test_federation_drives_async_lm_run():
    """ISSUE acceptance: Federation.build drives an async (staleness > 0)
    run of a reduced transformer-backbone config end-to-end — the loss
    decreases, wire accounting is reported, and no gradients cross."""
    cfg = tiny_cfg()
    M = 4
    fed = Federation.build(
        cfg, VFLConfig(mu=1e-3, lr_server=0.05, lr_client=1e-4,
                       active_rows_only=True),
        async_engine.EngineConfig(method="cascaded", steps=80, batch_size=8,
                                  use_lanes=True),
        n_clients=M, seq_len=SEQ)
    assert fed.adapter.row_mask is not None
    params = fed.init_params(jax.random.key(0))
    toks = token_data(cfg, n=64)
    x_parts = jnp.asarray(vertical_partition(np.asarray(toks), M))
    res = fed.run(params, x_parts, toks)
    assert res.max_delay_seen > 0                      # real staleness
    assert np.isfinite(res.losses).all()
    assert res.losses[-15:].mean() < res.losses[:15].mean()
    assert res.wire_bytes > 0
    assert not res.transmits_gradients


def test_session_gates_row_mask_on_vfl_flag():
    """vfl.active_rows_only selects the active-row ZOO mask on BOTH
    planes: the derived async adapter honours the same flag the sync
    cascade's _maybe_row_mask gates on."""
    cfg = tiny_cfg()
    ec = async_engine.EngineConfig(method="cascaded")
    on = Federation.build(cfg, VFLConfig(active_rows_only=True), ec,
                          n_clients=2, seq_len=SEQ)
    off = Federation.build(cfg, VFLConfig(active_rows_only=False), ec,
                           n_clients=2, seq_len=SEQ)
    assert on.adapter.row_mask is not None
    assert off.adapter.row_mask is None


def test_federation_async_lm_dp_budget():
    """Same run with the noise channel: still gradient-free, finite
    (ε, δ) reported on the EngineResult."""
    cfg = tiny_cfg()
    M = 2
    fed = Federation.build(
        cfg, VFLConfig(mu=1e-3, lr_server=0.05, lr_client=1e-4),
        async_engine.EngineConfig(method="cascaded", steps=10, batch_size=4),
        n_clients=M, seq_len=SEQ,
        noise=GaussianLossChannel(clip=10.0, epsilon=0.5, delta=1e-5))
    params = fed.init_params(jax.random.key(1))
    toks = token_data(cfg, n=32)
    x_parts = jnp.asarray(vertical_partition(np.asarray(toks), M))
    res = fed.run(params, x_parts, toks)
    assert np.isfinite(res.epsilon) and res.epsilon > 0
    assert 0 < res.delta < 1
    assert not res.transmits_gradients
    assert np.isfinite(res.losses).all()
