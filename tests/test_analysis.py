"""The analysis plane, tested in both directions.

Static passes: every rule in the catalogue trips on its seeded fixture
(tests/analysis_fixtures/), every clean exemplar stays quiet, the
suppression/baseline machinery behaves, and the shipped tree itself is
clean under ``--strict`` (the CI gate invariant).

Runtime sanitizers: the host-transfer and recompile sentinels count what
a run actually does, and the continuous-batching scheduler's steady
state — block-stepping between admission and retirement on a warmed
engine — performs ZERO device->host transfers and ZERO retraces.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import cli, runtime, tags
from repro.models import common

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")


@pytest.fixture(scope="module")
def corpus():
    return cli.analyze_paths([FIXTURES])


def _rules_by_file(findings):
    out = {}
    for f in findings:
        name = os.path.relpath(f.path, FIXTURES)
        out.setdefault(name, []).append(f.rule)
    return {k: sorted(v) for k, v in out.items()}


# ------------------------------------------------------- static passes ----

EXPECTED = {
    "ba003_unknown_rule.py": ["BA003"],
    "federation/scheduler.py": ["TH201"],
    "pb101_undeclared_uplink.py": ["PB101"],
    "pb102_grad_downlink.py": ["PB102", "PB102"],
    "pb103_raw_features.py": ["PB103"],
    "pb104_unmetered_wire.py": ["PB104"],
    "pb105_raw_losses.py": ["PB105"],
    "suppressed.py": ["BA001", "PB101"],
    "th201_hot_loop.py": ["TH201", "TH201", "TH201"],
    "th202_traced_branch.py": ["TH202"],
    "th203_scan_carry.py": ["TH203", "TH203"],
    "th204_debug.py": ["TH204", "TH204", "TH204"],
}


def test_every_rule_has_a_failing_fixture(corpus):
    tripped = {f.rule for f in corpus}
    # BA002 needs a broken file; IF3xx are jaxpr rules — their leaky
    # fixtures live in analysis_fixtures/ifc/ and are exercised through
    # the certifier in test_ifc.py (they are AST-clean by design)
    static_rules = {r for r in cli.RULES if not r.startswith("IF")} - {"BA002"}
    assert static_rules <= tripped, static_rules - tripped


def test_fixture_corpus_exact(corpus):
    assert _rules_by_file(corpus) == EXPECTED


def test_clean_exemplars_stay_quiet(corpus):
    flagged = {os.path.basename(f.path) for f in corpus}
    assert "clean_transport_flow.py" not in flagged
    assert "transportlike.py" not in flagged


def test_suppression_mechanics(corpus):
    sup = [f for f in corpus if f.path.endswith("suppressed.py")]
    # the justified ignore swallows its PB101; the reasonless one is
    # BA001 and its PB101 survives
    assert {(f.rule, f.line) for f in sup} == {("BA001", 13), ("PB101", 14)}


def test_select_family_filter(corpus, capsys):
    only_pb = cli.select_families(corpus, "PB")
    assert only_pb and {f.rule[:2] for f in only_pb} == {"PB"}
    assert cli.select_families(corpus, "pb, th") == cli.select_families(
        corpus, "PB,TH")
    with pytest.raises(SystemExit) as exc:
        cli.select_families(corpus, "ZZ")
    assert exc.value.code == 2
    with pytest.raises(SystemExit):
        cli.select_families(corpus, "")
    capsys.readouterr()


def test_select_flag_end_to_end(capsys):
    # the fixtures trip PB rules, so selecting only TH on a PB fixture
    # passes while the unknown family is a usage error (exit 2)
    pb_only = os.path.join(FIXTURES, "pb101_undeclared_uplink.py")
    assert cli.main([pb_only, "--strict", "--select", "TH"]) == 0
    assert cli.main([pb_only, "--strict", "--select", "PB"]) == 1
    with pytest.raises(SystemExit) as exc:
        cli.main([pb_only, "--select", "IF,NOPE"])
    assert exc.value.code == 2
    capsys.readouterr()


def test_partial_scan_resolves_registry_accounting(capsys):
    """PB104 regression: scanning ONLY the wire plane must still resolve
    ``accounted_by="Transport.account_wire"`` — the accounting registry
    (tags.ACCOUNTING_MODULES) seeds the target set on partial scans."""
    wire_dir = os.path.join(SRC, "wire")
    assert cli.main([wire_dir, "--strict"]) == 0
    assert "Transport.account_wire" in cli.registry_accounting()
    capsys.readouterr()


def test_ba002_on_unparseable_file(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    findings = cli.analyze_paths([str(bad)])
    assert [f.rule for f in findings] == ["BA002"]


def test_shipped_tree_is_clean():
    assert cli.analyze_paths([SRC]) == []


def test_baseline_workflow(tmp_path, capsys):
    base = str(tmp_path / "baseline.json")
    assert cli.main([FIXTURES, "--write-baseline", base]) == 0
    keys = json.loads(open(base).read())
    assert len(keys) == sum(len(v) for v in EXPECTED.values())
    # baselined findings are tolerated...
    assert cli.main([FIXTURES, "--baseline", base]) == 0
    # ...but --strict ignores the baseline entirely
    assert cli.main([FIXTURES, "--baseline", base, "--strict"]) == 1
    capsys.readouterr()


def test_wire_decorator_stacks_and_host_boundary_needs_reason():
    @tags.wire("up", accounted_by="Transport.account")
    @tags.wire("down", accounted_by="Transport.account", kind="loss")
    def both_ways():
        return None

    assert [w["direction"] for w in both_ways.__vfl_wire__] == ["down", "up"]
    with pytest.raises(ValueError):
        tags.host_boundary("")
    with pytest.raises(ValueError):
        tags.wire("sideways", accounted_by="Transport.account")
    with pytest.raises(ValueError):
        tags.party("referee")


# --------------------------------------------------- runtime sentinels ----


def test_host_sentinel_counts_each_materializing_read_once():
    rep = runtime.SanitizerReport()
    with runtime.host_transfer_sentinel(rep):
        x = jnp.arange(4) * 2
        np.asarray(x)                       # 1: buffer-protocol read
        y = jnp.arange(4) + 1
        y.tolist()                          # 2: materializes + caches
        y.tolist()                          # cached: free
        np.asarray(y)                       # cached: free
        (jnp.ones(()) * 3).item()           # 3
        int(jnp.arange(5).sum())            # 4
        np.asarray(np.arange(4))            # host array: free
    assert rep.d2h == 4, rep.d2h_sites


def test_strict_raises_and_names_the_call_site():
    with pytest.raises(runtime.StrictModeViolation) as exc:
        with runtime.strict():
            np.asarray(jnp.arange(3) + 7)
    assert "test_analysis.py" in str(exc.value)


def test_recompile_sentinel_fresh_vs_cached():
    @jax.jit
    def f(x):
        return x * 2 + 1

    arg = jnp.arange(6, dtype=jnp.float32)
    fresh, cached = runtime.SanitizerReport(), runtime.SanitizerReport()
    with runtime.recompile_sentinel(fresh):
        f(arg).block_until_ready()
    with runtime.recompile_sentinel(cached):
        f(arg).block_until_ready()
    assert fresh.compiles >= 1
    assert cached.compiles == 0


# ------------------------------------- steady-state scheduler hygiene ----


def _toy_session(n_clients=2, seq_len=24):
    from repro.configs import get_config, reduced
    from repro.federation import Federation
    cfg = reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab_size=256, remat=False)
    fed = Federation.build(cfg, n_clients=n_clients, seq_len=seq_len)
    return cfg, fed


def test_steady_state_paged_decode_is_host_silent():
    """The acceptance invariant: on a warmed engine, the block-stepping
    loop between admission and retirement does zero d2h transfers and
    zero fresh compiles — strict() raises otherwise."""
    PL, GL, n_req = 8, 16, 3
    cfg, fed = _toy_session(seq_len=PL + GL)
    key = jax.random.key(0)
    params = common.materialize(fed.model.param_specs, key)
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (n_req, PL), 0, cfg.vocab_size))

    srv = fed.serve(params, max_batch=n_req)
    for i in range(n_req):
        srv.submit(prompts[i], GL, key=jax.random.fold_in(key, i))
    warm = srv.run()                     # compiles the whole block ladder

    for i in range(n_req):
        srv.submit(prompts[i], GL, key=jax.random.fold_in(key, i))
    srv._admit_free_slots()

    def occupied():
        return [s for s in range(srv.max_batch)
                if srv._slot_req[s] is not None]

    with runtime.strict() as rep:        # raises StrictModeViolation on any
        while occupied() and min(srv._remaining[s]
                                 for s in occupied()) > 0:
            srv._block_step()
    assert rep.d2h == 0 and rep.compiles == 0

    srv._retire_wave()                   # the one sanctioned wave fetch
    res = [srv.results[r.rid + n_req] for r in warm]
    assert all(np.array_equal(a.tokens, b.tokens)
               for a, b in zip(res, warm))
