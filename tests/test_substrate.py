"""Optimizers, schedules, checkpointing, data pipeline, HLO parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import lm_token_batches, make_classification, vertical_partition
from repro.optim import adamw, sgd
from repro.optim.schedule import inv_sqrt, make_schedule, warmup_cosine
from repro.utils.hlo import collective_bytes, parse_collectives


# ------------------------------------------------------------ optimizers --

def test_sgd_quadratic_converges():
    opt = sgd(0.3)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-4


def test_sgd_momentum_faster_on_illconditioned():
    def run(opt):
        params = {"w": jnp.asarray([5.0, 5.0])}
        state = opt.init(params)
        H = jnp.asarray([1.0, 0.01])
        for _ in range(100):
            params, state = opt.update({"w": H * params["w"]}, state, params)
        return float(jnp.sum(jnp.abs(params["w"])))
    assert run(sgd(0.5, momentum=0.9)) < run(sgd(0.5))


def test_adamw_converges_and_decays():
    opt = adamw(0.1, weight_decay=0.1)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    for _ in range(200):
        params, state = opt.update({"w": params["w"] - 1.0}, state, params)
    # fixed point: grad + wd*w = 0 -> w ~= 1/(1+wd·...) < 1
    assert 0.5 < float(params["w"][0]) < 1.0


def test_grad_clip():
    opt = sgd(1.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    params, _ = opt.update({"w": jnp.full(4, 100.0)}, state, params)
    assert abs(float(jnp.linalg.norm(params["w"])) - 1.0) < 1e-4


def test_schedules():
    s = warmup_cosine(1.0, 10, 110)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(110)) < 0.2
    s2 = inv_sqrt(1.0, warmup=4)
    assert float(s2(jnp.asarray(1))) < float(s2(jnp.asarray(4)))
    assert float(s2(jnp.asarray(100))) < float(s2(jnp.asarray(25)))
    with pytest.raises(ValueError):
        make_schedule("nope", 1.0)


# ------------------------------------------------------------ checkpoint --

def test_checkpoint_roundtrip(tmp_path):
    params = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
              "c": jnp.asarray([1, 2], jnp.int32)}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, step=42, metadata={"note": "hi"})
    loaded, step = load_checkpoint(path, params)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(loaded["a"]["b"]),
                                  np.asarray(params["a"]["b"]))
    assert loaded["c"].dtype == jnp.int32


def test_atomic_write_crash_preserves_target(tmp_path):
    """A writer that dies mid-write leaves the PREVIOUS file intact and
    no temp litter — never a torn file at the target path."""
    from repro.checkpoint.io import atomic_write
    target = os.path.join(tmp_path, "state.json")
    atomic_write(target, lambda f: f.write("v1"), mode="w")

    def torn(f):
        f.write("v2 but only hal")
        raise RuntimeError("power loss (simulated)")

    with pytest.raises(RuntimeError, match="power loss"):
        atomic_write(target, torn, mode="w")
    with open(target) as f:
        assert f.read() == "v1"
    assert os.listdir(tmp_path) == ["state.json"]   # tmp file unlinked


def test_checkpoint_survives_torn_write(tmp_path, monkeypatch):
    """Regression: a process killed mid-``save_checkpoint`` (half an npz
    written, then nothing) must leave the previous checkpoint loadable
    bitwise — arrays are replaced atomically and the manifest last."""
    from repro.checkpoint import io as ckpt_io
    path = os.path.join(tmp_path, "ckpt")
    v1 = {"a": {"b": jnp.arange(6, dtype=jnp.float32)},
          "c": jnp.asarray([1, 2], jnp.int32)}
    save_checkpoint(path, v1, step=1)

    def torn_savez(f, **arrays):
        f.write(b"PK\x03\x04 half an npz, then the lights went out")
        raise KeyboardInterrupt("kill -9 (simulated)")

    v2 = {"a": {"b": jnp.full((6,), 7.0, jnp.float32)},
          "c": jnp.asarray([9, 9], jnp.int32)}
    with monkeypatch.context() as m:
        m.setattr(ckpt_io.np, "savez", torn_savez)
        with pytest.raises(KeyboardInterrupt):
            save_checkpoint(path, v2, step=2)

    loaded, step = load_checkpoint(path, v1)
    assert step == 1                       # the old checkpoint, complete
    np.testing.assert_array_equal(np.asarray(loaded["a"]["b"]),
                                  np.asarray(v1["a"]["b"]))
    np.testing.assert_array_equal(np.asarray(loaded["c"]),
                                  np.asarray(v1["c"]))
    assert not [fn for fn in os.listdir(path) if fn.endswith(".tmp")]


# ------------------------------------------------------------------ data --

def test_vertical_partition_disjoint_and_complete():
    X, y = make_classification(0, 64, 32, 4)
    Xp = vertical_partition(X, 4)
    assert Xp.shape == (4, 64, 8)
    np.testing.assert_array_equal(np.concatenate(list(Xp), axis=1), X)


def test_classification_learnable():
    """A linear probe should beat chance easily on the synthetic task."""
    X, y = make_classification(1, 1000, 32, 4, sep=3.0)
    # one ridge-regression step as a cheap probe
    Y = np.eye(4)[y]
    W = np.linalg.lstsq(X, Y, rcond=None)[0]
    acc = np.mean(np.argmax(X @ W, -1) == y)
    assert acc > 0.8, acc


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_generator_deterministic(seed):
    a = next(lm_token_batches(seed, 100, 2, 16))
    b = next(lm_token_batches(seed, 100, 2, 16))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_lm_tokens_in_range():
    batch = next(lm_token_batches(0, 257, 4, 64))
    assert batch["tokens"].min() >= 0
    assert batch["tokens"].max() < 257


# ------------------------------------------------------------- HLO parse --

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[256,128]{1,0} all-gather(%p), replica_groups={}
  %ar = f32[64,64]{1,0} all-reduce(%x), to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = bf16[16,128]{1,0} all-to-all(%p), dimensions={0}
  %cp = s32[8]{0} collective-permute(%idx), source_target_pairs={{0,1}}
}
"""


def test_parse_collectives_kinds():
    got = dict((k, b) for k, b in parse_collectives(HLO_SAMPLE))
    assert set(got) == {"all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"}
    assert got["all-gather"] == 256 * 128 * 2
    assert got["all-reduce"] == 64 * 64 * 4


def test_collective_bytes_allreduce_doubled():
    agg = collective_bytes(HLO_SAMPLE)
    assert agg["all-reduce"] == 2 * 64 * 64 * 4
    assert agg["total"] == (256 * 128 * 2 + 2 * 64 * 64 * 4 + 4 * 128 * 2
                            + 16 * 128 * 2 + 8 * 4)
