"""The corpus' accounting target: `Transport.account` is the one legal
``accounted_by`` qualname inside this fixture set (mirrors the real
``repro.federation.transport.Transport``)."""
from repro.analysis import tags


class Transport:
    @tags.accounting
    def account(self, message):
        return message
