"""PB102 both ways: a gradient pushed into a client hook, and a gradient
returned from client-party code — neither declares a "down" wire."""
import jax

from repro.analysis import tags


def push_exact_grads(adapter, params, batch):
    g = jax.grad(_loss)(params)
    adapter.client_forward(g, batch)  # PB102: gradient into a client hook
    return g


@tags.party("client")
def client_receives(params, batch):
    g = jax.value_and_grad(_loss)(params)
    return g  # PB102: gradient-typed return from client-party code


def _loss(params):
    return 0.0
