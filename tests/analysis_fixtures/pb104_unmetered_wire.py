"""PB104: a wire declaration pointing at a qualname that is not an
``@tags.accounting`` method — the channel would cross unmetered."""
from repro.analysis import tags


@tags.wire("up", accounted_by="Transport.launder", kind="embedding",
           reason="typo'd accounting target")
def declared_but_unmetered(adapter, params, e):  # PB104 (on the def)
    return adapter.server_loss(params["server"], e, None)
