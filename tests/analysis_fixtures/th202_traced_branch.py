"""TH202: Python branching on traced values inside traced functions.
Shape/None checks and static_argnames-excluded parameters stay legal."""
import jax


@jax.jit
def relu_bad(x):
    if x > 0:  # TH202: traced-value branch
        return x
    return x * 0


@jax.jit
def pad_ok(x):
    if x.ndim == 1:  # quiet: shape metadata is static
        return x[None]
    return x


def step(x, mode):
    if mode == "fast":  # quiet: `mode` is a static argument below
        return x * 2
    return x


def none_guard(x, mask):
    out = x if mask is None else x * mask  # quiet: None check is static
    return out


step_fast = jax.jit(step, static_argnames=("mode",))
guard_traced = jax.jit(none_guard)
