"""TH201 via the hot-module path match (this file's path ends with
``federation/scheduler.py``): host syncs INSIDE for/while loops are
flagged without any decorator; the same sync outside a loop is not."""
import numpy as np


def drive(srv):
    out = []
    for rid in srv.queue:
        out.append(np.asarray(srv.fetch(rid)))  # TH201: sync per iteration
    final = np.asarray(srv.buffer)  # quiet: one amortized fetch after
    return out, final
