"""TH201 in a ``@tags.hot_loop`` body: syncs, coercions and uploads are
flagged ANYWHERE, no loop statement required. The ``host_boundary`` twin
doing the same fetch is sanctioned."""
import jax.numpy as jnp
import numpy as np

from repro.analysis import tags


@tags.hot_loop
def block_step_bad(state):
    tables = jnp.asarray(state.tables)  # TH201: per-step upload
    k = float(state.remaining.min())    # TH201: host coercion
    toks = np.asarray(state.gen_buf)    # TH201: device->host fetch
    return tables, k, toks


@tags.host_boundary("once-per-wave retirement fetch, amortized over the "
                    "whole drain")
def retire_wave_ok(state):
    return np.asarray(state.gen_buf)  # quiet: sanctioned crossing
