"""The bitwise-benign flow the analyzer must NOT flag: a declared,
metered uplink and a downlink-laundered loss feed to the ZOO estimator
(the shape of ``repro.core.cascade.make_cascaded_step``)."""
import jax

from repro.analysis import tags
from repro.core import zoo


@tags.wire("up", accounted_by="Transport.account", kind="embedding",
           reason="declared uplink: clean + perturbed embeddings, metered "
                  "by the fixture Transport")
def cascaded_step(adapter, transport, params, batch, u_stack, mu, phi, key):
    lanes = adapter.client_lanes(params["clients"], batch, u_stack, mu)
    losses = adapter.server_loss(params["server"], lanes, batch)  # declared
    recv = transport.downlink(losses, key)  # DP noise + ledger
    g = zoo.grad_from_losses(u_stack, recv[1:], recv[0], mu, phi)  # laundered
    return g, jax.tree.map(lambda a: a, params)
