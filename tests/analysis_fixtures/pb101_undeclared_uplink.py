"""PB101: client-sourced value reaches a server sink with no declared wire."""


def train_step(adapter, params, batch):
    e = adapter.client_embed(params["clients"], batch)
    return adapter.server_loss(params["server"], e, batch)  # PB101
