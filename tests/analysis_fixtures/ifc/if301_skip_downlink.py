"""LEAKY (jaxpr fixture): the client update consumes raw server-side
cotangents and never touches ``Transport.downlink`` — the FOO shortcut
the paper's §V forbids. The engine's gradient anchor
(``marks.grad_mark``, exactly what ``_server_update`` wraps its
first-order gradients in) puts ``grad`` taint on the update, and the
taint reaches a client-bound output with no wire boundary on the path:
the certifier must report **IF301 and nothing else**.

This file is deliberately AST-clean — the source-text passes cannot see
the leak (the gradient call is a bare name, the flow crosses no tagged
attribute), which is the whole point of certifying the jaxpr instead.
"""
import jax.numpy as jnp
from jax import grad

from repro.analysis import marks

EXPECT = "IF301"


def build():
    def objective(server_w, client_w, x, y):
        c = x @ client_w
        s = c @ server_w
        return jnp.mean((s - y) ** 2)

    def fn(server_w, client_w, x, y):
        # raw cotangents of the joint objective, handed straight to the
        # client optimizer: skips the loss downlink entirely
        g = marks.grad_mark(grad(objective, argnums=1)(server_w, client_w,
                                                       x, y))
        return client_w - 0.1 * g

    args = (jnp.zeros((4, 2)), jnp.zeros((3, 4)), jnp.zeros((8, 3)),
            jnp.zeros((8, 2)))
    return dict(fn=fn, args=args,
                is_server=lambda p: p.startswith("[0]"),
                dp_configured=False, down_limits={"loss": 3})
