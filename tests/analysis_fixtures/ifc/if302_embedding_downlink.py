"""LEAKY (jaxpr fixture): a (batch, embed) matrix of server-side
activations pushed through the REAL ``Transport.downlink``. The flow is
wire-declared and laundered — no IF301 — but the paper's bottleneck is
(1+q) loss *scalars* per activated client, and the crossing's shape is
read off the jaxpr: the certifier must report **IF302 and nothing
else**.
"""
import jax
import jax.numpy as jnp

from repro.federation.transport import Transport

EXPECT = "IF302"


def build():
    transport = Transport("cascaded")

    def fn(server_w, x, key):
        acts = jnp.tanh(x @ server_w)       # (batch, embed) server values
        # the real downlink channel, misused: a matrix is not a loss lane
        return transport.downlink(acts, key)

    args = (jnp.zeros((3, 8)), jnp.zeros((4, 3)), jax.random.key(0))
    return dict(fn=fn, args=args,
                is_server=lambda p: p.startswith("[0]"),
                dp_configured=False, down_limits={"loss": 3})
