"""LEAKY (jaxpr fixture): DP noise applied AFTER the ZOO estimator
consumed the losses — the (1+q) raw loss scalars cross the wire
unnoised, and the "noise" only perturbs a value that never leaves the
server's blast radius. With a DP channel configured, the downlink
crossing must carry ``dp`` taint (noise BEFORE the wire, as
``Transport.downlink`` does); here it carries raw ``server`` taint, so
the certifier must report **IF303 and nothing else**. The crossing is
correctly shaped ((1+q,) scalars), so IF302 stays quiet — only the
noising ORDER is wrong.
"""
import jax.numpy as jnp

from repro.analysis import marks

EXPECT = "IF303"


def build():
    mu = 1e-3

    def fn(server_w, u, x, y):
        def loss_at(w):
            return jnp.mean((x @ w - y) ** 2)

        # 1 clean + 2 perturbed server losses: the ZOO lane stack
        losses = jnp.stack([loss_at(server_w),
                            loss_at(server_w + mu * u),
                            loss_at(server_w - mu * u)])
        # WRONG ORDER: raw losses hit the wire...
        sent = marks.wire_boundary(losses, kind="loss", direction="down")
        est = (sent[1] - sent[0]) / mu      # two-point estimate, client side
        # ...and the noise lands after the estimator already consumed them
        return marks.dp_noise(est * jnp.mean(u))

    args = (jnp.zeros((3,)), jnp.ones((3,)), jnp.zeros((8, 3)),
            jnp.zeros((8,)))
    return dict(fn=fn, args=args,
                is_server=lambda p: p.startswith("[0]"),
                dp_configured=True, down_limits={"loss": 3})
