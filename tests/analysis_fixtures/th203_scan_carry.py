"""TH203: literal-dtype casts that (re)define a ``lax.scan`` carry.
Casting the xs/outputs and anchoring to ``.dtype`` are both fine."""
import jax
import jax.numpy as jnp


def body_rebind(h, x):
    h = (h + x).astype(jnp.float32)  # TH203: carry rebound at literal dtype
    return h, x


def body_return(h, x):
    return (h * x).astype(jnp.bfloat16), h  # TH203: carry slot of the return


def body_ok(h, x):
    acc = x.astype(jnp.float32)      # quiet: xs cast (f32 accumulation)
    h = (h + acc).astype(h.dtype)    # quiet: anchored to the carry dtype
    return h, acc


def run(h0, xs):
    a, _ = jax.lax.scan(body_rebind, h0, xs)
    b, _ = jax.lax.scan(body_return, h0, xs)
    c, _ = jax.lax.scan(body_ok, h0, xs)
    return a, b, c
