"""PB105: server-evaluated losses fed straight to the client's ZOO
estimator — Transport.downlink (DP noise + ledger) bypassed."""
from repro.core import zoo


def leaky_zoo_update(server_loss, u_stack, mu, phi):
    losses = server_loss(u_stack)
    return zoo.grad_from_losses(u_stack, losses[1:], losses[0],
                                mu, phi)  # PB105
