"""TH204: leftover debug instrumentation."""
import jax


def scan_body_with_debug(h, x):
    jax.debug.print("h={h}", h=h)  # TH204
    return h + x, x


@jax.jit
def traced_print(x):
    print("tracing", x)  # TH204: fires once per trace, not per step
    return x * 2


def stale_breakpoint(x):
    breakpoint()  # TH204
    return x
