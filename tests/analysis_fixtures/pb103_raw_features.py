"""PB103: raw (pre-embedding) client features touched by server code.
The client-party twin touching the same name stays legal."""
from repro.analysis import tags


@tags.party("server")
def server_backbone(params, x_parts, y):
    return _backbone(params, x_parts)  # PB103: raw features on the server


@tags.party("client")
def client_projection(params, x_parts):
    return _embed(params, x_parts)  # quiet: clients own their features


def _backbone(params, x):
    return x


def _embed(params, x):
    return x
