"""Suppression mechanics: a justified ignore silences its finding; an
unjustified one is BA001 AND the original finding survives."""


def justified(adapter, params, batch):
    e = adapter.client_embed(params["clients"], batch)
    # analysis: ignore[PB101] fixture: documented test-only crossing
    return adapter.server_loss(params["server"], e, batch)  # quiet


def unjustified(adapter, params, batch):
    e = adapter.client_embed(params["clients"], batch)
    # analysis: ignore[PB101]
    return adapter.server_loss(params["server"], e, batch)  # PB101 + BA001
