"""Fixture: a justified suppression naming a rule id that does not
exist. The AST passes find nothing wrong with the code itself — the
typo'd id is the defect (BA003): it suppresses nothing, so the finding
it meant to cover would keep firing under the real id."""


def quiet_helper(x):
    # analysis: ignore[PB999] guarding a rule id that was never minted
    return x + 1
